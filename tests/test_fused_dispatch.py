"""Fused dispatch kernel (kernels/fused_dispatch.py) gates.

``backend="pallas_fused"`` folds the class-sort gather and the inverse-
permutation scatter into the weight-switch kernel via a second scalar-
prefetch operand (the plan's row-index vector).  The contract tested
here: BITWISE equality with the unfused pallas backend (same compute
shapes tile by tile), oracle-level (<1e-6) equality with the XLA
engine, at most ONE standalone activation gather and ONE scatter per
layer in the traced program (the exact-path capacity buffers — vs 3 of
each under unfused pallas), and zero retraces across every traced
input (mask, tiers, margins, residency).
"""
from __future__ import annotations

import dataclasses
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.jit_cache import assert_zero_retrace
from repro.analysis.opcount import activation_moves
from repro.configs.registry import get_config, smoke_config
from repro.kernels import ops
from repro.models import model as M
from repro.runtime import dispatch as D

jax.config.update("jax_platform_name", "cpu")

_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"}


def _mk_case(key, t, n, d, d_h):
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (t, d), jnp.float32) * 0.5
    router = jax.random.normal(ks[1], (d, n + 1)) * 0.5
    w1 = jax.random.normal(ks[2], (n, d, d_h)) * 0.2
    b1 = jax.random.normal(ks[3], (n, d_h)) * 0.1
    w2 = jax.random.normal(ks[4], (n, d_h, d)) * 0.2
    b2 = jax.random.normal(ks[5], (n, d)) * 0.1
    wi = jax.random.normal(jax.random.fold_in(key, 7), (d, 2 * d)) * 0.1
    wo = jax.random.normal(jax.random.fold_in(key, 8), (2 * d, d)) * 0.1
    exact_fn = lambda xb: jnp.dot(jax.nn.silu(jnp.dot(xb, wi)), wo)
    return x, x @ router, (w1, b1, w2, b2), exact_fn


# ---------------------------------------------------------------------------
# engine: fused == unfused pallas (bitwise) == xla oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,n,d,d_h,block", [
    (200, 3, 64, 32, 64),     # generous capacity, mixed classes
    (37, 2, 24, 8, 32),       # T < block_t
    (128, 1, 32, 16, 64),     # single approximator
    (96, 5, 40, 8, 16),       # many classes, some likely sparse
])
@pytest.mark.parametrize("masked", [False, True])
def test_fused_matches_unfused_and_oracle(t, n, d, d_h, block, masked):
    key = jax.random.PRNGKey(t * 131 + n)
    x, logits, w, exact_fn = _mk_case(key, t, n, d, d_h)
    mask = (jnp.arange(t) % 5 != 0) if masked else None
    caps = dict(exact_cap=max(t // 2, 1), invoke_cap=max(int(t * 0.4), 1),
                row_mask=mask)
    yx, sx = D.mcma_dispatch(x, logits, exact_fn, *w, backend="xla", **caps)
    yp, sp = D.mcma_dispatch(x, logits, exact_fn, *w, backend="pallas",
                             block_t=block, interpret=True, **caps)
    yf, sf = D.mcma_dispatch(x, logits, exact_fn, *w, backend="pallas_fused",
                             block_t=block, interpret=True, **caps)
    # the fused kernel runs the SAME compute shapes tile by tile as the
    # unfused one — bitwise, not approximately
    np.testing.assert_array_equal(np.asarray(yf), np.asarray(yp))
    np.testing.assert_allclose(np.asarray(yf), np.asarray(yx),
                               rtol=1e-6, atol=1e-6)
    for k in ("class_counts", "dispatched", "dropped"):
        np.testing.assert_array_equal(np.asarray(sf[k]), np.asarray(sx[k]))


def test_fused_mixed_qos_tiers_and_asymmetric_caps():
    t, n = 160, 3
    x, logits, w, exact_fn = _mk_case(jax.random.PRNGKey(5), t, n, 48, 16)
    tier = jnp.arange(t, dtype=jnp.int32) % 3
    margins = jnp.asarray([0.8, 0.0, -0.8], jnp.float32)
    caps = dict(exact_cap=t // 2, invoke_cap=(48, 32, 16),
                tier=tier, tier_margins=margins)
    outs = {}
    for be in D.DISPATCH_BACKENDS:
        interp = be in D.PALLAS_BACKENDS
        outs[be] = np.asarray(D.mcma_dispatch(
            x, logits, exact_fn, *w, backend=be, block_t=32,
            interpret=interp, **caps)[0])
    np.testing.assert_array_equal(outs["pallas_fused"], outs["pallas"])
    np.testing.assert_allclose(outs["pallas_fused"], outs["xla"],
                               rtol=1e-6, atol=1e-6)


def test_fused_residency_swap_bitexact_and_zero_retrace():
    t, lib, d, d_h = 96, 6, 32, 16
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (t, d), jnp.float32) * 0.5
    logits = x @ (jax.random.normal(ks[1], (d, lib + 1)) * 0.5)
    w1 = jax.random.normal(ks[2], (lib, d, d_h)) * 0.2
    b1 = jnp.zeros((lib, d_h))
    w2 = jax.random.normal(ks[3], (lib, d_h, d)) * 0.2
    b2 = jnp.zeros((lib, d))
    stacks = ops.prepad_switched_weights(w1, b1, w2, b2)
    wi = jax.random.normal(ks[4], (d, d)) * 0.1
    exact_fn = lambda xb: jnp.dot(xb, wi)
    fns = {}
    for be in D.DISPATCH_BACKENDS:
        interp = be in D.PALLAS_BACKENDS
        fns[be] = jax.jit(lambda xx, lg, rv, b=be, ip=interp:
                          D.mcma_dispatch(
                              xx, lg, exact_fn, *stacks, exact_cap=48,
                              invoke_cap=32, backend=b, block_t=32,
                              interpret=ip, weights_prepadded=True,
                              residency=rv)[0])
    for res in ([0, 1, 2], [5, 3, 1], [4, 4, 0]):    # incl. duplicate ids
        rv = jnp.asarray(res, jnp.int32)
        ys = {be: np.asarray(f(x, logits, rv)) for be, f in fns.items()}
        np.testing.assert_array_equal(ys["pallas_fused"], ys["pallas"])
        np.testing.assert_allclose(ys["pallas_fused"], ys["xla"],
                                   rtol=1e-6, atol=1e-6)
    for be, f in fns.items():
        assert_zero_retrace(f, f"{be}: a residency swap")


def test_fused_vector_io_branches_bit_identical():
    """The kernel's two static I/O lowerings (vectorized take/.at vs the
    per-row fori DMA form) must produce the same bits — the compiled-mode
    branch is what runs on TPU, the vectorized one in CI."""
    t, n, d, d_h = 130, 4, 40, 16
    x, _, (w1, b1, w2, b2), _ = _mk_case(jax.random.PRNGKey(3), t, n, d, d_h)
    cls = jax.random.randint(jax.random.PRNGKey(4), (t,), 0, n)
    ys = [np.asarray(ops.switched_apply_fused(
        x, cls, w1, b1, w2, b2, block_t=32, interpret=True, vector_io=vio))
        for vio in (True, False)]
    np.testing.assert_array_equal(ys[0], ys[1])
    np.testing.assert_array_equal(
        ys[0], np.asarray(ops.switched_apply(
            x, cls, w1, b1, w2, b2, block_t=32, interpret=True)))


# ---------------------------------------------------------------------------
# op-count audit: <= 1 standalone activation gather/scatter per layer
# ---------------------------------------------------------------------------

def test_fused_execute_runs_one_activation_pass_per_layer():
    t, n, d, d_h, layers = 128, 3, 32, 16, 3
    x, logits, w, exact_fn = _mk_case(jax.random.PRNGKey(9), t, n, d, d_h)
    stacked = jax.tree.map(
        lambda a: jnp.stack([a * (0.8 + 0.1 * i) for i in range(layers)]), w)
    moves = {}
    for be in D.DISPATCH_BACKENDS:
        interp = be in D.PALLAS_BACKENDS
        plan = D.make_dispatch_plan(logits, exact_cap=64, invoke_cap=48,
                                    backend=be, block_t=32)

        def tick(xx, ip=interp, p=plan):
            def layer(h, ws):
                return D.execute_dispatch(p, h, exact_fn, *ws,
                                          interpret=ip), None
            return jax.lax.scan(layer, xx, stacked)[0]

        g, s = activation_moves(jax.make_jaxpr(tick)(x))
        assert g % layers == 0 and s % layers == 0, (be, g, s)
        moves[be] = (g // layers, s // layers)
    gf, sf = moves["pallas_fused"]
    gu, su = moves["pallas"]
    # fused: only the exact-path capacity buffers remain standalone;
    # unfused additionally pays the class-sort gather + inverse scatter
    assert gf <= 1 and sf <= 1, moves
    assert gf < gu and sf < su, moves


def test_fused_plan_reuse_zero_retrace_across_traced_inputs():
    t, n, d, d_h = 96, 3, 32, 16
    x, logits, w, exact_fn = _mk_case(jax.random.PRNGKey(21), t, n, d, d_h)
    margins = jnp.asarray([0.5, 0.0, -0.5], jnp.float32)
    tier = jnp.arange(t, dtype=jnp.int32) % 3
    plan_fn = jax.jit(lambda lg, mk, tr, mg: D.make_dispatch_plan(
        lg, mk, exact_cap=48, invoke_cap=32, backend="pallas_fused",
        block_t=32, tier=tr, tier_margins=mg))
    exec_fn = jax.jit(lambda p, xx: D.execute_dispatch(
        p, xx, exact_fn, *w, interpret=True))
    mask = jnp.ones((t,), bool)
    for i in range(3):                       # mask/tier/margin changes
        p = plan_fn(logits + 0.1 * i, mask.at[i].set(False),
                    (tier + i) % 3, margins * (1.0 - 0.2 * i))
        jax.block_until_ready(exec_fn(p, x))
    assert_zero_retrace(plan_fn, "a mask/tier/margin change")
    assert_zero_retrace(exec_fn, "a replanned fused execute")


# ---------------------------------------------------------------------------
# model decode: layer + tick scope through the fused backend
# ---------------------------------------------------------------------------

def _decode_cfg(backend, scope):
    cfg = smoke_config(get_config("internlm2-1.8b"))
    return dataclasses.replace(cfg, approx=dataclasses.replace(
        cfg.approx, enable=True, backend=backend,
        interpret=backend in D.PALLAS_BACKENDS, block_t=16,
        route_scope=scope))


@pytest.mark.parametrize("scope", ["layer", "tick"])
def test_decode_step_fused_backend_both_scopes(scope):
    B = 8
    params = M.init_model(jax.random.PRNGKey(0), _decode_cfg("xla", scope))
    toks = jnp.arange(1, B + 1, dtype=jnp.int32)[:, None]
    mask = jnp.asarray([True] * 6 + [False] * 2)
    outs = {}
    for be in D.DISPATCH_BACKENDS:
        cfg = _decode_cfg(be, scope)
        cache = M.init_cache(cfg, B, 32)
        lg, _, m = M.decode(cfg, params, cache, toks, serve=True,
                            collect_metrics=True, row_mask=mask)
        outs[be] = np.asarray(lg)
        assert np.isfinite(float(m["invocation"]))
    np.testing.assert_array_equal(outs["pallas_fused"], outs["pallas"])
    np.testing.assert_allclose(outs["pallas_fused"], outs["xla"],
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# mesh: (4, 2) — subprocess always, in-process when 8 devices exist
# ---------------------------------------------------------------------------

_MESH = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs.registry import get_config, smoke_config
    from repro.models import model as M
    from repro.sharding import activations as A

    def cfg_with(backend, scope):
        cfg = smoke_config(get_config("internlm2-1.8b"))
        return dataclasses.replace(cfg, approx=dataclasses.replace(
            cfg.approx, enable=True, backend=backend, interpret=True,
            block_t=16, route_scope=scope))

    B = 8
    mask = jnp.asarray([True] * 6 + [False] * 2)
    toks = jnp.arange(1, B + 1, dtype=jnp.int32)[:, None]
    params = M.init_model(jax.random.PRNGKey(0), cfg_with("xla", "tick"))
    out = {}
    for scope in ("layer", "tick"):
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        outs = {}
        for backend in ("xla", "pallas", "pallas_fused"):
            c = cfg_with(backend, scope)
            cache = M.init_cache(c, B, 32)
            with mesh, A.activation_sharding(P(("data",), None, None)):
                lg, _, m = jax.jit(
                    lambda p, ca, t, rm, c_=c: M.decode(
                        c_, p, ca, t, serve=True, collect_metrics=True,
                        row_mask=rm))(params, cache, toks, mask)
            outs[backend] = np.asarray(lg)
        out[scope] = {
            "fused_bitexact_vs_pallas": bool(
                np.array_equal(outs["pallas_fused"], outs["pallas"])),
            "fused_err_vs_xla": float(
                np.abs(outs["pallas_fused"] - outs["xla"]).max()),
        }
    print("RESULT" + json.dumps(out))
""")


def test_fused_mesh_subprocess_4x2():
    r = subprocess.run([sys.executable, "-c", _MESH], capture_output=True,
                       text=True, timeout=600, env=_ENV)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.split("RESULT")[1])
    for scope in ("layer", "tick"):
        assert out[scope]["fused_bitexact_vs_pallas"], (scope, out)
        assert out[scope]["fused_err_vs_xla"] < 2e-5, (scope, out)


needs_8_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (CI multidevice leg: XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")


@needs_8_devices
def test_fused_sharded_engine_inprocess_8_devices():
    t, n, d, d_h = 256, 4, 32, 16
    x, logits, (w1, b1, w2, b2), _ = _mk_case(
        jax.random.PRNGKey(13), t, n, d, d_h)
    wi = jax.random.normal(jax.random.PRNGKey(14), (d, d)) * 0.1
    exact_fn_p = lambda ep, xb: jnp.dot(xb, ep)
    mesh = jax.make_mesh((8,), ("data",))
    outs = {}
    for be in D.DISPATCH_BACKENDS:
        interp = be in D.PALLAS_BACKENDS
        y, _ = jax.jit(lambda xx, lg, b=be, ip=interp:
                       D.mcma_dispatch_sharded(
                           mesh, xx, lg, exact_fn_p, wi, w1, b1, w2, b2,
                           exact_cap=16, invoke_cap=12, backend=b,
                           block_t=16, interpret=ip))(x, logits)
        outs[be] = np.asarray(y)
    np.testing.assert_array_equal(outs["pallas_fused"], outs["pallas"])
    np.testing.assert_allclose(outs["pallas_fused"], outs["xla"],
                               rtol=1e-6, atol=1e-6)
