"""Capacity autotuning (runtime/autotune.py) and the free-slot router-bias
fix it depends on.

The controller is pure host-side python over the engine's invoke_stats, so
its law is unit-tested directly; the acceptance invariant (skewed mix ->
operating point under the drop budget with strictly more served
invocation than static, pallas == xla at every point) runs against the
real engine single-device AND on an 8-virtual-device mesh (subprocess,
the test_sharding.py pattern).  The mask fix is pinned by equating a
half-empty slot table with its dense sub-batch, at the engine and at the
DecodeServer level.
"""
import dataclasses
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, smoke_config
from repro.runtime import dispatch as D
from repro.runtime.autotune import (CapacityController, OperatingPoint,
                                    default_ladder, point_caps)

jax.config.update("jax_platform_name", "cpu")

_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"}


def _run(script: str, timeout: int = 600) -> dict:
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=timeout, env=_ENV)
    assert r.returncode == 0, r.stderr[-2000:]
    return json.loads(r.stdout.split("RESULT")[1])


# ---------------------------------------------------------------------------
# Controller law (host-side, no engine)
# ---------------------------------------------------------------------------

def _ctrl(ladder=None, t=100, n=3, **kw):
    ladder = ladder or (OperatingPoint(0.5, 0.1), OperatingPoint(0.5, 0.3),
                        OperatingPoint(1.0, 1.0))
    kw.setdefault("cooldown", 0)
    kw.setdefault("down_patience", 2)
    return CapacityController(
        ladder, lambda pt: point_caps(pt, t, n), drop_budget=0.05, **kw)


def _stats(counts):
    counts = np.asarray(counts, float)
    return {"class_counts": counts, "dropped": 0.0}


def test_controller_steps_up_to_first_sufficient_rung():
    c = _ctrl()
    # 60 rows hot on class 1 vs invoke_cap 10 at rung 0: 50 dropped
    s = {"class_counts": np.asarray([40., 60., 0., 0.]), "dropped": 50.0}
    idx = c.observe(s)
    # rung 1 (cap 30) still drops 30; rung 2 (cap 100) is the first fit
    assert idx == 2
    assert c.history[0].from_index == 0 and c.history[0].to_index == 2


def test_controller_steps_down_with_patience_and_hysteresis():
    c = _ctrl(start=2)
    # light mix: fits every rung's caps (exact 45 < 50, per-class <= 10)
    light = {"class_counts": np.asarray([45., 4., 3., 3.]), "dropped": 0.0}
    assert c.observe(light) == 2          # patience 1/2
    assert c.observe(light) == 1          # patience reached -> one rung
    # one rung at a time, and the new rung needs fresh patience
    assert c.observe(light) == 1
    assert c.observe(light) == 0


def test_controller_cooldown_blocks_consecutive_switches():
    c = _ctrl(cooldown=3)
    hot = {"class_counts": np.asarray([0., 100., 0., 0.]), "dropped": 90.0}
    assert c.observe(hot) == 2
    light = {"class_counts": np.asarray([45., 0., 0., 0.]), "dropped": 0.0}
    for _ in range(3):                    # inside cooldown: frozen
        assert c.observe(light) == 2
    for _ in range(2):
        c.observe(light)
    assert c.index == 1                   # then the down path resumes


def test_controller_backoff_dampens_thrash():
    """A mix the prediction clears but reality drops (the layer-mean /
    cross-shard-skew case) must not oscillate forever: each re-escalation
    doubles the patience before the next down attempt."""
    c = _ctrl(down_patience=1)
    # at rung 1: prediction from these counts fits rung 0... but observed
    # drops say otherwise once we get there
    deceptive_ok = {"class_counts": np.asarray([45., 5., 0., 0.]),
                    "dropped": 0.0}
    deceptive_bad = {"class_counts": np.asarray([45., 5., 0., 0.]),
                     "dropped": 40.0}
    c.index = 1
    downs = []
    for i in range(64):
        # reality: rung 0 drops hard, higher rungs don't
        idx = c.observe(deceptive_bad if c.index == 0 else deceptive_ok)
        if c.history and c.history[-1].to_index < c.history[-1].from_index \
                and (not downs or c.history[-1].tick != downs[-1]):
            downs.append(c.history[-1].tick)
    assert len(downs) >= 2
    gaps = np.diff(downs)
    assert (gaps[1:] >= gaps[:-1]).all(), gaps   # monotone non-decreasing
    assert c._down_hold > 1                      # backoff engaged


def test_default_ladder_ordered_and_bracketing():
    cfg = smoke_config(get_config("internlm2-1.8b"))
    cfg = dataclasses.replace(cfg, approx=dataclasses.replace(
        cfg.approx, enable=True))
    lad = default_ladder(cfg)
    a = cfg.approx
    costs = [p.cost(a.n_approx) for p in lad]
    assert costs == sorted(costs)
    assert OperatingPoint(a.exact_frac, a.invoke_frac, a.shard_slack) in lad
    assert lad[-1] == OperatingPoint(1.0, 1.0, a.shard_slack)
    assert len(set(lad)) == len(lad)


# ---------------------------------------------------------------------------
# Free-slot bias fix: masked dispatch == dense sub-batch
# ---------------------------------------------------------------------------

def _mk_case(key, t, n, d, d_h):
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (t, d), jnp.float32) * 0.5
    router = jax.random.normal(ks[1], (d, n + 1)) * 0.5
    w = (jax.random.normal(ks[2], (n, d, d_h)) * 0.2,
         jax.random.normal(ks[3], (n, d_h)) * 0.1,
         jax.random.normal(ks[4], (n, d_h, d)) * 0.2,
         jax.random.normal(ks[5], (n, d)) * 0.1)
    wi = jax.random.normal(jax.random.fold_in(key, 7), (d, 2 * d)) * 0.1
    wo = jax.random.normal(jax.random.fold_in(key, 8), (2 * d, d)) * 0.1
    exact_fn = lambda xb: jnp.dot(jax.nn.silu(jnp.dot(xb, wi)), wo)
    return x, x @ router, w, exact_fn


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_half_empty_mask_equals_dense_batch(backend):
    """Regression for the free-slot router bias: a half-active row mask
    must yield the SAME invoke_stats as dispatching only the active rows,
    and identical outputs on them (idle rows exactly zero)."""
    t, n, d, d_h = 128, 3, 48, 16
    x, logits, w, exact_fn = _mk_case(jax.random.PRNGKey(3), t, n, d, d_h)
    kw = dict(exact_cap=t // 2, invoke_cap=t // 3, backend=backend)
    if backend == "pallas":
        kw.update(block_t=32, interpret=True)
    mask = jnp.arange(t) < t // 2
    ym, sm = D.mcma_dispatch(x, logits, exact_fn, *w, row_mask=mask, **kw)
    yd, sd = D.mcma_dispatch(x[:t // 2], logits[:t // 2], exact_fn, *w, **kw)
    np.testing.assert_array_equal(np.asarray(sm["class_counts"]),
                                  np.asarray(sd["class_counts"]))
    np.testing.assert_array_equal(np.asarray(sm["dispatched"]),
                                  np.asarray(sd["dispatched"]))
    assert int(sm["dropped"]) == int(sd["dropped"])
    assert float(sm["invocation"]) == pytest.approx(
        float(sd["invocation"]), abs=1e-7)
    assert int(sm["class_counts"].sum()) == t // 2     # idle rows excluded
    np.testing.assert_allclose(np.asarray(ym)[:t // 2], np.asarray(yd),
                               rtol=1e-6, atol=1e-6)
    assert not np.asarray(ym)[t // 2:].any()           # idle rows -> zero


def test_all_false_mask_reports_zero_invocation():
    """A fully idle batch must report invocation 0.0 (not the 1.0 that
    1 - 0/max(0,1) would claim) and all-zero counts/outputs."""
    t, n, d, d_h = 64, 2, 32, 8
    x, logits, w, exact_fn = _mk_case(jax.random.PRNGKey(6), t, n, d, d_h)
    y, s = D.mcma_dispatch(x, logits, exact_fn, *w, exact_cap=16,
                           invoke_cap=16, backend="xla",
                           row_mask=jnp.zeros((t,), bool))
    assert float(s["invocation"]) == 0.0
    assert float(s["exact_frac"]) == 0.0
    assert int(s["class_counts"].sum()) == 0
    assert not np.asarray(y).any()


def test_all_true_mask_is_identity():
    """mask of all-True must trace to the exact same numbers as no mask."""
    t, n, d, d_h = 96, 2, 32, 8
    x, logits, w, exact_fn = _mk_case(jax.random.PRNGKey(4), t, n, d, d_h)
    kw = dict(exact_cap=t // 2, invoke_cap=t // 3, backend="xla")
    y0, s0 = D.mcma_dispatch(x, logits, exact_fn, *w, **kw)
    y1, s1 = D.mcma_dispatch(x, logits, exact_fn, *w,
                             row_mask=jnp.ones((t,), bool), **kw)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    np.testing.assert_array_equal(np.asarray(s0["class_counts"]),
                                  np.asarray(s1["class_counts"]))


def test_server_half_empty_table_matches_batch1_invocation():
    """DecodeServer end-to-end: one request on a 4-slot table must report
    the SAME invocation rate (and tokens) as on a 1-slot table — the
    free slots no longer enter the router stats."""
    from repro.models import model as M
    from repro.runtime.server import DecodeServer, Request
    cfg = smoke_config(get_config("internlm2-1.8b"))
    cfg = dataclasses.replace(cfg, approx=dataclasses.replace(
        cfg.approx, enable=True))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(1, 9, dtype=np.int32)
    outs = []
    for batch in (1, 4):
        srv = DecodeServer(cfg, params, batch=batch, max_len=64,
                           use_mcma_dispatch=True)
        r = Request(rid=0, prompt=prompt, max_new=5)
        srv.submit(r)
        stats = srv.run_until_drained(200)
        outs.append((r.out, stats["invocation_rate"],
                     stats["served_invocation_rate"]))
    assert outs[0][0] == outs[1][0]
    assert outs[0][1] == pytest.approx(outs[1][1], abs=1e-9)
    assert outs[0][2] == pytest.approx(outs[1][2], abs=1e-9)


# ---------------------------------------------------------------------------
# Acceptance: autotune on a skewed mix, single-device engine
# ---------------------------------------------------------------------------

def _hot_logits(key, t, n, hot, hot_frac):
    ks = jax.random.split(key, 2)
    cls = jnp.where(jax.random.uniform(ks[0], (t,)) < hot_frac, hot,
                    jax.random.randint(ks[1], (t,), 0, n + 1))
    return jax.nn.one_hot(cls, n + 1) * 10.0


def test_autotune_converges_under_budget_and_beats_static():
    """Skewed mix where the static config drops >10% of approximable
    rows: the controller must settle under the drop budget with strictly
    more served approximator rows than static, and the Pallas backend
    must match the XLA oracle bit-for-bit at EVERY visited point."""
    t, n, d, d_h = 256, 3, 48, 16
    budget = 0.05
    x, _, w, exact_fn = _mk_case(jax.random.PRNGKey(11), t, n, d, d_h)
    ladder = (OperatingPoint(0.5, 0.15), OperatingPoint(0.5, 0.35),
              OperatingPoint(1.0, 1.0))
    ctrl = CapacityController(ladder, lambda pt: point_caps(pt, t, n),
                              drop_budget=budget, cooldown=1,
                              down_patience=4)

    def run(idx, lg, backend):
        pt = ladder[idx]
        kw = dict(exact_cap=max(int(t * pt.exact_frac), 1),
                  invoke_cap=max(int(t * pt.invoke_frac), 1))
        if backend == "pallas":
            kw.update(block_t=32, interpret=True)
        return D.mcma_dispatch(x, lg, exact_fn, *w, backend=backend, **kw)

    static_drop = static_served = 0.0
    tuned_served = 0.0
    drops = []
    for tick in range(16):
        lg = _hot_logits(jax.random.fold_in(jax.random.PRNGKey(5), tick),
                         t, n, hot=n, hot_frac=0.8)
        yx, sx = run(ctrl.index, lg, "xla")
        yp, sp = run(ctrl.index, lg, "pallas")
        np.testing.assert_array_equal(np.asarray(yp), np.asarray(yx))
        _, ss = run(0, lg, "xla")
        static_drop += float(ss["dropped"])
        static_served += float(np.asarray(ss["dispatched"])[1:].sum())
        tuned_served += float(np.asarray(sx["dispatched"])[1:].sum())
        drops.append(float(sx["dropped"]) / t)
        ctrl.observe(jax.tree.map(np.asarray, sx))
    approximable = 0.8 * t * 16                     # ~hot rows alone
    assert static_drop / approximable > 0.10        # the premise holds
    assert np.mean(drops[-4:]) <= budget            # converged under budget
    assert tuned_served > static_served             # strictly more invoked
    assert ctrl.index > 0                           # actually moved


# ---------------------------------------------------------------------------
# Acceptance: the same invariant on an 8-virtual-device mesh (subprocess)
# ---------------------------------------------------------------------------

_MESH_AUTOTUNE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.runtime import dispatch as D
    from repro.runtime.autotune import (CapacityController, OperatingPoint,
                                        point_caps)
    from repro.sharding.rules import shard_capacity

    T, N, DC, DH, DEVS, BLOCK = 128, 3, 32, 16, 8, 8
    TL = T // DEVS
    BUDGET = 0.05
    ks = jax.random.split(jax.random.PRNGKey(11), 8)
    x = jax.random.normal(ks[0], (T, DC), jnp.float32) * 0.5
    w1 = jax.random.normal(ks[2], (N, DC, DH)) * 0.2
    b1 = jax.random.normal(ks[3], (N, DH)) * 0.1
    w2 = jax.random.normal(ks[4], (N, DH, DC)) * 0.2
    b2 = jax.random.normal(ks[5], (N, DC)) * 0.1
    wi = jax.random.normal(ks[6], (DC, 2 * DC)) * 0.1
    wo = jax.random.normal(ks[7], (2 * DC, DC)) * 0.1
    exact_fn_p = lambda ep, xb: jnp.dot(jax.nn.silu(jnp.dot(xb, ep[0])),
                                        ep[1])
    mesh = jax.make_mesh((DEVS,), ("data",))

    ladder = (OperatingPoint(0.5, 0.15), OperatingPoint(0.5, 0.35),
              OperatingPoint(1.0, 1.0))
    ctrl = CapacityController(
        ladder, lambda pt: point_caps(pt, TL, N, n_shards=DEVS),
        drop_budget=BUDGET, cooldown=1, down_patience=4)

    def hot_logits(key, hot_frac):
        k1, k2 = jax.random.split(key)
        cls = jnp.where(jax.random.uniform(k1, (T,)) < hot_frac, N,
                        jax.random.randint(k2, (T,), 0, N + 1))
        return jax.nn.one_hot(cls, N + 1) * 10.0

    fns = {}            # (rung, backend) -> jitted engine (never retraced)

    def run(idx, lg, backend):
        if (idx, backend) not in fns:
            pt = ladder[idx]
            ec = shard_capacity(TL, pt.exact_frac, slack=pt.shard_slack)
            ic = shard_capacity(TL, pt.invoke_frac, slack=pt.shard_slack)
            fns[(idx, backend)] = jax.jit(
                lambda a, b, be=backend, e=ec, i=ic:
                D.mcma_dispatch_sharded(
                    mesh, a, b, exact_fn_p, (wi, wo), w1, b1, w2, b2,
                    exact_cap=e, invoke_cap=i, backend=be, block_t=BLOCK,
                    interpret=(be == "pallas")))
        return fns[(idx, backend)](x, lg)

    static_drop = static_served = tuned_served = 0.0
    drops, bitexact = [], True
    TICKS = 12
    for tick in range(TICKS):
        lg = hot_logits(jax.random.fold_in(jax.random.PRNGKey(5), tick),
                        0.8)
        yx, sx = run(ctrl.index, lg, "xla")
        yp, sp = run(ctrl.index, lg, "pallas")
        bitexact &= bool(np.array_equal(np.asarray(yp), np.asarray(yx)))
        _, ss = run(0, lg, "xla")
        static_drop += float(ss["dropped"])
        static_served += float(np.asarray(ss["dispatched"])[1:].sum())
        tuned_served += float(np.asarray(sx["dispatched"])[1:].sum())
        drops.append(float(sx["dropped"]) / T)
        ctrl.observe(jax.tree.map(np.asarray, sx))
    print("RESULT" + json.dumps({
        "static_drop_frac_of_hot": static_drop / (0.8 * T * TICKS),
        "tail_drop": float(np.mean(drops[-4:])),
        "tuned_served": tuned_served, "static_served": static_served,
        "final_index": ctrl.index, "bitexact": bitexact,
        "budget": BUDGET}))
""")


def test_autotune_mesh_converges_under_budget_subprocess():
    out = _run(_MESH_AUTOTUNE, timeout=900)
    assert out["bitexact"]
    assert out["static_drop_frac_of_hot"] > 0.10
    assert out["tail_drop"] <= out["budget"]
    assert out["tuned_served"] > out["static_served"]
    assert out["final_index"] > 0


# ---------------------------------------------------------------------------
# DecodeServer integration
# ---------------------------------------------------------------------------

def test_server_autotune_end_to_end_reports_trajectory():
    from repro.models import model as M
    from repro.runtime.server import DecodeServer, Request
    cfg = smoke_config(get_config("internlm2-1.8b"))
    cfg = dataclasses.replace(cfg, approx=dataclasses.replace(
        cfg.approx, enable=True))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    ladder = (OperatingPoint(0.25, 0.1), OperatingPoint(1.0, 1.0))
    srv = DecodeServer(cfg, params, batch=2, max_len=64,
                       use_mcma_dispatch=True, autotune=ladder,
                       drop_budget=0.05,
                       autotune_kwargs=dict(cooldown=1, down_patience=4))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 5)
                    .astype(np.int32), max_new=4) for i in range(3)]
    for r in reqs:
        srv.submit(r)
    stats = srv.run_until_drained(300)
    assert all(r.done for r in reqs)
    at = stats["autotune"]
    assert 0 <= at["final_index"] < len(ladder)
    assert at["ticks"] == stats["ticks"]
    for s in at["switches"]:
        assert 0 <= s["from_index"] < len(ladder)
        assert 0 <= s["to_index"] < len(ladder)
    # the satellite-3 observability fields are present and consistent
    assert stats["dropped_rows"] >= 0.0
    disp = np.asarray(stats["dispatched_per_class"])
    routed = np.asarray(stats["routed_per_class"])
    assert disp.shape == routed.shape == (cfg.approx.n_approx + 1,)
    assert (disp <= routed + 1e-6).all()
    assert 0.0 <= stats["served_invocation_rate"] <= 1.0
