"""Runtime: decode server (continuous batching), gradient compression,
optimizers, straggler monitor."""
import dataclasses
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, smoke_config
from repro.models import model as M
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, cosine_schedule
from repro.runtime.server import DecodeServer, Request
from repro.runtime.trainer import StragglerMonitor

jax.config.update("jax_platform_name", "cpu")


def test_server_drains_and_recycles_slots():
    cfg = smoke_config(get_config("internlm2-1.8b"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    server = DecodeServer(cfg, params, batch=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 5).astype(np.int32),
                    max_new=6) for i in range(5)]   # 5 requests, 2 slots
    for r in reqs:
        server.submit(r)
    stats = server.run_until_drained(max_ticks=500)
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 6 for r in reqs)
    assert stats["ticks"] < 500


def test_server_slot_reset_isolates_requests():
    """A recycled slot must not leak KV state: the same prompt must yield
    the same tokens whether it runs in a fresh server or a recycled slot."""
    cfg = smoke_config(get_config("olmo-1b"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(1, 9, dtype=np.int32)

    fresh = DecodeServer(cfg, params, batch=1, max_len=64)
    r1 = Request(rid=0, prompt=prompt, max_new=5)
    fresh.submit(r1)
    fresh.run_until_drained(200)

    recycled = DecodeServer(cfg, params, batch=1, max_len=64)
    filler = Request(rid=1, prompt=np.ones(3, np.int32), max_new=4)
    r2 = Request(rid=2, prompt=prompt, max_new=5)
    recycled.submit(filler)
    recycled.submit(r2)
    recycled.run_until_drained(200)

    assert r1.out == r2.out, (r1.out, r2.out)


def test_adamw_decreases_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    opt = adamw_init(params)
    step = jnp.zeros((), jnp.int32)
    for i in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt = adamw_update(params, grads, opt, step + i, lr=5e-2,
                                   weight_decay=0.0)
    assert float(jnp.sum(params["w"] ** 2)) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(jnp.asarray(s), base_lr=1.0, warmup=10,
                                 total=100)) for s in range(100)]
    assert lrs[0] < lrs[9]                 # warmup rises
    assert max(lrs) == pytest.approx(1.0, rel=1e-2)
    assert lrs[-1] < 0.01                  # decays to ~0


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor()
    flags = [mon.observe(1.0) for _ in range(10)]
    assert not any(flags)
    assert mon.observe(10.0) is True
    assert mon.slow_steps == 1


_COMPRESSION = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.optim.compression import ef_int8_allreduce_tree, init_error_feedback

    mesh = jax.make_mesh((4,), ("pod",))
    # quadratic: each pod sees a different shard of the data
    key = jax.random.PRNGKey(0)
    targets = jax.random.normal(key, (4, 8))
    w0 = jnp.zeros((8,))

    @partial(shard_map, mesh=mesh, in_specs=(P(), P("pod"), P()),
             out_specs=(P(), P()), check_rep=False)
    def compressed_step(w, tgt, err):
        g = 2 * (w - tgt[0])                     # local gradient
        mean_g, new_err = ef_int8_allreduce_tree({"g": g}, {"g": err},
                                                 "pod")
        return mean_g["g"], new_err["g"]

    @partial(shard_map, mesh=mesh, in_specs=(P(), P("pod")), out_specs=P(),
             check_rep=False)
    def exact_step(w, tgt):
        g = 2 * (w - tgt[0])
        return jax.lax.pmean(g, "pod")

    # one jitted fori_loop: eager multi-device dispatch costs ~1s/step on
    # host devices, which pushed the subprocess past its timeout.  The
    # carry starts at the (4, 8) shape w takes after the first step (the
    # replicated err broadcasts through the allreduce) so the loop-carry
    # type is stable; values match the eager trajectory exactly.
    @jax.jit
    def run(w0, targets, err0):
        def body(_, c):
            w_c, err, w_e = c
            g_c, err = compressed_step(w_c, targets, err)
            return (w_c - 0.05 * g_c, err,
                    w_e - 0.05 * exact_step(w_e, targets))
        wb = jnp.zeros((4, 8)) + w0
        return jax.lax.fori_loop(0, 300, body, (wb, err0, wb))

    err0 = jnp.zeros((4, 8))                     # per-pod error feedback
    w_c, err, w_e = run(w0, targets, err0)
    opt = jnp.mean(targets, 0)
    out = {"err_compressed": float(jnp.linalg.norm(w_c - opt)),
           "err_exact": float(jnp.linalg.norm(w_e - opt))}
    print("RESULT" + json.dumps(out))
""")


def test_int8_error_feedback_converges():
    r = subprocess.run([sys.executable, "-c", _COMPRESSION],
                       capture_output=True, text=True, timeout=600,
                       # JAX_PLATFORMS pins CPU: without it jax probes the
                       # TPU plugin and stalls ~8min on TPU-less hosts
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr[-2000:]
    import json
    out = json.loads(r.stdout.split("RESULT")[1])
    assert out["err_exact"] < 1e-3
    assert out["err_compressed"] < 1e-2   # EF keeps quantization unbiased
