"""Snapshot of the serving public API (``repro.runtime``).

The ServeOptions/LibrarySpec consolidation made ``repro.runtime`` the
one import surface for deployments; this file pins it.  A failure here
means the public API changed: if deliberate, update the snapshot IN THE
SAME PR and call it out as breaking (removal/rename) or additive (new
name — append it).
"""
import dataclasses

import repro.runtime as rt

RUNTIME_ALL = (
    "CapacityController",
    "DecodeServer",
    "DispatchPlan",
    "DrainStats",
    "InvokeStats",
    "LibrarySpec",
    "OperatingPoint",
    "Request",
    "ResidencyController",
    "ServeOptions",
    "Swap",
    "Switch",
    "add_serve_options",
    "default_ladder",
    "execute_dispatch",
    "ladder_from_counts",
    "make_dispatch_plan",
    "mcma_dispatch",
    "plan_invoke_stats",
)

SERVE_OPTIONS_FIELDS = (
    "batch", "max_len", "eos", "greedy", "seed", "use_mcma_dispatch",
    "mesh", "autotune", "drop_budget", "autotune_kwargs", "route_scope",
    "qos_tiers", "qos_app", "qos_margin_scale", "prefill_chunk",
    "admission", "overflow", "aging", "kv_page_size", "kv_pages",
    "backend", "library",
)

LIBRARY_SPEC_FIELDS = (
    "library_size", "n_resident", "promote_margin", "demote_margin",
    "observe_window", "cooldown", "ema", "start",
)

INVOKE_STATS_FIELDS = (
    "class_counts", "dispatched", "dropped", "exact_frac", "invocation",
    "executed_rows", "padding_rows", "tier_counts", "tier_dispatched",
    "tier_dropped", "tier_served_invocation", "lib_counts",
    "off_set_exact_rows",
)

DRAIN_STATS_FIELDS = (
    "ticks", "wall_s", "undrained_queued", "undrained_inflight",
    "prefill_ticks", "prefill_tokens", "invocation_rate",
    "prefill_invocation_rate", "dropped_rows", "routed_per_class",
    "dispatched_per_class", "dropped_frac", "served_invocation_rate",
    "per_tier", "autotune", "lib_routed_per_class", "off_set_exact_rows",
    "residency", "pages_in_use", "page_hwm", "alloc_failures",
    "page_util", "kv_bytes_resident", "extras",
)


def _fields(cls):
    return tuple(f.name for f in dataclasses.fields(cls))


def test_runtime_all_snapshot():
    assert tuple(rt.__all__) == RUNTIME_ALL
    for name in rt.__all__:
        assert getattr(rt, name, None) is not None, name


def test_serve_options_field_snapshot():
    assert _fields(rt.ServeOptions) == SERVE_OPTIONS_FIELDS
    assert _fields(rt.LibrarySpec) == LIBRARY_SPEC_FIELDS


def test_stats_field_snapshots():
    assert _fields(rt.InvokeStats) == INVOKE_STATS_FIELDS
    assert _fields(rt.DrainStats) == DRAIN_STATS_FIELDS


def test_value_objects_are_frozen():
    for cls in (rt.ServeOptions, rt.LibrarySpec, rt.InvokeStats):
        assert cls.__dataclass_params__.frozen, cls.__name__


def test_canonical_constructor_shape():
    """The documented deployment spelling type-checks end to end."""
    o = rt.ServeOptions(batch=8, use_mcma_dispatch=True,
                        library=rt.LibrarySpec(library_size=16,
                                               n_resident=4))
    assert o.library.initial_residency() == (0, 1, 2, 3)
    import inspect
    sig = inspect.signature(rt.DecodeServer.__init__)
    assert "options" in sig.parameters
    assert sig.parameters["options"].kind is inspect.Parameter.KEYWORD_ONLY
