"""Per-architecture smoke tests on REDUCED configs (same family wiring,
tiny dims): one forward + one train-grad step asserting shapes and
finiteness, and prefill->decode vs full-forward consistency.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS, cells, get_config, input_specs, smoke_config
from repro.models import model as M

jax.config.update("jax_platform_name", "cpu")

B, S = 2, 64


def _inputs(cfg, key, b=B, s=S):
    if cfg.input_mode == "embeddings":
        return jax.random.normal(key, (b, s, cfg.d_model), cfg.adtype) * 0.1
    return jax.random.randint(key, (b, s), 0, cfg.vocab)


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_grad_step(arch, rng):
    cfg = smoke_config(get_config(arch))
    params = M.init_model(rng, cfg)
    inputs = _inputs(cfg, jax.random.fold_in(rng, 1))
    labels = jax.random.randint(jax.random.fold_in(rng, 2), (B, S), 0, cfg.vocab)

    logits, _, aux, _ = M.forward(cfg, params, inputs)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: M.lm_loss(cfg, p, inputs, labels), has_aux=True)(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    # a plain SGD step must keep the model finite
    params2 = jax.tree.map(lambda p, g: p - 0.01 * g.astype(p.dtype), params, grads)
    logits2, _, _, _ = M.forward(cfg, params2, inputs)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch, rng):
    """decode(prefill(x[:s]), x[s]) must match forward(x[:s+1])[-1]."""
    import dataclasses
    cfg = smoke_config(get_config(arch))
    if cfg.moe.n_experts:
        # capacity drops depend on how many tokens compete for an expert's
        # slots, which differs by construction between full-forward and
        # single-token decode; give every token a slot for this check.
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts) / cfg.moe.top_k))
    params = M.init_model(rng, cfg)
    # chunked scans need chunk-aligned S; pad the reference to 2*S and read
    # position S — causality makes trailing padding invisible at S.
    full = _inputs(cfg, jax.random.fold_in(rng, 3), B, 2 * S)

    ref_logits, _, _, _ = M.forward(cfg, params, full)
    want = np.asarray(ref_logits[:, S], np.float32)

    prefix = full[:, :S]
    last = full[:, S:][:, :1]
    _, cache, _, _ = M.forward(cfg, params, prefix, collect_cache=True)
    cache = M.pad_cache(cfg, cache, S + 1)
    got_logits, new_cache = M.decode(cfg, params, cache, last, serve=False)
    got = np.asarray(got_logits, np.float32)

    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
    assert (np.asarray(new_cache["pos"]) == S + 1).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_cells(arch):
    """Every assigned cell has well-formed ShapeDtypeStruct inputs."""
    cfg = get_config(arch)
    for sh in cells(arch):
        specs = input_specs(cfg, sh)
        assert "inputs" in specs
        leaves = jax.tree.leaves(specs)
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
        if sh.kind == "decode":
            assert "cache" in specs


def test_long500k_skips_match_design():
    ran = {a for a in ARCH_IDS
           if any(c.name == "long_500k" for c in cells(a))}
    assert ran == {"xlstm-1.3b", "zamba2-2.7b", "mixtral-8x7b"}
