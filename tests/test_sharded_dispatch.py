"""Shard_map-native MCMA dispatch (runtime/dispatch.py): sharded engine vs
the single-device per-shard reference, psum-reduced invoke_stats vs
single-device totals, the manual ApproxFFN serve path through the engine,
and the mesh DecodeServer end to end.

Two flavors per invariant:
  * in-process tests that need >= 8 jax devices — skipped on a plain run,
    exercised by the CI multidevice leg / `make test-multidevice`
    (XLA_FLAGS=--xla_force_host_platform_device_count=8);
  * subprocess tests (the test_sharding.py pattern) that force 8 virtual
    CPU devices themselves, so the shard_map paths run on EVERY pytest
    invocation, not only on the multidevice leg.
"""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"}


def _run(script: str) -> dict:
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=600, env=_ENV)
    assert r.returncode == 0, r.stderr[-2000:]
    return json.loads(r.stdout.split("RESULT")[1])


# ---------------------------------------------------------------------------
# Shared case builder (also used inside the subprocess scripts via repr)
# ---------------------------------------------------------------------------

_CASE = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np

    T, N, D, DH, BLOCK, DEVS = 256, 3, 64, 16, 32, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    x = jax.random.normal(ks[0], (T, D), jnp.float32) * 0.5
    router = jax.random.normal(ks[1], (D, N + 1)) * 0.5
    w1 = jax.random.normal(ks[2], (N, D, DH)) * 0.2
    b1 = jax.random.normal(ks[3], (N, DH)) * 0.1
    w2 = jax.random.normal(ks[4], (N, DH, D)) * 0.2
    b2 = jax.random.normal(ks[5], (N, D)) * 0.1
    wi = jax.random.normal(ks[6], (D, 2 * D)) * 0.1
    wo = jax.random.normal(ks[7], (2 * D, D)) * 0.1
    logits = x @ router
    exact_fn = lambda xb: jnp.dot(jax.nn.silu(jnp.dot(xb, wi)), wo)
    exact_fn_p = lambda ep, xb: jnp.dot(jax.nn.silu(jnp.dot(xb, ep[0])),
                                        ep[1])
    TL = T // DEVS
    EC, IC = TL // 2, max(int(TL * 0.4), 1)
""")


def _sharded_vs_reference(backend: str) -> dict:
    """Runs inside THIS process (needs >= 8 devices) — returns the same
    payload shape as the subprocess variant."""
    from repro.runtime import dispatch as D
    exec_ns: dict = {}
    exec(compile(_CASE, "<case>", "exec"), exec_ns)
    T, N, BLOCK, DEVS, TL = (exec_ns[k] for k in
                             ("T", "N", "BLOCK", "DEVS", "TL"))
    EC, IC = exec_ns["EC"], exec_ns["IC"]
    x, logits = exec_ns["x"], exec_ns["logits"]
    w = (exec_ns["w1"], exec_ns["b1"], exec_ns["w2"], exec_ns["b2"])
    exact_fn, exact_fn_p = exec_ns["exact_fn"], exec_ns["exact_fn_p"]
    wi, wo = exec_ns["wi"], exec_ns["wo"]

    mesh = jax.make_mesh((DEVS,), ("data",))
    y_sh, s_sh = jax.jit(lambda xx, lg: D.mcma_dispatch_sharded(
        mesh, xx, lg, exact_fn_p, (wi, wo), *w, exact_cap=EC, invoke_cap=IC,
        backend=backend, block_t=BLOCK, interpret=True))(x, logits)

    # single-device reference: each shard's rows dispatched independently
    # with the same per-shard capacities, stats summed
    ys, acc = [], None
    for i in range(DEVS):
        yi, si = D.mcma_dispatch(
            x[i * TL:(i + 1) * TL], logits[i * TL:(i + 1) * TL], exact_fn,
            *w, exact_cap=EC, invoke_cap=IC, backend=backend, block_t=BLOCK,
            interpret=True)
        ys.append(np.asarray(yi))
        si = jax.tree.map(np.asarray, si)
        acc = si if acc is None else {
            k: acc[k] + si[k] for k in
            ("class_counts", "dispatched", "dropped", "executed_rows",
             "padding_rows")}
    y_ref = np.concatenate(ys)
    # full-batch single call: routing (class_counts) is row-wise, so the
    # sharded totals must equal the unsharded ones exactly
    _, s_full = D.mcma_dispatch(x, logits, exact_fn, *w, exact_cap=T // 2,
                                invoke_cap=int(T * 0.4), backend="xla")
    return {"y_sh": np.asarray(y_sh), "y_ref": y_ref,
            "s_sh": jax.tree.map(np.asarray, s_sh), "s_ref": acc,
            "full_counts": np.asarray(s_full["class_counts"]), "T": T}


def _assert_sharded_payload(p):
    np.testing.assert_array_equal(p["y_sh"], p["y_ref"])  # bit-for-bit
    for k in ("class_counts", "dispatched", "dropped", "executed_rows",
              "padding_rows"):
        np.testing.assert_array_equal(p["s_sh"][k], p["s_ref"][k])
    # routing stats are global: identical to a full-batch single call
    np.testing.assert_array_equal(p["s_sh"]["class_counts"],
                                  p["full_counts"])
    assert float(p["s_sh"]["invocation"]) == pytest.approx(
        1.0 - p["full_counts"][0] / p["T"], abs=1e-6)
    assert int(p["s_sh"]["class_counts"].sum()) == p["T"]


needs_8_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (CI multidevice leg: XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")


@needs_8_devices
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_sharded_dispatch_matches_single_device_inprocess(backend):
    _assert_sharded_payload(_sharded_vs_reference(backend))


@needs_8_devices
def test_sharded_pallas_bitexact_vs_xla_oracle_inprocess():
    """Acceptance: sharded pallas output == sharded xla oracle bit-for-bit
    (CPU f32, interpret mode), stats identical."""
    px = _sharded_vs_reference("xla")
    pp = _sharded_vs_reference("pallas")
    np.testing.assert_array_equal(pp["y_sh"], px["y_sh"])
    for k in ("class_counts", "dispatched", "dropped"):
        np.testing.assert_array_equal(pp["s_sh"][k], px["s_sh"][k])


# ---------------------------------------------------------------------------
# Subprocess variants: run on every pytest invocation (1-device main proc)
# ---------------------------------------------------------------------------

_SHARDED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.runtime import dispatch as DS
""") + _CASE + textwrap.dedent("""
    mesh = jax.make_mesh((DEVS,), ("data",))
    out = {}
    for backend in ("xla", "pallas"):
        y_sh, s_sh = jax.jit(lambda xx, lg, be=backend:
            DS.mcma_dispatch_sharded(
                mesh, xx, lg, exact_fn_p, (wi, wo), w1, b1, w2, b2,
                exact_cap=EC, invoke_cap=IC, backend=be, block_t=BLOCK,
                interpret=True))(x, logits)
        ys, counts, disp, dropped = [], 0, 0, 0
        for i in range(DEVS):
            yi, si = DS.mcma_dispatch(
                x[i*TL:(i+1)*TL], logits[i*TL:(i+1)*TL], exact_fn,
                w1, b1, w2, b2, exact_cap=EC, invoke_cap=IC,
                backend=backend, block_t=BLOCK, interpret=True)
            ys.append(np.asarray(yi))
            counts = counts + np.asarray(si["class_counts"])
            disp = disp + np.asarray(si["dispatched"])
            dropped = dropped + int(si["dropped"])
        out[backend] = {
            "bitexact_vs_ref": bool(np.array_equal(np.asarray(y_sh),
                                                   np.concatenate(ys))),
            "counts_match": bool(np.array_equal(
                np.asarray(s_sh["class_counts"]), counts)),
            "disp_match": bool(np.array_equal(
                np.asarray(s_sh["dispatched"]), disp)),
            "dropped_match": int(s_sh["dropped"]) == dropped,
            "counts_sum": int(np.asarray(s_sh["class_counts"]).sum()),
            "invocation": float(s_sh["invocation"]),
            "y": np.asarray(y_sh).tolist(),
        }
    out["pallas_bitexact_vs_xla"] = bool(np.array_equal(
        np.asarray(out["pallas"]["y"]), np.asarray(out["xla"]["y"])))
    for be in ("xla", "pallas"):
        del out[be]["y"]
    # full-batch routing reference
    _, s_full = DS.mcma_dispatch(x, logits, exact_fn, w1, b1, w2, b2,
                                exact_cap=T // 2, invoke_cap=int(T * 0.4),
                                backend="xla")
    out["full_invocation"] = float(s_full["invocation"])
    print("RESULT" + json.dumps(out))
""")


def test_sharded_dispatch_subprocess_8_virtual_devices():
    out = _run(_SHARDED)
    for be in ("xla", "pallas"):
        assert out[be]["bitexact_vs_ref"], be
        assert out[be]["counts_match"], be
        assert out[be]["disp_match"], be
        assert out[be]["dropped_match"], be
        assert out[be]["counts_sum"] == 256
    assert out["pallas_bitexact_vs_xla"]
    assert out["xla"]["invocation"] == pytest.approx(
        out["full_invocation"], abs=1e-6)


_APPROX_MANUAL = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.registry import get_config, smoke_config
    from repro.models.approx_ffn import approx_ffn_fwd, init_approx_ffn
    from repro.sharding import activations as A

    def cfg_with(backend):
        # full capacities: per-shard ranking and global ranking then keep
        # exactly the same rows, so the mesh output must equal the
        # single-device output (up to the TP psum's fp reassociation)
        cfg = smoke_config(get_config("internlm2-1.8b"))
        return dataclasses.replace(cfg, approx=dataclasses.replace(
            cfg.approx, enable=True, backend=backend, interpret=True,
            block_t=16, exact_frac=1.0, invoke_frac=1.0))

    cfg = cfg_with("xla")
    p = init_approx_ffn(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 16, cfg.d_model),
                          jnp.float32) * 0.5

    # single-device engine reference (same per-shard capacities emerge
    # because routing is identical; generous caps avoid drop divergence)
    y1, a1 = approx_ffn_fwd(cfg, p, x, serve=True)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    xs = jax.device_put(x, NamedSharding(mesh, P(("data",), None, None)))
    out = {}
    ys = {}
    for backend in ("xla", "pallas"):
        c = cfg_with(backend)
        with mesh, A.activation_sharding(P(("data",), None, None)):
            y, a = jax.jit(lambda p_, x_, c_=c: approx_ffn_fwd(
                c_, p_, x_, serve=True))(p, xs)
        st = jax.tree.map(np.asarray, a["invoke_stats"])
        ys[backend] = np.asarray(y)
        out[backend] = {
            "counts": st["class_counts"].tolist(),
            "counts_sum": int(st["class_counts"].sum()),
            "invocation": float(a["invocation"]),
            "max_diff_vs_single": float(np.abs(np.asarray(y)
                                               - np.asarray(y1)).max()),
        }
    out["pallas_bitexact_vs_xla"] = bool(np.array_equal(ys["pallas"],
                                                        ys["xla"]))
    out["single_counts"] = np.asarray(
        a1["invoke_stats"]["class_counts"]).tolist()
    out["single_invocation"] = float(a1["invocation"])
    print("RESULT" + json.dumps(out))
""")


def test_approx_ffn_manual_serve_through_engine():
    """The distributed ApproxFFN serve path runs the SAME mcma_dispatch
    engine under shard_map: routing stats equal the single-device run
    exactly, pallas == xla bit-for-bit on the mesh, and the TP exact path
    matches single-device to fp tolerance (psum reorders the d_ff sum)."""
    out = _run(_APPROX_MANUAL)
    assert out["pallas_bitexact_vs_xla"]
    for be in ("xla", "pallas"):
        assert out[be]["counts"] == out["single_counts"], out
        assert out[be]["counts_sum"] == 8 * 16
        assert out[be]["invocation"] == pytest.approx(
            out["single_invocation"], abs=1e-6)
        assert out[be]["max_diff_vs_single"] < 1e-4, out
    print("RESULT ok")


_SERVER_MESH = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, numpy as np
    from repro.configs.registry import get_config, smoke_config
    from repro.launch.mesh import make_host_mesh
    from repro.models import model as M
    from repro.runtime.server import DecodeServer, Request

    cfg = smoke_config(get_config("internlm2-1.8b"))
    cfg = dataclasses.replace(cfg, approx=dataclasses.replace(
        cfg.approx, enable=True))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(1, 9, dtype=np.int32)

    outs = []
    for mesh in (None, make_host_mesh(data=4, model=2)):
        srv = DecodeServer(cfg, params, batch=4, max_len=64,
                           use_mcma_dispatch=True, mesh=mesh)
        r = Request(rid=0, prompt=prompt, max_new=6)
        srv.submit(r)
        stats = srv.run_until_drained(200)
        outs.append({"out": r.out, "rate": stats["invocation_rate"],
                     "done": r.done})
    print("RESULT" + json.dumps({"single": outs[0], "mesh": outs[1]}))
""")


def test_decode_server_mesh_matches_single_device_tokens():
    """A DecodeServer on a (4, 2) mesh of 8 virtual devices must emit the
    same greedy tokens as the single-device server and report a sane
    psum-reduced invocation rate."""
    out = _run(_SERVER_MESH)
    assert out["mesh"]["done"] and out["single"]["done"]
    assert out["mesh"]["out"] == out["single"]["out"], out
    assert 0.0 <= out["mesh"]["rate"] <= 1.0


# ---------------------------------------------------------------------------
# Spec helpers (no devices needed)
# ---------------------------------------------------------------------------

class FakeMesh:
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape)


def test_dispatch_specs_shapes():
    from jax.sharding import PartitionSpec as P
    from repro.sharding import rules as R
    mesh = FakeMesh((8,), ("data",))
    specs = R.mcma_dispatch_specs(mesh)
    assert len(specs["in"]) == 7 and len(specs["out"]) == 2
    assert specs["in"][0] == P(("data",), None)
    assert specs["out"][1] == P()
    # multi-pod: rows shard over the DP meta-axis
    mesh3 = FakeMesh((2, 4, 2), ("pod", "data", "model"))
    assert R.mcma_dispatch_specs(mesh3)["in"][0] == P(("pod", "data"), None)
    a = R.approx_serve_specs(mesh3, gated=True)
    assert a["in"][0]["ffn"]["w_gate"] == P(("pod", "data"), "model")
    m = R.moe_manual_specs(mesh3, gated=False)
    assert "w_gate" not in m["in"][0]
    assert m["in"][0]["w_in"] == P("model", ("pod", "data"), None)


def test_capacity_slot_helpers_roundtrip():
    """The shared grouped-dispatch primitives: sort -> slots -> scatter ->
    gather must reproduce per-class arrival order with drops zeroed."""
    from repro.runtime import dispatch as D
    cls = jnp.asarray([2, 0, 1, 0, 2, 2, 0, 1], jnp.int32)
    xs = jnp.arange(8, dtype=jnp.float32)[:, None] + 1.0
    order, cls_sorted, rank, counts = D.class_sort_ranks(cls, 3)
    np.testing.assert_array_equal(np.asarray(counts), [3, 2, 3])
    keep, slot = D.capacity_slots(cls_sorted, rank, 2, n_local=3)
    buf = D.scatter_rows(xs[order], slot, keep, 6)
    # class-major, arrival order, capacity 2: rows 1,3 | 2,7 | 0,4
    np.testing.assert_array_equal(np.asarray(buf[:, 0]),
                                  [2, 4, 3, 8, 1, 5])
    y = D.gather_rows(buf, slot, keep)
    got = np.zeros(8, np.float32)
    got[np.asarray(order)] = np.asarray(y[:, 0])
    # rows 5 (class 2) and 6 (class 0) are rank 2 >= cap -> dropped
    want = np.asarray([1, 2, 3, 4, 5, 0, 0, 8], np.float32)
    np.testing.assert_array_equal(got, want)
