"""Hypothesis compatibility shim for the property tests.

When ``hypothesis`` is installed this module re-exports the real
``given`` / ``settings`` / ``st`` and the tests run as true property
tests.  When it is absent (minimal CI images), the same decorators
degrade to deterministic example-based tests: each strategy draws from a
seeded ``random.Random`` (seeded by the test name via crc32, so runs are
reproducible and independent of ``PYTHONHASHSEED``) and the test body
runs over a fixed number of drawn examples.  No shrinking, no database —
a failing draw is reported with the drawn values so it can be pinned as
a regular parametrized case.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import random
    import zlib

    # Cap on drawn examples in fallback mode: the point is smoke coverage
    # of the invariant, not exploration (real hypothesis does that), and
    # every distinct shape costs a jit trace.
    _FALLBACK_MAX = 8

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _StrategiesModule:
        """The subset of ``hypothesis.strategies`` the test-suite uses."""

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elems = list(elements)
            return _Strategy(lambda r: r.choice(elems))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.getrandbits(1)))

    st = _StrategiesModule()

    def settings(max_examples=None, deadline=None, **_kw):
        def deco(fn):
            if max_examples is not None:
                fn._hc_max_examples = max_examples
            return fn
        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = min(getattr(wrapper, "_hc_max_examples", _FALLBACK_MAX),
                        _FALLBACK_MAX)
                rng = random.Random(zlib.crc32(fn.__name__.encode()))
                for i in range(n):
                    drawn = tuple(s.example(rng) for s in arg_strategies)
                    kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                    try:
                        fn(*args, *drawn, **kwargs, **kw)
                    except Exception as e:  # noqa: BLE001 - re-raised
                        raise AssertionError(
                            f"example-based fallback failed on draw {i}: "
                            f"args={drawn} kwargs={kw}") from e
            # NOTE: no functools.wraps — ``__wrapped__`` would make pytest
            # introspect the inner signature and demand fixtures for the
            # drawn arguments.
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
