"""Serving scheduler: chunked prefill + cost-model admission, and the
prompt-overflow bugfix family.

Pins, per the PR's acceptance criteria:
  * submit() rejects empty prompts and prompts that cannot fit
    ``max_len`` next to their ``max_new`` budget (or trims the prompt's
    head under ``overflow="trim"``) — no clamped cache writes, no wedged
    slot;
  * a request injected past submit() validation (straight into the
    queue) is ABORTED by the tick loop before any out-of-range KV write,
    and the slot is freed — the pre-PR behavior was an infinite
    prompt-feeding loop with silent KV corruption at the last cache
    position;
  * ``run_until_drained(max_ticks)`` exhaustion reports
    ``undrained_queued``/``undrained_inflight`` and marks stranded
    requests aborted instead of returning quietly;
  * chunked prefill produces greedy tokens BIT-IDENTICAL to
    token-by-token serving, and identical decode-phase invoke stats, on
    1 device and on the 8-virtual-device mesh (the servers run at a
    no-clip operating point: capacity contention is batch-mix-dependent
    by design, so the equality contract holds when prefill capacity
    never binds — docs/serving.md);
  * a bursty mixed-length mixed-tier arrival replay drains cleanly with
    the per-tier QoS ledger intact;
  * cost-model admission orders the queue by prompt length x tier
    multiplier and ages starved requests to the front.
"""
import dataclasses
import json
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config, smoke_config
from repro.models import model as M
from repro.runtime.server import DecodeServer, Request

jax.config.update("jax_platform_name", "cpu")

_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu",
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}


def _cfg(**over):
    cfg = smoke_config(get_config("internlm2-1.8b"))
    return dataclasses.replace(cfg, approx=dataclasses.replace(
        cfg.approx, enable=True, **over))


_PARAMS = {}


def _params(cfg):
    key = (cfg.approx.exact_frac, cfg.approx.invoke_frac)
    if key not in _PARAMS:
        _PARAMS[key] = M.init_model(jax.random.PRNGKey(0), cfg)
    return _PARAMS[key]


# ---------------------------------------------------------------------------
# submit-time validation (the overflow / empty-prompt bugfixes)
# ---------------------------------------------------------------------------

def test_submit_rejects_empty_prompt():
    cfg = _cfg()
    srv = DecodeServer(cfg, _params(cfg), batch=1, max_len=32)
    with pytest.raises(ValueError, match="empty prompt"):
        srv.submit(Request(rid=0, prompt=np.zeros((0,), np.int32)))
    assert not srv.queue


def test_submit_rejects_prompt_overflow():
    """A prompt of length max_len + k (and anything whose prompt+max_new
    cannot fit) is rejected at submit — the regression the pre-chunking
    loop turned into silent KV corruption plus a wedged slot."""
    cfg = _cfg()
    srv = DecodeServer(cfg, _params(cfg), batch=1, max_len=32)
    for plen in (33, 40, 30):      # max_len + 1, + 8, and 30 + max_new > 32
        with pytest.raises(ValueError, match="exceeds max_len"):
            srv.submit(Request(rid=0, prompt=np.ones(plen, np.int32),
                               max_new=4))
    with pytest.raises(ValueError, match="max_new"):
        srv.submit(Request(rid=1, prompt=np.ones(3, np.int32), max_new=0))
    assert not srv.queue
    # the boundary case fits exactly and is served
    r = Request(rid=2, prompt=np.ones(28, np.int32), max_new=4)
    srv.submit(r)
    stats = srv.run_until_drained(200)
    assert r.done and not r.aborted and len(r.out) == 4
    assert stats["undrained_queued"] == stats["undrained_inflight"] == 0


def test_submit_trim_policy_keeps_prompt_tail():
    cfg = _cfg()
    srv = DecodeServer(cfg, _params(cfg), batch=1, max_len=32,
                       overflow="trim")
    prompt = np.arange(1, 41, dtype=np.int32)          # 40 > 32 - 4
    r = Request(rid=0, prompt=prompt.copy(), max_new=4)
    srv.submit(r)
    assert r.prompt.size == 28                          # max_len - max_new
    assert (r.prompt == prompt[-28:]).all()             # the TAIL survives
    srv.run_until_drained(200)
    assert r.done and not r.aborted and len(r.out) == 4


def test_wedge_guard_aborts_queue_injected_overflow():
    """Bypassing submit() must not wedge the slot table: the tick loop
    aborts the unservable request BEFORE any clamped cache write, frees
    the slot, and keeps serving.  Pre-PR this looped forever (the
    max_len check sat below the prompt-feeding continue)."""
    cfg = _cfg()
    srv = DecodeServer(cfg, _params(cfg), batch=1, max_len=32)
    bad = Request(rid=0, prompt=np.ones(40, np.int32), max_new=4)
    good = Request(rid=1, prompt=np.ones(5, np.int32), max_new=4)
    srv.queue.append(bad)                # straight past validation
    srv.submit(good)
    stats = srv.run_until_drained(200)
    assert bad.aborted and bad.done and not bad.out
    assert good.done and not good.aborted and len(good.out) == 4
    assert stats["ticks"] < 200          # no infinite prompt-feeding loop
    assert stats["undrained_queued"] == stats["undrained_inflight"] == 0


def test_run_until_drained_reports_stranded_requests():
    """max_ticks exhaustion is not a quiet success: stranded requests are
    counted in the stats and marked aborted (done stays False)."""
    cfg = _cfg()
    srv = DecodeServer(cfg, _params(cfg), batch=1, max_len=64)
    reqs = [Request(rid=i, prompt=np.ones(8, np.int32), max_new=8)
            for i in range(4)]
    for r in reqs:
        srv.submit(r)
    stats = srv.run_until_drained(max_ticks=10)   # enough for ~0 requests
    assert stats["undrained_queued"] + stats["undrained_inflight"] >= 2
    stranded = [r for r in reqs if not r.done]
    assert stranded and all(r.aborted for r in stranded)
    done = [r for r in reqs if r.done]
    assert all(not r.aborted for r in done)


# ---------------------------------------------------------------------------
# chunked prefill == token-by-token, bit for bit
# ---------------------------------------------------------------------------

def _mixed_requests(vocab, seed=0):
    rng = np.random.default_rng(seed)
    lens = (40, 5, 23, 9, 3)
    return [Request(rid=i, prompt=rng.integers(1, vocab, n).astype(np.int32),
                    max_new=5, tier=int(rng.integers(0, 3)))
            for i, n in enumerate(lens)]


def _serve(cfg, *, prefill_chunk, admission, reqs, mesh=None):
    srv = DecodeServer(cfg, _params(cfg), batch=2, max_len=64,
                       use_mcma_dispatch=True, route_scope="tick",
                       qos_tiers=(0.05, 0.10, 0.20), mesh=mesh,
                       prefill_chunk=prefill_chunk, admission=admission)
    for r in reqs:
        srv.submit(r)
    stats = srv.run_until_drained(1000)
    return srv, stats


def test_chunked_prefill_bitexact_tokens_and_decode_stats():
    """Same request stream, token-by-token vs chunked: identical greedy
    tokens per request AND identical decode-phase invoke stats (the
    chunked run's decode ticks replay the token run's sampling ticks
    exactly; prefill-chunk stats live in their own accumulators)."""
    # no-clip operating point: the bit-exactness contract's precondition
    cfg = _cfg(exact_frac=1.0, invoke_frac=1.0)
    a = _mixed_requests(cfg.vocab)
    b = _mixed_requests(cfg.vocab)
    srv_t, st_t = _serve(cfg, prefill_chunk=0, admission="fifo", reqs=a)
    srv_c, st_c = _serve(cfg, prefill_chunk=8, admission="fifo", reqs=b)
    assert all(r.done for r in a + b)
    for ra, rb in zip(a, b):
        assert ra.out == rb.out, (ra.rid, ra.out, rb.out)
    assert st_c["prefill_ticks"] > 0
    assert st_c["ticks"] < st_t["ticks"]      # chunking saves whole ticks
    # single-request decode-phase stat equality: batch=1 keeps tick rows
    # aligned, so the chunked run's decode-tick invocation sequence must
    # equal the tail of the token run's (from the first sampling tick on)
    cfg1 = cfg
    prompt = np.arange(1, 34, dtype=np.int32)
    outs, logs = [], []
    for chunk in (0, 8):
        srv = DecodeServer(cfg1, _params(cfg1), batch=1, max_len=64,
                           use_mcma_dispatch=True, route_scope="tick",
                           prefill_chunk=chunk)
        r = Request(rid=0, prompt=prompt.copy(), max_new=6)
        srv.submit(r)
        srv.run_until_drained(200)
        outs.append(r.out)
        logs.append(srv.tick_log)
    assert outs[0] == outs[1]
    dec_token = [inv for ph, _, inv in logs[0] if ph == "decode"]
    dec_chunk = [inv for ph, _, inv in logs[1] if ph == "decode"]
    # token mode: P-1 prompt-feeding ticks + 6 sampling ticks, all
    # "decode"; chunk mode: only the 6 sampling ticks are "decode"
    assert len(dec_chunk) == 6
    assert dec_token[-6:] == dec_chunk, (dec_token[-6:], dec_chunk)


needs_8_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8); covered by the CI multidevice leg")


_MESH_SCRIPT = textwrap.dedent("""
    import dataclasses, json
    import numpy as np
    import jax
    jax.config.update("jax_platform_name", "cpu")
    from repro.configs.registry import get_config, smoke_config
    from repro.launch.mesh import make_host_mesh
    from repro.models import model as M
    from repro.runtime.server import DecodeServer, Request

    cfg = smoke_config(get_config("internlm2-1.8b"))
    cfg = dataclasses.replace(cfg, approx=dataclasses.replace(
        cfg.approx, enable=True, exact_frac=1.0, invoke_frac=1.0))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    mesh = make_host_mesh(data=2, model=4)
    rng = np.random.default_rng(0)
    out = {}
    for chunk in (0, 8):
        reqs = [Request(rid=i,
                        prompt=rng1.integers(1, cfg.vocab, n)
                        .astype(np.int32), max_new=4, tier=i % 3)
                for rng1 in [np.random.default_rng(0)]
                for i, n in enumerate((25, 4, 17))]
        srv = DecodeServer(cfg, params, batch=2, max_len=64,
                           use_mcma_dispatch=True, route_scope="tick",
                           qos_tiers=(0.05, 0.10, 0.20), mesh=mesh,
                           prefill_chunk=chunk, admission="fifo")
        for r in reqs:
            srv.submit(r)
        stats = srv.run_until_drained(500)
        out[str(chunk)] = {
            "tokens": {r.rid: r.out for r in reqs},
            "done": all(r.done for r in reqs),
            "prefill_ticks": stats["prefill_ticks"],
        }
    print("RESULT" + json.dumps(out))
""")


@needs_8_devices
def test_chunked_prefill_bitexact_on_mesh_inprocess():
    """CI multidevice leg: the same equality through the shard_map-native
    serve path (params/cache sharded, chunk + decode steps under the
    mesh, psum'd invoke stats)."""
    from repro.launch.mesh import make_host_mesh
    cfg = _cfg(exact_frac=1.0, invoke_frac=1.0)
    mesh = make_host_mesh(data=2, model=4)
    a = _mixed_requests(cfg.vocab)
    b = _mixed_requests(cfg.vocab)
    _, st_t = _serve(cfg, prefill_chunk=0, admission="fifo", reqs=a,
                     mesh=mesh)
    _, st_c = _serve(cfg, prefill_chunk=8, admission="fifo", reqs=b,
                     mesh=mesh)
    assert all(r.done for r in a + b)
    for ra, rb in zip(a, b):
        assert ra.out == rb.out, (ra.rid, ra.out, rb.out)
    assert st_c["prefill_ticks"] > 0


def test_chunked_prefill_bitexact_on_mesh_subprocess():
    """Same mesh equality via subprocess (8 forced virtual devices), so
    the single-device tier-1 run still covers the mesh path."""
    r = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                       capture_output=True, text=True, timeout=900,
                       env=_ENV)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.split("RESULT")[1])
    assert out["0"]["done"] and out["8"]["done"]
    assert out["0"]["tokens"] == out["8"]["tokens"]
    assert out["8"]["prefill_ticks"] > 0
    assert out["0"]["prefill_ticks"] == 0


# ---------------------------------------------------------------------------
# bursty mixed-tier e2e + admission cost model
# ---------------------------------------------------------------------------

def test_bursty_mixed_tier_replay_drains():
    from benchmarks.bench_serve import gen_stream, replay
    cfg = _cfg()
    srv = DecodeServer(cfg, _params(cfg), batch=2, max_len=160,
                       use_mcma_dispatch=True, route_scope="tick",
                       qos_tiers=(0.05, 0.10, 0.20),
                       prefill_chunk=16, admission="cost")
    stream = gen_stream("bursty", 0.25, 8, cfg.vocab, n_tiers=3)
    assert any(len(a.prompt) >= 64 for a in stream)    # mixed lengths
    reqs, stats = replay(srv, stream)
    assert all(r.done and not r.aborted for r in reqs)
    assert stats["undrained_queued"] == stats["undrained_inflight"] == 0
    assert stats["prefill_ticks"] > 0
    assert "per_tier" in stats                          # QoS ledger intact
    assert sum(p["rows"] for p in stats["per_tier"]) > 0


def test_admission_cost_orders_queue():
    """Cost admission: shorter prompts first; a tighter tier is more
    expensive (x1.5 at the tightest); aging eventually promotes a
    starved request over fresher cheaper ones."""
    cfg = _cfg()
    srv = DecodeServer(cfg, _params(cfg), batch=1, max_len=64,
                       use_mcma_dispatch=True,
                       qos_tiers=(0.05, 0.10, 0.20), aging=0.05)
    long_loose = Request(rid=0, prompt=np.ones(40, np.int32), tier=2,
                         max_new=2)
    short_tight = Request(rid=1, prompt=np.ones(10, np.int32), tier=0,
                          max_new=2)
    short_loose = Request(rid=2, prompt=np.ones(10, np.int32), tier=2,
                          max_new=2)
    for r in (long_loose, short_tight, short_loose):
        srv.submit(r)
    # same length: the loose tier is cheaper than the tight one; both
    # beat the long prompt
    costs = {r.rid: srv._admission_cost(r)
             for r in (long_loose, short_tight, short_loose)}
    assert costs[2] < costs[1] < costs[0]
    # aging: a starved request eventually beats a FRESH cheaper one (it
    # cannot beat its own cohort — equal ages cancel)
    srv.ticks = int(1 + (costs[0] - costs[2]) / srv.aging)
    fresh = Request(rid=3, prompt=np.ones(10, np.int32), tier=2, max_new=2)
    srv.submit(fresh)                       # arrival_tick = srv.ticks
    assert srv._admission_cost(long_loose) < srv._admission_cost(fresh)
    srv.queue.remove(fresh)
    # _admit honors the ordering (slot 0 takes the cheapest: rid 2)
    srv.ticks = 0
    srv._admit()
    assert srv.slots[0] is long_loose or srv.slots[0].rid == 2
    assert srv.slots[0].rid == 2


def test_fifo_admission_preserved():
    cfg = _cfg()
    srv = DecodeServer(cfg, _params(cfg), batch=1, max_len=64,
                       admission="fifo")
    a = Request(rid=0, prompt=np.ones(30, np.int32), max_new=2)
    b = Request(rid=1, prompt=np.ones(3, np.int32), max_new=2)
    srv.submit(a)
    srv.submit(b)
    srv._admit()
    assert srv.slots[0].rid == 0
