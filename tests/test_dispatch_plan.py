"""Plan/execute dispatch architecture (runtime/dispatch.py) and tick-level
routing (ApproxConfig.route_scope="tick").

Pins, per the PR's acceptance criteria:
  * make_dispatch_plan + execute_dispatch == the one-shot mcma_dispatch
    bit-for-bit on CPU f32 — both backends, with and without row_mask;
  * one plan reused across L layers' weights == L independent per-layer
    dispatches when the per-layer logits are identical (plan reuse is a
    pure refactor of the compute, not a semantics change);
  * tick-scope decode: pallas == xla oracle on 1 device and on the
    8-virtual-device (data, model) mesh (subprocess + in-process CI-leg
    variants), with the plan built and consumed inside the same sharding;
  * the grad-accum metrics fix and the hybrid decode metrics fix (the two
    satellite bugs), and the tick-router head's co-training signal.
"""
import dataclasses
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, smoke_config
from repro.models import model as M
from repro.runtime import dispatch as D
from repro.runtime import steps as S

jax.config.update("jax_platform_name", "cpu")

_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"}


def _run(script: str) -> dict:
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=600, env=_ENV)
    assert r.returncode == 0, r.stderr[-2000:]
    return json.loads(r.stdout.split("RESULT")[1])


def _mk_case(key, t, n, d, d_h):
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (t, d), jnp.float32) * 0.5
    router = jax.random.normal(ks[1], (d, n + 1)) * 0.5
    w1 = jax.random.normal(ks[2], (n, d, d_h)) * 0.2
    b1 = jax.random.normal(ks[3], (n, d_h)) * 0.1
    w2 = jax.random.normal(ks[4], (n, d_h, d)) * 0.2
    b2 = jax.random.normal(ks[5], (n, d)) * 0.1
    wi = jax.random.normal(jax.random.fold_in(key, 7), (d, 2 * d)) * 0.1
    wo = jax.random.normal(jax.random.fold_in(key, 8), (2 * d, d)) * 0.1
    exact_fn = lambda xb: jnp.dot(jax.nn.silu(jnp.dot(xb, wi)), wo)
    return x, x @ router, (w1, b1, w2, b2), exact_fn


def _approx_cfg(**over):
    cfg = smoke_config(get_config("internlm2-1.8b"))
    return dataclasses.replace(cfg, approx=dataclasses.replace(
        cfg.approx, enable=True, **over))


# ---------------------------------------------------------------------------
# plan + execute == the one-shot engine, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("with_mask", [False, True])
def test_plan_execute_matches_mcma_dispatch(backend, with_mask):
    t, n, d, d_h, block = 96, 3, 48, 16, 32
    key = jax.random.PRNGKey(11)
    x, logits, w, exact_fn = _mk_case(key, t, n, d, d_h)
    rm = (jnp.arange(t) % 5 != 0) if with_mask else None
    caps = dict(exact_cap=t // 2, invoke_cap=max(int(t * 0.3), 1))
    kw = dict(backend=backend, block_t=block)
    interp = backend == "pallas"

    plan = D.make_dispatch_plan(logits, rm, **caps, **kw)
    y = D.execute_dispatch(plan, x, exact_fn, *w, interpret=interp)
    y_ref, s_ref = D.mcma_dispatch(x, logits, exact_fn, *w, row_mask=rm,
                                   interpret=interp, **caps, **kw)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))
    s = D.plan_invoke_stats(plan)
    assert set(s) == set(s_ref)
    for k in s:
        np.testing.assert_array_equal(np.asarray(s[k]), np.asarray(s_ref[k]))


def test_plan_from_operating_point_matches_explicit_caps():
    from repro.runtime.autotune import OperatingPoint
    from repro.sharding.rules import shard_capacity
    t, n = 80, 2
    x, logits, w, exact_fn = _mk_case(jax.random.PRNGKey(3), t, n, 32, 8)
    pt = OperatingPoint(0.5, 0.3)
    p1 = D.make_dispatch_plan(logits, operating_point=pt)
    p2 = D.make_dispatch_plan(
        logits, exact_cap=shard_capacity(t, 0.5),
        invoke_cap=shard_capacity(t, 0.3))
    assert (p1.exact_cap, p1.invoke_cap) == (p2.exact_cap, p2.invoke_cap)
    np.testing.assert_array_equal(np.asarray(p1.cls), np.asarray(p2.cls))


def test_plan_is_a_pytree_and_jit_stable():
    """A DispatchPlan must flow through jit boundaries (the decode step
    builds it inside the jitted tick) with its static meta intact."""
    t, n = 64, 2
    _, logits, _, _ = _mk_case(jax.random.PRNGKey(5), t, n, 32, 8)
    f = jax.jit(lambda lg: D.make_dispatch_plan(lg, exact_cap=32,
                                                invoke_cap=16))
    plan = f(logits)
    assert plan.n_approx == n and plan.exact_cap == 32
    leaves = jax.tree_util.tree_leaves(plan)
    assert len(leaves) == len(D._PLAN_DATA)
    again = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(plan), leaves)
    assert again.backend == plan.backend


# ---------------------------------------------------------------------------
# plan reuse across layers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_plan_reused_across_layers_matches_per_layer_dispatch(backend):
    """When every layer's logits agree, ONE plan executed against L
    different weight sets must equal L independent per-layer dispatches —
    the semantic guarantee that makes tick-scope hoisting sound."""
    t, n, d, d_h, block, L = 64, 2, 32, 8, 16, 4
    key = jax.random.PRNGKey(29)
    x, logits, _, _ = _mk_case(key, t, n, d, d_h)
    layers = [_mk_case(jax.random.fold_in(key, i + 1), t, n, d, d_h)[2:]
              for i in range(L)]
    caps = dict(exact_cap=t // 2, invoke_cap=max(int(t * 0.4), 1))
    interp = backend == "pallas"

    plan = D.make_dispatch_plan(logits, backend=backend, block_t=block,
                                **caps)
    for w, exact_fn in layers:
        y_plan = D.execute_dispatch(plan, x, exact_fn, *w, interpret=interp)
        y_ref, _ = D.mcma_dispatch(x, logits, exact_fn, *w, backend=backend,
                                   block_t=block, interpret=interp, **caps)
        np.testing.assert_array_equal(np.asarray(y_plan), np.asarray(y_ref))


# ---------------------------------------------------------------------------
# tick-scope decode: one plan above the layer scan
# ---------------------------------------------------------------------------

def test_tick_decode_pallas_matches_xla_oracle():
    cfg = _approx_cfg()
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    assert "tick_router" in params
    b = 4
    cache = M.init_cache(cfg, b, 32)
    toks = jnp.arange(1, b + 1, dtype=jnp.int32)[:, None]
    mask = jnp.asarray([True, True, False, True])
    outs, stats = {}, {}
    for be, kw in (("xla", {}),
                   ("pallas", dict(interpret=True, block_t=16))):
        c = _approx_cfg(backend=be, route_scope="tick", **kw)
        lg, _, m = M.decode(c, params, cache, toks, serve=True,
                            collect_metrics=True, row_mask=mask)
        outs[be], stats[be] = np.asarray(lg), jax.tree.map(np.asarray, m)
    np.testing.assert_array_equal(outs["pallas"], outs["xla"])
    np.testing.assert_array_equal(stats["pallas"]["class_counts"],
                                  stats["xla"]["class_counts"])
    # the plan embeds the row mask: only the 3 active rows are routed
    assert int(stats["xla"]["class_counts"].sum()) == 3


def test_tick_decode_metrics_are_the_plan_stats():
    """Every layer executes the SAME plan, so the layer-meaned metrics the
    step reports must equal the plan's tick-level stats exactly — one
    observation per tick for the autotuner, not L noisy ones."""
    cfg = _approx_cfg(route_scope="tick")
    params = M.init_model(jax.random.PRNGKey(1), cfg)
    b = 4
    cache = M.init_cache(cfg, b, 32)
    toks = jnp.arange(1, b + 1, dtype=jnp.int32)[:, None]
    from repro.models.approx_ffn import make_tick_plan
    x = M.L.embed_fwd(cfg, params["embed"], toks)
    plan = make_tick_plan(cfg, params, x)
    want = jax.tree.map(np.asarray, D.plan_invoke_stats(plan))
    _, _, m = M.decode(cfg, params, cache, toks, serve=True,
                       collect_metrics=True)
    np.testing.assert_array_equal(np.asarray(m["class_counts"]),
                                  want["class_counts"])
    np.testing.assert_array_equal(np.asarray(m["dispatched"]),
                                  want["dispatched"])
    assert float(m["invocation"]) == pytest.approx(
        float(want["invocation"]), abs=1e-7)


def test_decode_server_tick_scope_end_to_end():
    from repro.runtime.server import DecodeServer, Request
    cfg = _approx_cfg()
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    server = DecodeServer(cfg, params, batch=2, max_len=64,
                          use_mcma_dispatch=True, route_scope="tick")
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 5)
                    .astype(np.int32), max_new=4) for i in range(3)]
    for r in reqs:
        server.submit(r)
    stats = server.run_until_drained(max_ticks=300)
    assert all(r.done for r in reqs)
    assert 0.0 <= stats["invocation_rate"] <= 1.0
    assert "routed_per_class" in stats


# ---------------------------------------------------------------------------
# tick scope on the mesh: plan built and consumed in the same sharding
# ---------------------------------------------------------------------------

_TICK_MESH = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs.registry import get_config, smoke_config
    from repro.models import model as M
    from repro.sharding import activations as A

    def cfg_with(backend):
        cfg = smoke_config(get_config("internlm2-1.8b"))
        return dataclasses.replace(cfg, approx=dataclasses.replace(
            cfg.approx, enable=True, backend=backend, interpret=True,
            block_t=16, route_scope="tick"))

    cfg = cfg_with("xla")
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    B = 8
    cache = M.init_cache(cfg, B, 32)
    toks = jnp.arange(1, B + 1, dtype=jnp.int32)[:, None]
    mask = jnp.asarray([True] * 6 + [False] * 2)

    # single-device reference (routing is row-wise, so the psum-reduced
    # mesh counts must equal these exactly)
    _, _, m1 = M.decode(cfg, params, cache, toks, serve=True,
                        collect_metrics=True, row_mask=mask)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    outs, counts = {}, {}
    for backend in ("xla", "pallas"):
        c = cfg_with(backend)
        with mesh, A.activation_sharding(P(("data",), None, None)):
            lg, _, m = jax.jit(lambda p, ca, t, rm, c_=c: M.decode(
                c_, p, ca, t, serve=True, collect_metrics=True,
                row_mask=rm))(params, cache, toks, mask)
        outs[backend] = np.asarray(lg)
        counts[backend] = np.asarray(m["class_counts"]).tolist()
    out = {
        "pallas_bitexact_vs_xla": bool(np.array_equal(outs["pallas"],
                                                      outs["xla"])),
        "counts": counts,
        "single_counts": np.asarray(m1["class_counts"]).tolist(),
        "counts_sum": float(np.asarray(m1["class_counts"]).sum()),
    }
    print("RESULT" + json.dumps(out))
""")


def test_tick_scope_mesh_subprocess_8_virtual_devices():
    out = _run(_TICK_MESH)
    assert out["pallas_bitexact_vs_xla"]
    for be in ("xla", "pallas"):
        assert out["counts"][be] == out["single_counts"], out
    assert out["counts_sum"] == 6.0  # active rows only


needs_8_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (CI multidevice leg: XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")


@needs_8_devices
def test_tick_scope_mesh_inprocess():
    """CI multidevice leg: tick-scope decode on a (4, 2) mesh — pallas ==
    xla bit-for-bit, plan counts == the single-device routing."""
    from repro.sharding import activations as A
    from jax.sharding import PartitionSpec as P
    params = M.init_model(jax.random.PRNGKey(0), _approx_cfg())
    b = 8
    cache = M.init_cache(_approx_cfg(), b, 32)
    toks = jnp.arange(1, b + 1, dtype=jnp.int32)[:, None]
    mask = jnp.asarray([True] * 6 + [False] * 2)
    cfg1 = _approx_cfg(route_scope="tick")
    _, _, m1 = M.decode(cfg1, params, cache, toks, serve=True,
                        collect_metrics=True, row_mask=mask)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    outs = {}
    for be in ("xla", "pallas"):
        c = _approx_cfg(backend=be, interpret=True, block_t=16,
                        route_scope="tick")
        with mesh, A.activation_sharding(P(("data",), None, None)):
            lg, _, m = jax.jit(lambda p, ca, t, rm, c_=c: M.decode(
                c_, p, ca, t, serve=True, collect_metrics=True,
                row_mask=rm))(params, cache, toks, mask)
        outs[be] = np.asarray(lg)
        np.testing.assert_array_equal(np.asarray(m["class_counts"]),
                                      np.asarray(m1["class_counts"]))
    np.testing.assert_array_equal(outs["pallas"], outs["xla"])


# ---------------------------------------------------------------------------
# satellite fixes: grad-accum metrics, hybrid decode metrics, tick co-train
# ---------------------------------------------------------------------------

def test_grad_accum_preserves_metrics():
    """grad_accum > 1 used to return metrics = {} — the invocation /
    router_acc / block metrics must survive the accumulation scan and,
    with equal-sized microbatches, equal the single-shot values."""
    cfg = _approx_cfg()
    state = S.init_train_state(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    batch = {"inputs": jnp.asarray(rng.integers(0, cfg.vocab, (4, 8)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 8)),
                                   jnp.int32)}
    _, m1 = S.make_train_step(cfg, grad_accum=1)(state, batch)
    _, m2 = S.make_train_step(cfg, grad_accum=2)(state, batch)
    for k in ("invocation", "router_acc", "lm_loss", "tick_router_acc"):
        assert k in m2, (k, sorted(m2))
        assert float(m2[k]) == pytest.approx(float(m1[k]), abs=1e-5), k
    assert float(m2["loss"]) == pytest.approx(float(m1["loss"]), abs=1e-5)


def test_grad_accum_matches_single_shot_gradients():
    """The fix must not perturb the accumulated gradients themselves."""
    cfg = _approx_cfg()
    state = S.init_train_state(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    batch = {"inputs": jnp.asarray(rng.integers(0, cfg.vocab, (4, 8)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 8)),
                                   jnp.int32)}
    s1, _ = S.make_train_step(cfg, grad_accum=1)(state, batch)
    s2, _ = S.make_train_step(cfg, grad_accum=2)(state, batch)
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         s1["params"], s2["params"])
    assert max(jax.tree_util.tree_leaves(diffs)) < 1e-5


@pytest.mark.parametrize("route_scope", ["layer", "tick"])
def test_hybrid_decode_collects_dispatch_metrics(route_scope):
    """model.decode's hybrid group body used to drop the shared block's
    metrics (x, nc, _, _ = ...), so collect_metrics returned {} and the
    autotuner was blind for the zamba2 family."""
    cfg = smoke_config(get_config("zamba2-2.7b"))
    cfg = dataclasses.replace(cfg, approx=dataclasses.replace(
        cfg.approx, enable=True, route_scope=route_scope))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    assert "tick_router" in params
    b = 2
    cache = M.init_cache(cfg, b, 32)
    toks = jnp.arange(1, b + 1, dtype=jnp.int32)[:, None]
    lg, _, m = M.decode(cfg, params, cache, toks, serve=True,
                        collect_metrics=True)
    assert "invocation" in m and "class_counts" in m, sorted(m)
    assert int(np.asarray(m["class_counts"]).sum()) == b
    assert np.isfinite(np.asarray(lg)).all()


def test_hybrid_tick_decode_pallas_matches_xla():
    base = smoke_config(get_config("zamba2-2.7b"))
    outs = {}
    for be, kw in (("xla", {}),
                   ("pallas", dict(interpret=True, block_t=16))):
        cfg = dataclasses.replace(base, approx=dataclasses.replace(
            base.approx, enable=True, backend=be, route_scope="tick", **kw))
        params = M.init_model(jax.random.PRNGKey(0), cfg)
        cache = M.init_cache(cfg, 2, 32)
        toks = jnp.asarray([[3], [5]], jnp.int32)
        lg, _, _ = M.decode(cfg, params, cache, toks, serve=True,
                            collect_metrics=True)
        outs[be] = np.asarray(lg)
    np.testing.assert_array_equal(outs["pallas"], outs["xla"])


def test_unknown_route_scope_raises():
    """A typo'd scope must fail loudly, not silently run layer routing."""
    with pytest.raises(ValueError, match="route_scope"):
        S.make_decode_step(_approx_cfg(), route_scope="ticks")
    cfg = _approx_cfg(route_scope="Tick")
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    cache = M.init_cache(cfg, 2, 32)
    with pytest.raises(ValueError, match="route_scope"):
        M.decode(cfg, params, cache, jnp.ones((2, 1), jnp.int32), serve=True)


def test_tick_router_head_cotrains():
    """The tick router must receive gradient signal from the aggregated
    competitive labels (its loss rides the aux channel)."""
    cfg = _approx_cfg()
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    inputs = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    loss, metrics = M.lm_loss(cfg, params, inputs, labels)
    assert "tick_router_loss" in metrics and "tick_router_acc" in metrics
    assert 0.0 <= float(metrics["tick_router_acc"]) <= 1.0
    g = jax.grad(lambda p: M.lm_loss(cfg, p, inputs, labels)[0])(params)
    assert float(jnp.linalg.norm(g["tick_router"])) > 0.0
