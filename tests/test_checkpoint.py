"""Fault tolerance: atomic checkpointing, bitwise restart, keep-k GC,
injected preemption, and deterministic data replay."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as C
from repro.configs.registry import get_config, smoke_config
from repro.data.pipeline import SyntheticLM
from repro.runtime.trainer import PreemptionError, Trainer, TrainerConfig

jax.config.update("jax_platform_name", "cpu")


def _tiny_cfg():
    return dataclasses.replace(smoke_config(get_config("olmo-1b")), vocab=128)


def _ds(cfg):
    return SyntheticLM(vocab=cfg.vocab, seq_len=32, global_batch=4)


def test_save_restore_roundtrip(tmp_path):
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"m": [jnp.ones(3), jnp.zeros(2)]},
             "step": jnp.asarray(7, jnp.int32)}
    C.save(str(tmp_path), 7, state)
    got, step = C.restore(str(tmp_path))
    assert step == 7
    flat_a = jax.tree.leaves(state)
    flat_b = jax.tree.leaves(got)
    assert len(flat_a) == len(flat_b)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_gc(tmp_path):
    state = {"x": jnp.zeros(1)}
    for s in range(6):
        C.save(str(tmp_path), s, state, keep_k=3)
    assert sorted(C.all_steps(str(tmp_path))) == [3, 4, 5]


def test_atomicity_partial_tmp_ignored(tmp_path):
    state = {"x": jnp.ones(2)}
    C.save(str(tmp_path), 1, state)
    # simulate a writer dying mid-checkpoint: stray tmp dir + step dir
    # without a manifest must both be ignored
    os.makedirs(tmp_path / "tmp.2")
    os.makedirs(tmp_path / "step_000000002")
    assert C.latest_step(str(tmp_path)) == 1
    got, step = C.restore(str(tmp_path))
    assert step == 1 and got is not None


def test_bitwise_resume(tmp_path):
    """save@5 -> restart -> train to 10 == uninterrupted train to 10."""
    cfg = _tiny_cfg()
    tc = TrainerConfig(total_steps=10, ckpt_every=5, log_every=100,
                       ckpt_dir=str(tmp_path / "a"))
    t1 = Trainer(cfg, tc, _ds(cfg), seed=3)
    r1 = t1.run()

    # interrupted twin: run to 5 (ckpt), new Trainer resumes 5 -> 10
    tc2 = TrainerConfig(total_steps=5, ckpt_every=5, log_every=100,
                        ckpt_dir=str(tmp_path / "b"))
    Trainer(cfg, tc2, _ds(cfg), seed=3).run()
    tc3 = TrainerConfig(total_steps=10, ckpt_every=5, log_every=100,
                        ckpt_dir=str(tmp_path / "b"))
    t3 = Trainer(cfg, tc3, _ds(cfg), seed=3)
    assert t3.start_step == 5
    t3.run()

    for a, b in zip(jax.tree.leaves(t1.state), jax.tree.leaves(t3.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_injected_preemption_then_auto_restore(tmp_path):
    """A preempted job restarted with the same command line recovers."""
    cfg = _tiny_cfg()
    ckpt = str(tmp_path / "ck")
    tc = TrainerConfig(total_steps=10, ckpt_every=2, log_every=100,
                       ckpt_dir=ckpt, fail_at=7)
    with pytest.raises(PreemptionError):
        Trainer(cfg, tc, _ds(cfg), seed=0).run()
    assert C.latest_step(ckpt) == 6
    tc2 = TrainerConfig(total_steps=10, ckpt_every=2, log_every=100,
                        ckpt_dir=ckpt)
    t = Trainer(cfg, tc2, _ds(cfg), seed=0)
    assert t.start_step == 6
    out = t.run()
    assert out["steps"] == 4


def test_data_determinism_and_host_slicing():
    ds = SyntheticLM(vocab=512, seq_len=64, global_batch=8)
    a = ds.batch_at(3)
    b = ds.batch_at(3)
    np.testing.assert_array_equal(np.asarray(a["inputs"]), np.asarray(b["inputs"]))
    c = ds.batch_at(4)
    assert not np.array_equal(np.asarray(a["inputs"]), np.asarray(c["inputs"]))
    # shifted labels
    np.testing.assert_array_equal(np.asarray(a["inputs"][:, 1:]),
                                  np.asarray(a["labels"][:, :-1]))
    # host slicing: different hosts draw different rows
    h0 = SyntheticLM(vocab=512, seq_len=64, global_batch=8, host_id=0, n_hosts=2)
    h1 = SyntheticLM(vocab=512, seq_len=64, global_batch=8, host_id=1, n_hosts=2)
    assert h0.local_batch == 4
    assert not np.array_equal(np.asarray(h0.batch_at(0)["inputs"]),
                              np.asarray(h1.batch_at(0)["inputs"]))


def test_elastic_restore_changes_nothing(tmp_path):
    """Restore is mesh-agnostic: host arrays round-trip without sharding."""
    cfg = _tiny_cfg()
    tc = TrainerConfig(total_steps=2, ckpt_every=2, log_every=100,
                       ckpt_dir=str(tmp_path))
    t = Trainer(cfg, tc, _ds(cfg), seed=1)
    t.run()
    state, step = C.restore(str(tmp_path))
    assert step == 2
    # manifests carry no mesh info
    import json
    man = json.load(open(tmp_path / "step_000000002" / "manifest.json"))
    assert "mesh" not in json.dumps(man)
