"""Paper-core behaviour + hypothesis property tests on system invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.apps import APPS, make_dataset
from repro.core import (npu_model, quality, train_iterative, train_mcca,
                        train_mcma, train_one_pass)
from repro.core.mcma import _labels_competitive, _labels_complementary
from repro.core.mlp import MLPSpec, balanced_weights

jax.config.update("jax_platform_name", "cpu")

FAST = dict(n_train=1_500, n_test=800, epochs=250)


@pytest.fixture(scope="module")
def bs_models():
    app = APPS["blackscholes"]
    key = jax.random.PRNGKey(0)
    xtr, ytr, xte, yte = make_dataset(app, key, FAST["n_train"], FAST["n_test"])
    ks = jax.random.split(key, 4)
    return app, xte, yte, {
        "one-pass": train_one_pass(app, ks[0], xtr, ytr, epochs=FAST["epochs"]),
        "iterative": train_iterative(app, ks[1], xtr, ytr, epochs=FAST["epochs"]),
        "mcma": train_mcma(app, ks[2], xtr, ytr, epochs=FAST["epochs"]),
        "mcca": train_mcca(app, ks[3], xtr, ytr, epochs=FAST["epochs"]),
    }


def test_all_methods_produce_valid_metrics(bs_models):
    app, xte, yte, models = bs_models
    for name, m in models.items():
        met = m.evaluate(xte, yte)
        assert 0.0 <= met.invocation <= 1.0, name
        assert 0.0 <= met.recall <= 1.0, name
        assert met.false_neg >= 0 and met.false_pos >= 0, name


def test_mcma_invocation_beats_one_pass(bs_models):
    """The paper's headline: MCMA recovers abandoned safe inputs."""
    app, xte, yte, models = bs_models
    inv_mcma = models["mcma"].evaluate(xte, yte).invocation
    inv_op = models["one-pass"].evaluate(xte, yte).invocation
    assert inv_mcma >= inv_op - 0.02, (inv_mcma, inv_op)


def test_npu_cost_model_monotone(bs_models):
    app, *_ = bs_models
    costs = [npu_model.cost(app, inv).time_per_call
             for inv in (0.0, 0.3, 0.6, 0.9)]
    assert all(a > b for a, b in zip(costs, costs[1:]))  # more inv = faster
    cpu = npu_model.cpu_only(app)
    full = npu_model.cost(app, 1.0)
    assert full.speedup_vs(cpu) < 1.0 or full.time_per_call < cpu.time_per_call


def test_mcca_consults_more_classifiers_than_mcma(bs_models):
    app, xte, yte, models = bs_models
    mcca = models["mcca"]
    if len(mcca.pairs) > 1:
        assert float(mcca.classifiers_consulted(xte)) > 1.0


# ---------------------------------------------------------------------------
# Hypothesis property tests
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(2, 5), st.integers(4, 60), st.floats(0.01, 0.5))
def test_complementary_labels_first_safe_wins(n_approx, n, bound):
    key = jax.random.PRNGKey(n_approx * 1000 + n)
    errs = jax.random.uniform(key, (n_approx, n), minval=0.0, maxval=1.0)
    labels = np.asarray(_labels_complementary(errs, bound))
    errs = np.asarray(errs)
    for j in range(n):
        safe = np.where(errs[:, j] <= bound)[0]
        assert labels[j] == (safe[0] if safe.size else n_approx)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 5), st.integers(4, 60), st.floats(0.01, 0.5))
def test_competitive_labels_argmin_under_bound(n_approx, n, bound):
    key = jax.random.PRNGKey(n_approx * 7919 + n)
    errs = jax.random.uniform(key, (n_approx, n), minval=0.0, maxval=1.0)
    labels = np.asarray(_labels_competitive(errs, bound))
    errs = np.asarray(errs)
    for j in range(n):
        if labels[j] < n_approx:
            assert errs[labels[j], j] <= bound
            assert labels[j] == np.argmin(errs[:, j])
        else:
            assert errs[:, j].min() > bound


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(10, 200))
def test_balanced_weights_mean_one_and_class_balanced(n_classes, n):
    key = jax.random.PRNGKey(n_classes * 31 + n)
    labels = jax.random.randint(key, (n,), 0, n_classes)
    w = np.asarray(balanced_weights(labels, n_classes))
    assert w.shape == (n,)
    assert abs(w.mean() - 1.0) < 1e-4
    # every represented class contributes equal total weight
    labels = np.asarray(labels)
    sums = [w[labels == c].sum() for c in range(n_classes)
            if (labels == c).any()]
    np.testing.assert_allclose(sums, sums[0], rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from(list(APPS)), st.integers(8, 64))
def test_per_sample_error_nonneg_and_zero_for_exact(app_name, n):
    app = APPS[app_name]
    key = jax.random.PRNGKey(n)
    x = app.gen(key, n)
    y = app.fn(x)
    err = np.asarray(quality.per_sample_error(app, y, y))
    assert (err >= 0).all() and err.max() < 1e-5


def test_mlp_spec_parse_and_macs():
    spec = MLPSpec.parse("6->8->1")
    assert spec.sizes == (6, 8, 1)
    assert spec.n_macs == 6 * 8 + 8 * 1
    assert spec.n_params == 6 * 8 + 8 + 8 * 1 + 1
