"""Self-test for the repro.analysis gate.

Pins, per the PR's acceptance criteria:
  * injected violations of EVERY rule family (RL001-RL005) are caught,
    in-process and through the ``python -m repro.analysis`` CLI (which
    must exit nonzero on a new finding);
  * the baseline workflow: grandfathered findings suppress, NEW findings
    still fail, fixed findings surface as stale without failing;
  * the current tree is clean — ``run_lint()`` over the real sources
    returns zero findings (the checked-in baseline stays empty);
  * the ``jit_cache`` helper: silent on a zero-retrace function, raises
    with the cause string on a retraced one;
  * the audit helpers detect a retraced program (TA001), a non-int32
    stats counter (TA002), and a host callback in the jaxpr (TA003);
  * a reduced ``run_audit`` sweep over the real engine is green.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import run_lint
from repro.analysis.findings import (Finding, load_baseline,
                                     split_by_baseline, write_baseline)
from repro.analysis.jit_cache import assert_zero_retrace, cache_size
from repro.analysis.lint import lint_paths

jax.config.update("jax_platform_name", "cpu")

REPO = Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# injected violations, one per rule family
# ---------------------------------------------------------------------------

VIOLATIONS = {
    "RL001": """
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("block_q",))
        def f(x, block_t=8):
            return x * block_t
        """,
    "RL002": """
        import jax

        @jax.jit
        def f(x: jax.Array):
            return x.sum().item()
        """,
    "RL003": """
        import dataclasses

        import jax

        @dataclasses.dataclass(frozen=True)
        class Plan:
            cls: jax.Array
            rank: jax.Array
        """,
    "RL004": """
        import jax
        from jax import lax

        def reduce_stats(counts):
            return lax.psum(counts, "bogus_axis")
        """,
    "RL005": """
        from jax.experimental import pallas as pl

        def grid_for(t, block_t):
            return (t // block_t,)
        """,
}

# drift variant for RL003: registered, but the flatten tuple dropped a field
RL003_DRIFT = """
    import dataclasses

    import jax

    _DATA = ("cls",)

    @dataclasses.dataclass(frozen=True)
    class Plan:
        cls: jax.Array
        rank: jax.Array

    jax.tree_util.register_pytree_node(
        Plan,
        lambda p: (tuple(getattr(p, f) for f in _DATA), ()),
        lambda meta, data: Plan(*data, *meta))
    """

# index_map arity drift for RL005's second contract
RL005_ARITY = """
    from jax.experimental import pallas as pl

    def launch(k, x):
        return pl.pallas_call(
            k, grid=(4, 4),
            in_specs=[pl.BlockSpec((1, 1), lambda i: (i, 0))])(x)
    """


def _mk_tree(tmp_path: Path, sources: dict) -> Path:
    """A fake repo root with a sharding spec (declaring only "data") and
    the given {relpath: source} files."""
    spec = tmp_path / "src" / "repro" / "sharding" / "rules.py"
    spec.parent.mkdir(parents=True, exist_ok=True)
    spec.write_text(textwrap.dedent("""
        def data_axes(mesh):
            return ("data",)
        """))
    for rel, src in sources.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return tmp_path


@pytest.mark.parametrize("rule", sorted(VIOLATIONS))
def test_injected_violation_is_caught(rule, tmp_path):
    root = _mk_tree(tmp_path, {f"src/repro/bad_{rule.lower()}.py":
                               VIOLATIONS[rule]})
    findings = lint_paths([root / "src"], root)
    assert rule in {f.rule for f in findings}, \
        f"{rule}: injected violation not caught ({findings})"
    # and the gate itself exits nonzero on it
    r = _cli(root)
    assert r.returncode == 1, r.stdout + r.stderr
    assert rule in r.stdout


def test_rl003_flatten_drift_is_caught(tmp_path):
    root = _mk_tree(tmp_path, {"src/repro/drift.py": RL003_DRIFT})
    fs = [f for f in lint_paths([root / "src"], root) if f.rule == "RL003"]
    assert any(f.detail == "field-drift" for f in fs), fs
    # registered -> the "unregistered" arm must NOT also fire
    assert not any(f.detail == "unregistered" for f in fs), fs


def test_rl005_index_map_arity_is_caught(tmp_path):
    root = _mk_tree(tmp_path, {"src/repro/arity.py": RL005_ARITY})
    fs = [f for f in lint_paths([root / "src"], root) if f.rule == "RL005"]
    assert any(f.detail.startswith("index-map-arity") for f in fs), fs


# the fused-dispatch kernel's shape: TWO scalar-prefetch operands and
# index maps factored out as named defs — RL005 must resolve the name
# and hold it to grid rank + 2
RL005_NAMED_ARITY = """
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    def launch(k, x, rows, tc):
        def _resident(i, rows_s):
            return (0, 0)
        spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2, grid=(4,),
            in_specs=[pl.BlockSpec((1, 1), _resident)])
        return pl.pallas_call(k, grid_spec=spec)(rows, tc, x)
    """

RL005_NAMED_ARITY_OK = """
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    def launch(k, x, rows, tc):
        def _resident(i, rows_s, tc_s):
            return (0, 0)
        _weight = lambda i, rows_s, tc_s: (tc_s[i], 0, 0)

        def _any(*args):
            return (0, 0)
        spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2, grid=(4,),
            in_specs=[pl.BlockSpec((1, 1), _resident),
                      pl.BlockSpec((1, 1), _weight),
                      pl.BlockSpec((1, 1), _any)])
        return pl.pallas_call(k, grid_spec=spec)(rows, tc, x)
    """


def test_rl005_named_index_map_arity_is_caught(tmp_path):
    root = _mk_tree(tmp_path, {"src/repro/named.py": RL005_NAMED_ARITY})
    fs = [f for f in lint_paths([root / "src"], root) if f.rule == "RL005"]
    assert any(f.detail == "index-map-arity:2:3" for f in fs), fs


def test_rl005_named_index_map_correct_arity_is_clean(tmp_path):
    """def-based and lambda-assigned maps with the right arity pass; a
    *args map stays unchecked rather than guessed."""
    root = _mk_tree(tmp_path, {"src/repro/named.py": RL005_NAMED_ARITY_OK})
    fs = [f for f in lint_paths([root / "src"], root) if f.rule == "RL005"
          and f.detail.startswith("index-map-arity")]
    assert fs == [], fs


# the paged-KV extension: page-grid floor divisions are held to the
# divisibility contract in ANY module (no pallas import, not kernels/);
# unrelated floor divisions outside the pallas scope stay unchecked
RL005_PAGE_GRID = """
    def bad_table_shape(max_len, page_size):
        return max_len // page_size

    def guarded_table_shape(max_len, page_size):
        assert max_len % page_size == 0
        return max_len // page_size

    def pages_needed(tokens, page_size):
        return (tokens + page_size - 1) // page_size

    def unrelated(total, workers):
        return total // workers
    """


def test_rl005_page_grid_arithmetic_covered_everywhere(tmp_path):
    root = _mk_tree(tmp_path, {"src/repro/runtime/pager.py":
                               RL005_PAGE_GRID})
    fs = [f for f in lint_paths([root / "src"], root) if f.rule == "RL005"]
    assert len(fs) == 1, fs                      # ONLY the unguarded one
    assert fs[0].scope == "bad_table_shape", fs
    assert fs[0].detail.startswith("floordiv"), fs


def test_guarded_and_plumbed_patterns_stay_clean(tmp_path):
    """The engine's own idioms must not trip the rules: an asserted
    floordiv, the round-up idiom, parameter-plumbed psum axes, a
    declared axis, and dataclasses.fields-based registration."""
    root = _mk_tree(tmp_path, {"src/repro/good.py": """
        import dataclasses

        import jax
        from jax import lax
        from jax.experimental import pallas as pl

        def tiles(t, block_t):
            assert t % block_t == 0
            return t // block_t

        def tiles_up(t, block_t):
            return (t + block_t - 1) // block_t

        def reduce_stats(counts, stats_axes):
            ax = tuple(stats_axes)          # plumbed: mesh-agnostic
            return lax.psum(counts, ax)

        def reduce_local(counts):
            return lax.psum(counts, ("data",))

        @dataclasses.dataclass(frozen=True)
        class Stats:
            counts: jax.Array

        _FIELDS = tuple(f.name for f in dataclasses.fields(Stats))
        jax.tree_util.register_pytree_node(
            Stats,
            lambda s: (tuple(getattr(s, f) for f in _FIELDS), None),
            lambda _, data: Stats(*data))
        """})
    assert lint_paths([root / "src"], root) == []


def test_current_tree_is_clean():
    """The repo's own sources carry zero findings — the checked-in
    baseline stays empty and every new finding fails the gate."""
    findings = run_lint(root=REPO)
    assert findings == [], "\n".join(f.render() for f in findings)
    assert load_baseline(REPO / "analysis_baseline.txt") == set()


# ---------------------------------------------------------------------------
# the CLI + baseline workflow
# ---------------------------------------------------------------------------

def _cli(root: Path, *extra: str):
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"), JAX_PLATFORMS="cpu")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--stage", "lint",
         "--root", str(root), str(root / "src"), *extra],
        capture_output=True, text=True, timeout=120, env=env)


def test_cli_fails_on_new_finding_and_baseline_suppresses(tmp_path):
    root = _mk_tree(tmp_path, {"src/repro/bad.py": VIOLATIONS["RL002"]})
    r = _cli(root)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "RL002" in r.stdout

    # grandfather it, then the same tree passes...
    assert _cli(root, "--update-baseline").returncode == 0
    r = _cli(root)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "grandfathered" in r.stdout

    # ...but a NEW violation still fails
    (root / "src" / "repro" / "worse.py").write_text(
        textwrap.dedent(VIOLATIONS["RL005"]))
    r = _cli(root)
    assert r.returncode == 1
    assert "RL005" in r.stdout

    # fixing the grandfathered finding surfaces it as stale, not a failure
    (root / "src" / "repro" / "worse.py").unlink()
    (root / "src" / "repro" / "bad.py").write_text("x = 1\n")
    r = _cli(root)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "[stale]" in r.stdout


def test_baseline_keys_are_line_number_free(tmp_path):
    """Moving a grandfathered finding to another line must not churn the
    baseline: keys carry rule/path/scope/detail, never the line."""
    src = VIOLATIONS["RL002"]
    root = _mk_tree(tmp_path, {"src/repro/bad.py": src})
    f1 = lint_paths([root / "src"], root)
    (root / "src" / "repro" / "bad.py").write_text(
        "# a comment pushing everything down\n" + textwrap.dedent(src))
    f2 = lint_paths([root / "src"], root)
    assert [f.key for f in f1] == [f.key for f in f2]
    assert [f.line for f in f1] != [f.line for f in f2]
    new, old, stale = split_by_baseline(f2, {f.key for f in f1})
    assert new == [] and len(old) == len(f1) and stale == set()


def test_baseline_round_trip(tmp_path):
    fs = [Finding(rule="RL001", path="a.py", line=3, scope="f",
                  detail="static_argnames:block_q", message="m")]
    path = tmp_path / "baseline.txt"
    write_baseline(path, fs)
    assert load_baseline(path) == {fs[0].key}


# ---------------------------------------------------------------------------
# jit_cache helper
# ---------------------------------------------------------------------------

def test_assert_zero_retrace_passes_and_fails():
    ok = jax.jit(lambda x: x + 1)
    for v in (0.0, 1.0, 2.0):
        ok(jnp.full((4,), v))
    assert cache_size(ok) == 1
    assert_zero_retrace(ok, "a value change")

    bad = jax.jit(lambda x: x + 1)
    bad(jnp.zeros((4,)))
    bad(jnp.zeros((8,)))                      # new shape -> second program
    assert cache_size(bad) == 2
    with pytest.raises(AssertionError, match="a shape change forced"):
        assert_zero_retrace(bad, "a shape change")


# ---------------------------------------------------------------------------
# audit helpers (TA001/TA002/TA003) + a reduced live sweep
# ---------------------------------------------------------------------------

def test_audit_detects_retrace():
    from repro.analysis.audit import retrace_findings
    fn = jax.jit(lambda x: x * 2)
    fn(jnp.zeros((4,)))
    assert retrace_findings(fn, scope="fn") == []
    fn(jnp.zeros((8,)))
    fs = retrace_findings(fn, scope="fn")
    assert len(fs) == 1 and fs[0].rule == "TA001"


def test_audit_detects_bad_stats_dtype():
    from repro.analysis.audit import stats_dtype_findings
    good = {"counts": jnp.zeros((4,), jnp.int32),
            "invocation": jnp.zeros((), jnp.float32)}
    assert stats_dtype_findings(good, scope="s") == []
    bad = dict(good, tier_counts=jnp.zeros((3,), jnp.int16))
    fs = stats_dtype_findings(bad, scope="s")
    assert len(fs) == 1 and fs[0].rule == "TA002"
    assert "tier_counts" in fs[0].detail


def test_audit_detects_host_callback():
    from repro.analysis.audit import callback_findings

    def clean(x):
        return jax.lax.scan(lambda c, v: (c + v, c), 0.0, x)[0]

    def dirty(x):
        jax.debug.callback(lambda v: None, x.sum())
        return x * 2

    x = jnp.zeros((4,))
    assert callback_findings(clean, (x,), scope="clean") == []
    fs = callback_findings(dirty, (x,), scope="dirty")
    assert len(fs) == 1 and fs[0].rule == "TA003"
    assert "debug_callback" in fs[0].detail

    # callbacks hiding inside control-flow sub-jaxprs are still found
    def nested(x):
        def body(c, v):
            jax.debug.callback(lambda s: None, v)
            return c + v, c
        return jax.lax.scan(body, 0.0, x)[0]
    assert callback_findings(nested, (x,), scope="nested") != []


def test_engine_audit_is_green():
    """The real engine holds its contracts under the reduced (xla-only,
    engine-only) sweep; ``make analyze`` runs the full one."""
    from repro.analysis.audit import run_audit
    fs = run_audit(backends=("xla",), with_steps=False)
    assert fs == [], "\n".join(f.render() for f in fs)
