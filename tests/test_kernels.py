"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis property tests.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

jax.config.update("jax_platform_name", "cpu")


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=3e-5, atol=3e-5)


def _mk_mlp(key, d_in, d_h, d_out, dtype):
    ks = jax.random.split(key, 4)
    w1 = (jax.random.normal(ks[0], (d_in, d_h)) * 0.2).astype(dtype)
    b1 = (jax.random.normal(ks[1], (d_h,)) * 0.1).astype(dtype)
    w2 = (jax.random.normal(ks[2], (d_h, d_out)) * 0.2).astype(dtype)
    b2 = (jax.random.normal(ks[3], (d_out,)) * 0.1).astype(dtype)
    return w1, b1, w2, b2


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t,d_in,d_h,d_out", [
    (64, 8, 8, 1),          # paper-scale approximator (padded to lanes)
    (300, 100, 40, 60),     # unaligned everything
    (512, 256, 128, 256),   # aligned LM-scale ApproxFFN slice
    (1, 6, 8, 2),           # single row
])
def test_mlp_forward_matches_ref(dtype, t, d_in, d_h, d_out):
    key = jax.random.PRNGKey(hash((t, d_in, d_h, d_out)) % 2**31)
    x = (jax.random.normal(key, (t, d_in)) * 0.5).astype(dtype)
    w1, b1, w2, b2 = _mk_mlp(jax.random.fold_in(key, 1), d_in, d_h, d_out, dtype)
    got = ops.mlp_apply(x, w1, b1, w2, b2, block_t=128, interpret=True)
    want = ref.mlp_forward_ref(x, w1, b1, w2, b2)
    assert got.shape == (t, d_out) and got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t,n,d_in,d_h,d_out,block", [
    (500, 3, 64, 32, 64, 128),   # MCMA default: 3 approximators
    (96, 1, 16, 8, 16, 32),      # degenerate single approximator
    (1024, 8, 128, 64, 128, 256),
    (33, 4, 10, 6, 4, 32),       # tiny ragged groups
])
def test_switched_mlp_matches_ref(dtype, t, n, d_in, d_h, d_out, block):
    key = jax.random.PRNGKey(hash((t, n, d_in)) % 2**31)
    x = (jax.random.normal(key, (t, d_in)) * 0.5).astype(dtype)
    ks = jax.random.split(jax.random.fold_in(key, 7), 4)
    w1 = (jax.random.normal(ks[0], (n, d_in, d_h)) * 0.2).astype(dtype)
    b1 = (jax.random.normal(ks[1], (n, d_h)) * 0.1).astype(dtype)
    w2 = (jax.random.normal(ks[2], (n, d_h, d_out)) * 0.2).astype(dtype)
    b2 = (jax.random.normal(ks[3], (n, d_out)) * 0.1).astype(dtype)
    cls = jax.random.randint(jax.random.fold_in(key, 9), (t,), 0, n)
    got = ops.switched_apply(x, cls, w1, b1, w2, b2, block_t=block, interpret=True)
    want = ref.switched_mlp_ref(x, cls, w1, b1, w2, b2)
    assert got.shape == (t, d_out) and got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_switched_mlp_skewed_classes():
    """All rows on one class (the common post-convergence MCMA regime)."""
    key = jax.random.PRNGKey(3)
    t, n, d = 257, 3, 32
    x = jax.random.normal(key, (t, d))
    w1 = jax.random.normal(jax.random.fold_in(key, 1), (n, d, 16)) * 0.2
    b1 = jnp.zeros((n, 16))
    w2 = jax.random.normal(jax.random.fold_in(key, 2), (n, 16, d)) * 0.2
    b2 = jnp.zeros((n, d))
    cls = jnp.full((t,), 2, jnp.int32)
    got = ops.switched_apply(x, cls, w1, b1, w2, b2, block_t=64, interpret=True)
    want = ref.switched_mlp_ref(x, cls, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# switched_apply edge cases (each against the pure-jnp oracle in ref.py)
# ---------------------------------------------------------------------------


def _mk_switched(key, n, d_in, d_h, d_out, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    w1 = (jax.random.normal(ks[0], (n, d_in, d_h)) * 0.2).astype(dtype)
    b1 = (jax.random.normal(ks[1], (n, d_h)) * 0.1).astype(dtype)
    w2 = (jax.random.normal(ks[2], (n, d_h, d_out)) * 0.2).astype(dtype)
    b2 = (jax.random.normal(ks[3], (n, d_out)) * 0.1).astype(dtype)
    return w1, b1, w2, b2


def _check_switched(x, cls, w1, b1, w2, b2, block):
    got = ops.switched_apply(x, cls, w1, b1, w2, b2, block_t=block,
                             interpret=True)
    want = ref.switched_mlp_ref(x, cls, w1, b1, w2, b2)
    assert got.shape == want.shape and got.dtype == x.dtype
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_switched_mlp_empty_class():
    """A class with zero rows must not perturb its neighbours' tiles."""
    key = jax.random.PRNGKey(11)
    t, n, d = 120, 4, 24
    x = jax.random.normal(key, (t, d))
    w = _mk_switched(jax.random.fold_in(key, 1), n, d, 8, d)
    cls = jax.random.randint(jax.random.fold_in(key, 2), (t,), 0, n)
    cls = jnp.where(cls == 1, 3, cls)        # class 1 is now empty
    _check_switched(x, cls, *w, block=32)


def test_switched_mlp_t_smaller_than_block():
    """T < block_t: everything lives inside partial tiles."""
    key = jax.random.PRNGKey(12)
    t, n, d = 7, 3, 16
    x = jax.random.normal(key, (t, d))
    w = _mk_switched(jax.random.fold_in(key, 1), n, d, 8, d)
    cls = jax.random.randint(jax.random.fold_in(key, 2), (t,), 0, n)
    _check_switched(x, cls, *w, block=64)


def test_switched_mlp_single_approximator():
    """n_approx == 1 degenerates to a plain grouped MLP (no switching)."""
    key = jax.random.PRNGKey(13)
    t, d = 150, 20
    x = jax.random.normal(key, (t, d))
    w = _mk_switched(jax.random.fold_in(key, 1), 1, d, 12, d)
    cls = jnp.zeros((t,), jnp.int32)
    _check_switched(x, cls, *w, block=64)


def test_switched_mlp_all_nc_zero_class():
    """All rows on a zero-weight "nC" class (the dispatch engine's trick
    for exact/over-capacity rows) must come out exactly zero."""
    key = jax.random.PRNGKey(14)
    t, n, d = 90, 3, 24
    x = jax.random.normal(key, (t, d))
    w1, b1, w2, b2 = _mk_switched(jax.random.fold_in(key, 1), n, d, 8, d)
    zc = lambda w: jnp.concatenate([w, jnp.zeros_like(w[:1])], 0)
    cls = jnp.full((t,), n, jnp.int32)       # everyone on the zero class
    got = ops.switched_apply(x, cls, zc(w1), zc(b1), zc(w2), zc(b2),
                             block_t=32, interpret=True)
    assert not np.asarray(got).any()
    _check_switched(x, cls, zc(w1), zc(b1), zc(w2), zc(b2), block=32)


# ---------------------------------------------------------------------------
# Property tests
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(t=st.integers(1, 300), d_in=st.integers(1, 80), d_h=st.integers(1, 40),
       d_out=st.integers(1, 80), seed=st.integers(0, 2**30))
def test_mlp_forward_property(t, d_in, d_h, d_out, seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (t, d_in)) * 0.5
    w1, b1, w2, b2 = _mk_mlp(jax.random.fold_in(key, 1), d_in, d_h, d_out, jnp.float32)
    got = ops.mlp_apply(x, w1, b1, w2, b2, block_t=64, interpret=True)
    want = ref.mlp_forward_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-5, atol=5e-5)


@settings(max_examples=15, deadline=None)
@given(t=st.integers(1, 200), n=st.integers(1, 5), seed=st.integers(0, 2**30))
def test_switched_permutation_invariance(t, n, seed):
    """Permuting the rows permutes the outputs identically (dispatch is
    row-wise — the sort/scatter machinery must be order-free)."""
    key = jax.random.PRNGKey(seed)
    d = 24
    x = jax.random.normal(key, (t, d))
    ks = jax.random.split(jax.random.fold_in(key, 5), 4)
    w1 = jax.random.normal(ks[0], (n, d, 8)) * 0.3
    b1 = jax.random.normal(ks[1], (n, 8)) * 0.1
    w2 = jax.random.normal(ks[2], (n, 8, d)) * 0.3
    b2 = jax.random.normal(ks[3], (n, d)) * 0.1
    cls = jax.random.randint(jax.random.fold_in(key, 6), (t,), 0, n)
    perm = jax.random.permutation(jax.random.fold_in(key, 8), t)
    y = ops.switched_apply(x, cls, w1, b1, w2, b2, block_t=32, interpret=True)
    y_perm = ops.switched_apply(x[perm], cls[perm], w1, b1, w2, b2,
                                block_t=32, interpret=True)
    np.testing.assert_allclose(np.asarray(y[perm]), np.asarray(y_perm),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# sLSTM recurrence kernel (VMEM-resident state)
# ---------------------------------------------------------------------------

from repro.kernels import slstm_scan as SK


@pytest.mark.parametrize("s,b,h,hd", [
    (8, 2, 2, 8),       # tiny
    (32, 4, 4, 16),     # smoke-model scale
    (16, 1, 4, 128),    # lane-aligned head dim
])
def test_slstm_scan_matches_ref(s, b, h, hd):
    key = jax.random.PRNGKey(s * 100 + b)
    xg = jax.random.normal(key, (s, b, h, 4 * hd), jnp.float32) * 0.5
    wh = (jax.random.normal(jax.random.fold_in(key, 1),
                            (h, hd, 4 * hd)) * 0.2).astype(jnp.float32)
    z = jnp.zeros((b, h, hd), jnp.float32)
    m0 = jnp.full((b, h, hd), -1e30, jnp.float32)
    ys, (hf, cf, nf, mf) = SK.slstm_scan(xg, wh, z, z, z, m0, interpret=True)
    ys2, (h2, c2, n2, m2) = ref.slstm_scan_ref(xg, wh, z, z, z, m0)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(ys2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(h2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cf), np.asarray(c2),
                               rtol=1e-5, atol=1e-5)


def test_slstm_scan_matches_model_layer():
    """Kernel == the model's slstm core (same gate layout end to end)."""
    import dataclasses
    from repro.configs.registry import get_config, smoke_config
    from repro.models import xlstm as X
    cfg = smoke_config(get_config("xlstm-1.3b"))
    p = X.init_slstm(jax.random.PRNGKey(3), cfg)
    b, s = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(4), (b, s, cfg.d_model),
                          jnp.float32) * 0.3
    # model path (without the post-FFN): reproduce slstm_fwd's core
    d, h, hd = X.slstm_dims(cfg)
    xg = (jnp.dot(x, p["w_x"]) + p["b"]).reshape(b, s, 4, h, hd) \
        .transpose(1, 0, 3, 2, 4).reshape(s, b, h, 4 * hd)
    z0 = jnp.zeros((b, h, hd), jnp.float32)
    m0 = jnp.full((b, h, hd), -1e30, jnp.float32)
    ys, _ = SK.slstm_scan(xg, p["w_h"].astype(jnp.float32),
                          z0, z0, z0, m0, interpret=True)
    y_kernel = ys.transpose(1, 0, 2, 3).reshape(b, s, d)

    y_model, _ = X.slstm_fwd(cfg, p, x)
    # undo the model's post up/down FFN by re-projecting the kernel output
    up = jnp.dot(y_kernel, p["w_up"])
    u, g = jnp.split(up, 2, axis=-1)
    y_kernel_full = jnp.dot(u * jax.nn.gelu(g), p["w_down"])
    np.testing.assert_allclose(np.asarray(y_kernel_full), np.asarray(y_model),
                               rtol=2e-4, atol=2e-4)


def test_slstm_scan_trainable_grads_match_ref():
    """custom_vjp wrapper: kernel fwd + reference bwd == reference grads."""
    s, b, h, hd = 12, 2, 2, 8
    key = jax.random.PRNGKey(7)
    xg = jax.random.normal(key, (s, b, h, 4 * hd), jnp.float32) * 0.5
    wh = (jax.random.normal(jax.random.fold_in(key, 1),
                            (h, hd, 4 * hd)) * 0.2).astype(jnp.float32)
    z = jnp.zeros((b, h, hd), jnp.float32)
    m0 = jnp.full((b, h, hd), -1e30, jnp.float32)

    def loss_kernel(xg, wh):
        ys, _ = SK.slstm_scan_trainable(xg, wh, z, z, z, m0, True)
        return jnp.sum(ys ** 2)

    def loss_ref(xg, wh):
        ys, _ = ref.slstm_scan_ref(xg, wh, z, z, z, m0)
        return jnp.sum(ys ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1))(xg, wh)
    gr = jax.grad(loss_ref, argnums=(0, 1))(xg, wh)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# gather_resident_stacks: duplicate / out-of-range residency ids are pinned
# ---------------------------------------------------------------------------

def test_gather_resident_stacks_duplicates_and_oob():
    """Degenerate residency vectors have DEFINED semantics: duplicates
    duplicate the weight row; any id outside [0, library_size) resolves
    to the zero pseudo-class row (the slot serves exact zeros, like an
    empty slot) instead of whatever jax's gather clamping would pick."""
    from repro.analysis.jit_cache import assert_zero_retrace
    key = jax.random.PRNGKey(3)
    lib, d, d_h = 4, 8, 8
    ks = jax.random.split(key, 4)
    stacks = ops.prepad_switched_weights(
        jax.random.normal(ks[0], (lib, d, d_h)),
        jax.random.normal(ks[1], (lib, d_h)),
        jax.random.normal(ks[2], (lib, d_h, d)),
        jax.random.normal(ks[3], (lib, d)))

    # duplicates: both slots serve class 2's weights, deterministically
    dup = ops.gather_resident_stacks(*stacks,
                                     jnp.asarray([2, 2], jnp.int32))
    for full, got in zip(stacks, dup):
        assert got.shape[0] == 3                   # n_resident + pseudo
        np.testing.assert_array_equal(np.asarray(got[0]),
                                      np.asarray(full[2]))
        np.testing.assert_array_equal(np.asarray(got[1]),
                                      np.asarray(full[2]))
        # the trailing row stays the zero pseudo-class
        assert not np.any(np.asarray(got[-1]))

    # out of range on both sides (negative, == library_size, way past)
    for bad in ([-1, lib], [lib + 3, -7]):
        oob = ops.gather_resident_stacks(*stacks,
                                         jnp.asarray(bad, jnp.int32))
        for got in oob:
            assert not np.any(np.asarray(got[:2])), \
                f"OOB residency {bad} must serve the zero pseudo-class"

    # residency stays a TRACED input under the pinning
    fn = jax.jit(lambda r: ops.gather_resident_stacks(*stacks, r))
    for r in ([0, 1], [3, 3], [-1, 99]):
        fn(jnp.asarray(r, jnp.int32))
    assert_zero_retrace(fn, "a residency swap")
