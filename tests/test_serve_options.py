"""The consolidated serving API: ``ServeOptions`` construction, the
``from_args`` implication chain against the shared ``add_serve_options``
flag inventory, and the legacy-kwarg migration shim.

The migration contract this file pins:
  * ``DecodeServer(cfg, params, batch=...)`` (the historic kwarg form)
    still works, emits EXACTLY ONE ``DeprecationWarning``, and produces
    bit-identical tokens and drain stats to the ``options=`` spelling;
  * an unknown kwarg is a ``TypeError`` (not a silently-ignored option);
  * the three CLI surfaces share one flag inventory — a namespace from
    ``add_serve_options`` folds into a ``ServeOptions`` with the historic
    implications (qos/app/bounds -> tiers; tiers/autotune/library ->
    MCMA dispatch engine).
"""
import argparse
import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config, smoke_config
from repro.models import model as M
from repro.runtime.cli import add_serve_options
from repro.runtime.options import LibrarySpec, ServeOptions
from repro.runtime.server import DecodeServer, DrainStats, Request

jax.config.update("jax_platform_name", "cpu")


def _cfg(**approx_over):
    cfg = smoke_config(get_config("internlm2-1.8b"))
    if approx_over:
        cfg = dataclasses.replace(cfg, approx=dataclasses.replace(
            cfg.approx, **approx_over))
    return cfg


def _wave(cfg, n=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(1, cfg.vocab, 5)
                    .astype(np.int32), max_new=5) for i in range(n)]


def _drain(server, reqs):
    for r in reqs:
        server.submit(r)
    stats = server.run_until_drained(max_ticks=300)
    assert all(r.done for r in reqs)
    return stats, [list(r.out) for r in reqs]


def _parse(argv, **defaults):
    ap = argparse.ArgumentParser()
    add_serve_options(ap, **defaults)
    return ap.parse_args(argv)


# ---------------------------------------------------------------------------
# from_args: the implication chain
# ---------------------------------------------------------------------------

def test_from_args_defaults_match_field_defaults():
    o = ServeOptions.from_args(_parse([]))
    d = ServeOptions()
    # a bare parse reproduces a bare ServeOptions up to the CLI-side
    # defaults (the CLI turns chunked prefill on; the constructor's 0
    # keeps the historic token-granularity server)
    assert o == dataclasses.replace(d, batch=o.batch, max_len=o.max_len,
                                    prefill_chunk=16)
    assert o.use_mcma_dispatch is False and o.library is None


def test_from_args_qos_implies_tiers_and_dispatch():
    o = ServeOptions.from_args(_parse(["--qos"]))
    assert o.qos_tiers is True and o.use_mcma_dispatch
    o = ServeOptions.from_args(_parse(["--qos-app", "fft"]))
    assert o.qos_app == "fft" and o.qos_tiers is True
    o = ServeOptions.from_args(_parse(["--tier-bounds", "0.02,0.05,0.1"]))
    assert o.qos_tiers == (0.02, 0.05, 0.1) and o.use_mcma_dispatch


def test_from_args_autotune_implies_dispatch():
    o = ServeOptions.from_args(_parse(["--autotune"]))
    assert o.autotune is True and o.use_mcma_dispatch


def test_from_args_library_flags_build_spec():
    o = ServeOptions.from_args(_parse(["--library-size", "16",
                                       "--n-resident", "4"]))
    assert o.library == LibrarySpec(library_size=16, n_resident=4)
    assert o.use_mcma_dispatch
    # --n-resident defaults to min(4, library_size)
    o = ServeOptions.from_args(_parse(["--library-size", "2"]))
    assert o.library.n_resident == 2
    o = ServeOptions.from_args(_parse(["--library-size", "16"]))
    assert o.library.n_resident == 4
    # no --library-size: no spec, no implication
    o = ServeOptions.from_args(_parse(["--n-resident", "4"]))
    assert o.library is None and not o.use_mcma_dispatch


def test_from_args_overrides_win():
    o = ServeOptions.from_args(_parse(["--batch", "2"]), batch=32,
                               mesh="sentinel")
    assert o.batch == 32 and o.mesh == "sentinel"


def test_add_serve_options_rejects_unknown_default():
    ap = argparse.ArgumentParser()
    with pytest.raises((AssertionError, ValueError, TypeError)):
        add_serve_options(ap, not_a_flag=3)


def test_serve_options_frozen():
    o = ServeOptions()
    with pytest.raises(dataclasses.FrozenInstanceError):
        o.batch = 4


# ---------------------------------------------------------------------------
# the legacy-kwarg shim
# ---------------------------------------------------------------------------

def test_legacy_kwargs_bit_identical_to_options():
    cfg = _cfg(enable=True)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        legacy = DecodeServer(cfg, params, batch=3, max_len=48,
                              use_mcma_dispatch=True, prefill_chunk=4,
                              autotune=True, drop_budget=0.1)
    deps = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1, "legacy kwargs must warn EXACTLY once"
    assert "ServeOptions" in str(deps[0].message)

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        new = DecodeServer(cfg, params, options=ServeOptions(
            batch=3, max_len=48, use_mcma_dispatch=True, prefill_chunk=4,
            autotune=True, drop_budget=0.1))
    assert not [w for w in caught
                if issubclass(w.category, DeprecationWarning)], \
        "the options= spelling must NOT warn"

    assert legacy.options == new.options
    s_old, toks_old = _drain(legacy, _wave(cfg))
    s_new, toks_new = _drain(new, _wave(cfg))
    assert toks_old == toks_new, "legacy shim changed served tokens"
    for k in ("ticks", "prefill_ticks", "invocation_rate",
              "served_invocation_rate", "dropped_rows"):
        assert np.allclose(s_old[k], s_new[k]), k


def test_legacy_unknown_kwarg_is_type_error():
    cfg = _cfg()
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    with pytest.raises(TypeError, match="batchsize"):
        DecodeServer(cfg, params, batchsize=4)


def test_legacy_kwargs_layer_over_options():
    """Mixing options= with a legacy kwarg: the kwarg wins (replace)."""
    cfg = _cfg()
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("ignore")
        srv = DecodeServer(cfg, params,
                           options=ServeOptions(batch=2, max_len=32),
                           batch=3)
    assert srv.options.batch == 3 and srv.options.max_len == 32


def test_library_only_via_options():
    """The library feature is options-only — there is no legacy kwarg
    route into residency, so new-style users never see the warning."""
    from repro.runtime.server import _LEGACY_SERVE_KWARGS
    assert "library" not in _LEGACY_SERVE_KWARGS
    assert set(_LEGACY_SERVE_KWARGS) == {
        f.name for f in dataclasses.fields(ServeOptions)} - {"library"}


# ---------------------------------------------------------------------------
# DrainStats: the typed drain summary keeps its dict ergonomics
# ---------------------------------------------------------------------------

def test_drain_stats_mapping_protocol():
    s = DrainStats(ticks=7, wall_s=1.5)
    assert s["ticks"] == 7 and "ticks" in s
    assert "invocation_rate" not in s          # None fields are absent
    with pytest.raises(KeyError):
        s["invocation_rate"]
    s["invocation_rate"] = 0.25                # field write
    s["replay_wall_s"] = 2.0                   # unknown key -> extras
    assert s.invocation_rate == 0.25
    assert s["replay_wall_s"] == 2.0 and "replay_wall_s" in s
    d = s.asdict()
    assert d["ticks"] == 7 and d["replay_wall_s"] == 2.0
    assert "dropped_rows" not in d             # still-None fields skipped
    assert s.get("missing", "dflt") == "dflt"
    assert set(d) == set(dict(s.items()))
