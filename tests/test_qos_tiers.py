"""Per-request QoS tiers: an error bound threaded from ``Request`` through
routing, the ``DispatchPlan``, and the per-class-capacity autotune grid.

Pins, per the PR's acceptance criteria:
  * a UNIFORM default-tier batch is bit-for-bit identical to the
    margin-free engine — both backends, with and without ``row_mask``,
    at layer and tick scope (the tier plumbing is a pure widening);
  * a MIXED-tier batch is pallas == xla bit-for-bit on 1 device and on
    the 8-virtual-device (data, model) mesh (subprocess + in-process
    CI-leg variants), and the per-tier psum'd stats equal the
    single-device split exactly;
  * the per-tier stat split sums back to the totals, and a looser bound
    (more negative margin) buys strictly more served invocation than a
    tighter one in the same batch;
  * asymmetric per-class capacities (``invoke_cap`` tuples /
    ``OperatingPoint.invoke_fracs``) clamp each class at its own budget,
    and ``ladder_from_counts`` derives them from served class-count
    quantiles of a skewed mix;
  * ``DecodeServer`` validates ``Request.error_bound`` against the tier
    table (anchored on the apps-registry quality bound) at submit time —
    out-of-range fails loudly — and reports per-tier served invocation +
    dropped_frac in the drain summary.
"""
import dataclasses
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.jit_cache import assert_zero_retrace
from repro.configs.registry import get_config, smoke_config
from repro.models import model as M
from repro.runtime import autotune as AT
from repro.runtime import dispatch as D
from repro.runtime import steps as S

jax.config.update("jax_platform_name", "cpu")

_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"}

LEGACY_KEYS = ("class_counts", "dispatched", "dropped", "exact_frac",
               "invocation", "executed_rows", "padding_rows")


def _run(script: str) -> dict:
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=600, env=_ENV)
    assert r.returncode == 0, r.stderr[-2000:]
    return json.loads(r.stdout.split("RESULT")[1])


def _mk_case(key, t, n, d, d_h):
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (t, d), jnp.float32) * 0.5
    router = jax.random.normal(ks[1], (d, n + 1)) * 0.5
    w1 = jax.random.normal(ks[2], (n, d, d_h)) * 0.2
    b1 = jax.random.normal(ks[3], (n, d_h)) * 0.1
    w2 = jax.random.normal(ks[4], (n, d_h, d)) * 0.2
    b2 = jax.random.normal(ks[5], (n, d)) * 0.1
    wi = jax.random.normal(jax.random.fold_in(key, 7), (d, 2 * d)) * 0.1
    wo = jax.random.normal(jax.random.fold_in(key, 8), (2 * d, d)) * 0.1
    exact_fn = lambda xb: jnp.dot(jax.nn.silu(jnp.dot(xb, wi)), wo)
    return x, x @ router, (w1, b1, w2, b2), exact_fn


def _approx_cfg(**over):
    cfg = smoke_config(get_config("internlm2-1.8b"))
    return dataclasses.replace(cfg, approx=dataclasses.replace(
        cfg.approx, enable=True, **over))


def _mixed_tier(t, nt=3, seed=0):
    return jnp.asarray(np.random.default_rng(seed).integers(0, nt, t),
                       jnp.int32)


MARGINS = jnp.asarray([3.0, 0.0, -3.0])          # tight / base / loose


# ---------------------------------------------------------------------------
# uniform default tier == the margin-free engine, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("with_mask", [False, True])
def test_uniform_tier_engine_bitexact(backend, with_mask):
    """All rows on a zero-margin tier: output AND every legacy stat must
    be bit-identical to the engine without the tier arguments — even with
    nonzero margins parked at the unused tier indices."""
    t, n, d, d_h, block = 96, 3, 48, 16, 32
    x, logits, w, exact_fn = _mk_case(jax.random.PRNGKey(11), t, n, d, d_h)
    rm = (jnp.arange(t) % 5 != 0) if with_mask else None
    kw = dict(exact_cap=t // 2, invoke_cap=max(int(t * 0.3), 1),
              backend=backend, block_t=block, interpret=backend == "pallas")
    y0, s0 = D.mcma_dispatch(x, logits, exact_fn, *w, row_mask=rm, **kw)
    # every row on the BASE tier (index 1: margin 0.0) — the nonzero
    # margins parked at the unused tier indices must not matter
    y1, s1 = D.mcma_dispatch(x, logits, exact_fn, *w, row_mask=rm,
                             tier=jnp.ones((t,), jnp.int32),
                             tier_margins=MARGINS, **kw)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    for k in LEGACY_KEYS:
        np.testing.assert_array_equal(np.asarray(s0[k]), np.asarray(s1[k]))
    # the base tier carries everything; the unused tiers are exactly empty
    np.testing.assert_array_equal(np.asarray(s1["tier_counts"])[1],
                                  np.asarray(s1["class_counts"]))
    assert np.asarray(s1["tier_counts"])[0].sum() == 0
    assert np.asarray(s1["tier_counts"])[2].sum() == 0


@pytest.mark.parametrize("route_scope", ["layer", "tick"])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_uniform_tier_decode_step_bitexact(route_scope, backend):
    """The decode step with a uniform default-tier vector reproduces
    today's engine exactly, at both routing scopes and both backends."""
    kw = {} if backend == "xla" else dict(interpret=True, block_t=16)
    cfg = _approx_cfg(backend=backend, route_scope=route_scope, n_tiers=3,
                      **kw)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    b = 4
    cache = M.init_cache(cfg, b, 32)
    toks = jnp.arange(1, b + 1, dtype=jnp.int32)[:, None]
    mask = jnp.asarray([True, True, False, True])
    lg0, _, m0 = M.decode(cfg, params, cache, toks, serve=True,
                          collect_metrics=True, row_mask=mask)
    lg1, _, m1 = M.decode(cfg, params, cache, toks, serve=True,
                          collect_metrics=True, row_mask=mask,
                          tier=jnp.ones((b,), jnp.int32),
                          tier_margins=MARGINS)
    np.testing.assert_array_equal(np.asarray(lg0), np.asarray(lg1))
    np.testing.assert_array_equal(np.asarray(m0["class_counts"]),
                                  np.asarray(m1["class_counts"]))
    np.testing.assert_array_equal(np.asarray(m0["dispatched"]),
                                  np.asarray(m1["dispatched"]))


# ---------------------------------------------------------------------------
# mixed tiers: backend equivalence + the per-tier stat split
# ---------------------------------------------------------------------------

def test_mixed_tier_engine_pallas_matches_xla():
    t, n, d, d_h = 128, 3, 48, 16
    x, logits, w, exact_fn = _mk_case(jax.random.PRNGKey(3), t, n, d, d_h)
    tier = _mixed_tier(t)
    outs, stats = {}, {}
    for backend in ("xla", "pallas"):
        y, s = D.mcma_dispatch(
            x, logits, exact_fn, *w, exact_cap=t // 2,
            invoke_cap=max(int(t * 0.3), 1), backend=backend, block_t=32,
            interpret=backend == "pallas", tier=tier, tier_margins=MARGINS)
        outs[backend], stats[backend] = np.asarray(y), \
            jax.tree.map(np.asarray, s)
    np.testing.assert_array_equal(outs["pallas"], outs["xla"])
    for k in ("tier_counts", "tier_dispatched", "class_counts"):
        np.testing.assert_array_equal(stats["pallas"][k], stats["xla"][k])


def test_tier_split_sums_to_totals_and_is_monotone():
    """The per-tier matrices partition the totals exactly, and the loose
    tier serves strictly more invocation than the tight one."""
    t, n, d, d_h = 256, 3, 48, 16
    x, logits, w, exact_fn = _mk_case(jax.random.PRNGKey(7), t, n, d, d_h)
    tier = _mixed_tier(t)
    rm = jnp.arange(t) % 7 != 0
    _, s = D.mcma_dispatch(x, logits, exact_fn, *w, exact_cap=t // 2,
                           invoke_cap=max(int(t * 0.25), 1), backend="xla",
                           row_mask=rm, tier=tier, tier_margins=MARGINS)
    s = jax.tree.map(np.asarray, s)
    np.testing.assert_array_equal(s["tier_counts"].sum(0),
                                  s["class_counts"])
    np.testing.assert_array_equal(s["tier_dispatched"].sum(0),
                                  s["dispatched"])
    assert s["tier_dropped"].sum() == s["dropped"]
    served = s["tier_served_invocation"]
    assert served[2] > served[0], served
    # routed invocation is monotone across ALL tiers (margins 3 > 0 > -3)
    routed = s["tier_counts"][:, 1:].sum(-1) / s["tier_counts"].sum(-1)
    assert routed[0] < routed[1] < routed[2], routed


@pytest.mark.parametrize("route_scope", ["layer", "tick"])
def test_mixed_tier_decode_pallas_matches_xla(route_scope):
    b = 6
    tier = jnp.asarray([0, 1, 2, 2, 1, 0], jnp.int32)
    mask = jnp.asarray([True] * 5 + [False])
    params = M.init_model(jax.random.PRNGKey(0), _approx_cfg())
    outs, stats = {}, {}
    for be, kw in (("xla", {}),
                   ("pallas", dict(interpret=True, block_t=16))):
        cfg = _approx_cfg(backend=be, route_scope=route_scope, n_tiers=3,
                          **kw)
        cache = M.init_cache(cfg, b, 32)
        toks = jnp.arange(1, b + 1, dtype=jnp.int32)[:, None]
        lg, _, m = M.decode(cfg, params, cache, toks, serve=True,
                            collect_metrics=True, row_mask=mask,
                            tier=tier, tier_margins=MARGINS)
        outs[be], stats[be] = np.asarray(lg), jax.tree.map(np.asarray, m)
    np.testing.assert_array_equal(outs["pallas"], outs["xla"])
    np.testing.assert_array_equal(stats["pallas"]["tier_counts"],
                                  stats["xla"]["tier_counts"])
    # the masked slot (tier 0) is excluded from every tier's counts
    assert stats["xla"]["tier_counts"].sum() == 5


def test_tier_without_margins_or_n_tiers_fails_loudly():
    """A tier vector without a margins vector (or explicit n_tiers) must
    refuse, not silently drop tier>=1 rows from the per-tier stats."""
    t, n = 32, 2
    _, logits, _, _ = _mk_case(jax.random.PRNGKey(2), t, n, 32, 8)
    with pytest.raises(AssertionError, match="tier_margins"):
        D.make_dispatch_plan(logits, exact_cap=16, invoke_cap=8,
                             tier=_mixed_tier(t))
    # either escape hatch works
    p1 = D.make_dispatch_plan(logits, exact_cap=16, invoke_cap=8,
                              tier=_mixed_tier(t), n_tiers=3)
    p2 = D.make_dispatch_plan(logits, exact_cap=16, invoke_cap=8,
                              tier=_mixed_tier(t),
                              tier_margins=jnp.zeros((3,)))
    assert p1.n_tiers == p2.n_tiers == 3
    np.testing.assert_array_equal(np.asarray(p1.tier_counts),
                                  np.asarray(p2.tier_counts))


def test_tier_margins_are_traced_not_static():
    """One jitted program must serve every margin setting (and tier mix):
    the margins vector is an input, never a recompile trigger."""
    t, n, d, d_h = 64, 2, 32, 8
    x, logits, w, exact_fn = _mk_case(jax.random.PRNGKey(5), t, n, d, d_h)
    fn = jax.jit(lambda tr, tm: D.mcma_dispatch(
        x, logits, exact_fn, *w, exact_cap=t // 2, invoke_cap=t // 3,
        backend="xla", tier=tr, tier_margins=tm))
    invs = []
    for m in ([8.0, 0.0, -8.0], [0.0, 0.0, 0.0], [-8.0, 0.0, 8.0]):
        _, s = fn(_mixed_tier(t), jnp.asarray(m))
        invs.append(float(s["invocation"]))
    assert_zero_retrace(fn, "a margins change")
    # flipping the margins must actually change the routing
    assert invs[0] != invs[2]


# ---------------------------------------------------------------------------
# asymmetric per-class capacities
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_per_class_caps_clamp_each_class(backend):
    t, n, d, d_h = 128, 3, 48, 16
    x, logits, w, exact_fn = _mk_case(jax.random.PRNGKey(13), t, n, d, d_h)
    caps = (4, 40, 17)
    y, s = D.mcma_dispatch(x, logits, exact_fn, *w, exact_cap=t // 2,
                           invoke_cap=caps, backend=backend, block_t=32,
                           interpret=backend == "pallas")
    s = jax.tree.map(np.asarray, s)
    np.testing.assert_array_equal(
        s["dispatched"][1:], np.minimum(s["class_counts"][1:], caps))
    # executed capacity reflects the asymmetric budgets on the oracle
    if backend == "xla":
        assert int(s["executed_rows"]) == t // 2 + sum(caps)


def test_per_class_caps_pallas_matches_xla():
    t, n, d, d_h = 96, 3, 48, 16
    x, logits, w, exact_fn = _mk_case(jax.random.PRNGKey(17), t, n, d, d_h)
    outs = {}
    for backend in ("xla", "pallas"):
        y, _ = D.mcma_dispatch(x, logits, exact_fn, *w, exact_cap=t // 2,
                               invoke_cap=(3, 29, 11), backend=backend,
                               block_t=32, interpret=backend == "pallas")
        outs[backend] = np.asarray(y)
    np.testing.assert_array_equal(outs["pallas"], outs["xla"])


def test_uniform_tuple_caps_equal_scalar_cap():
    t, n, d, d_h = 80, 2, 32, 8
    x, logits, w, exact_fn = _mk_case(jax.random.PRNGKey(19), t, n, d, d_h)
    y1, s1 = D.mcma_dispatch(x, logits, exact_fn, *w, exact_cap=40,
                             invoke_cap=24, backend="xla")
    y2, s2 = D.mcma_dispatch(x, logits, exact_fn, *w, exact_cap=40,
                             invoke_cap=(24, 24), backend="xla")
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    np.testing.assert_array_equal(np.asarray(s1["dispatched"]),
                                  np.asarray(s2["dispatched"]))


def test_plan_from_asymmetric_operating_point():
    t, n = 80, 2
    _, logits, _, _ = _mk_case(jax.random.PRNGKey(23), t, n, 32, 8)
    pt = AT.OperatingPoint(0.5, 0.3, invoke_fracs=(0.3, 0.1))
    plan = D.make_dispatch_plan(logits, operating_point=pt)
    from repro.sharding.rules import shard_capacity
    assert plan.class_caps == (shard_capacity(t, 0.3),
                               shard_capacity(t, 0.1))
    assert pt.cost(n) == pytest.approx(0.5 + 0.3 + 0.1)


# ---------------------------------------------------------------------------
# ladder_from_counts: asymmetric rungs from a skewed served mix
# ---------------------------------------------------------------------------

def test_ladder_from_counts_skewed_mix():
    """A heavy-tailed mix (class 1 hot, class 2 cold) must yield rungs
    whose per-class fractions track the per-class quantiles — hot class
    gets capacity, cold class stops paying for uniform padding."""
    rng = np.random.default_rng(0)
    ticks, t, n = 64, 256, 3
    hot = rng.normal(150, 12, ticks).clip(0)        # class 1: hot
    mid = rng.normal(40, 8, ticks).clip(0)          # class 2: mid
    cold = rng.normal(6, 2, ticks).clip(0)          # class 3: cold tail
    exact = (t - hot - mid - cold).clip(0)
    counts = np.stack([exact, hot, mid, cold], -1)
    ladder = AT.ladder_from_counts(counts, t)
    assert len(ladder) >= 2
    # every derived rung (bar the escape hatch) is asymmetric: hot >> cold
    for pt in ladder[:-1]:
        assert pt.invoke_fracs[0] > pt.invoke_fracs[1] \
            > pt.invoke_fracs[2], pt
    # cost-ordered with the full-capacity escape rung last
    costs = [pt.cost(n) for pt in ladder]
    assert costs == sorted(costs)
    assert ladder[-1] == AT.OperatingPoint(1.0, 1.0,
                                           invoke_fracs=(1.0,) * n)
    # the mid rung covers median demand without uniform over-provisioning:
    # strictly cheaper than the uniform ladder sized for the hot class
    uniform_cost = (0.5 + n * (np.quantile(hot, 0.5) * 1.1 / t))
    assert ladder[0].cost(n) < uniform_cost
    # replaying the served counts against the top derived (non-escape)
    # rung stays under a 5% drop budget
    caps = AT.point_caps(ladder[-2], t, n)
    drops = np.maximum(counts - caps, 0).sum()
    assert drops / counts.sum() < 0.05


def test_ladder_from_counts_single_observation_and_controller():
    counts = np.asarray([100.0, 140.0, 10.0, 6.0])
    ladder = AT.ladder_from_counts(counts, 256)
    ctrl = AT.CapacityController(
        ladder, lambda pt: AT.point_caps(pt, 256, 3), drop_budget=0.05)
    idx = ctrl.observe({"class_counts": counts, "dropped": 0.0})
    assert 0 <= idx < len(ladder)


def test_margins_and_default_bounds():
    bounds = AT.default_tier_bounds(0.10)
    assert bounds == (0.05, 0.10, 0.20)
    m = AT.margins_from_bounds(bounds, 0.10, scale=4.0)
    assert m[1] == pytest.approx(0.0)                   # zero at the base
    assert m[0] > 0 > m[2]                              # tight > 0 > loose
    assert m[0] == pytest.approx(-m[2])                 # symmetric spread
    assert list(m) == sorted(m, reverse=True)           # monotone in bound


# ---------------------------------------------------------------------------
# server: submit-time validation + per-tier drain summary
# ---------------------------------------------------------------------------

def _server(**kw):
    from repro.runtime.server import DecodeServer
    cfg = _approx_cfg()
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    return DecodeServer(cfg, params, batch=4, max_len=64,
                        use_mcma_dispatch=True, **kw)


def test_submit_validates_error_bound():
    from repro.runtime.server import Request
    srv = _server(qos_tiers=(0.05, 0.10, 0.20))
    mk = lambda **kw: Request(rid=0, prompt=np.ones(3, np.int32), **kw)
    with pytest.raises(ValueError, match="tighter than the tightest"):
        srv.submit(mk(error_bound=0.01))
    with pytest.raises(ValueError, match="positive finite"):
        srv.submit(mk(error_bound=-0.1))
    with pytest.raises(ValueError, match="out of range"):
        srv.submit(mk(tier=7))
    # quantization: served at-or-tighter than asked, clamped to loosest
    for eb, want in ((0.05, 0), (0.07, 0), (0.10, 1), (0.15, 1),
                     (0.20, 2), (0.9, 2)):
        r = mk(error_bound=eb)
        srv.submit(r)
        assert r.tier == want, (eb, r.tier)


def test_submit_without_tier_table_fails_loudly():
    from repro.runtime.server import Request
    srv = _server()
    with pytest.raises(ValueError, match="no tier table"):
        srv.submit(Request(rid=0, prompt=np.ones(3, np.int32),
                           error_bound=0.1))


def test_qos_app_anchors_tier_table():
    """``qos_app`` pulls the quality.py bound from the apps registry: the
    tier table brackets it and the validation message names the app."""
    from repro.apps.registry import get_app
    from repro.runtime.server import Request
    srv = _server(qos_app="bessel")
    base = get_app("bessel").error_bound
    assert srv.tier_bounds == AT.default_tier_bounds(base)
    assert srv.tier_margins[1] == pytest.approx(0.0)
    with pytest.raises(ValueError, match="bessel"):
        srv.submit(Request(rid=0, prompt=np.ones(3, np.int32),
                           error_bound=base / 100))


@pytest.mark.parametrize("route_scope", ["layer", "tick"])
def test_server_mixed_tier_drain_summary(route_scope):
    from repro.runtime.server import Request
    srv = _server(qos_tiers=(0.05, 0.10, 0.20), route_scope=route_scope)
    rng = np.random.default_rng(0)
    bounds = [0.05, 0.10, 0.25, None]
    reqs = [Request(rid=i, prompt=rng.integers(0, srv.cfg.vocab, 5)
                    .astype(np.int32), max_new=4,
                    error_bound=bounds[i % len(bounds)])
            for i in range(6)]
    for r in reqs:
        srv.submit(r)
    stats = srv.run_until_drained(max_ticks=300)
    assert all(r.done for r in reqs)
    per = stats["per_tier"]
    assert [p["tier"] for p in per] == [0, 1, 2]
    assert [p["error_bound"] for p in per] == [0.05, 0.10, 0.20]
    # every active row is attributed to exactly one tier
    assert sum(p["rows"] for p in per) == pytest.approx(srv.active_sum)
    for p in per:
        assert 0.0 <= p["served_invocation_rate"] <= 1.0
        assert 0.0 <= p["dropped_frac"] <= 1.0
        assert p["rows"] > 0          # the wave hit every tier
    # tight margins bias to exact: tier 0 must not out-invoke tier 2
    assert per[0]["served_invocation_rate"] \
        <= per[2]["served_invocation_rate"] + 1e-9
    # the served-count history feeds the ladder autotuner
    ladder = srv.derived_ladder()
    assert ladder[-1].exact_frac == 1.0
    assert all(len(pt.invoke_fracs) == srv.cfg.approx.n_approx
               for pt in ladder)


# ---------------------------------------------------------------------------
# mesh: mixed tiers on 8 virtual devices, plan built and consumed sharded
# ---------------------------------------------------------------------------

_TIER_MESH = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs.registry import get_config, smoke_config
    from repro.models import model as M
    from repro.sharding import activations as A

    def cfg_with(backend, scope):
        cfg = smoke_config(get_config("internlm2-1.8b"))
        return dataclasses.replace(cfg, approx=dataclasses.replace(
            cfg.approx, enable=True, backend=backend, interpret=True,
            block_t=16, route_scope=scope, n_tiers=3))

    B = 8
    tier = jnp.asarray([0, 1, 2, 2, 1, 0, 1, 2], jnp.int32)
    margins = jnp.asarray([3.0, 0.0, -3.0])
    mask = jnp.asarray([True] * 6 + [False] * 2)
    toks = jnp.arange(1, B + 1, dtype=jnp.int32)[:, None]
    params = M.init_model(jax.random.PRNGKey(0), cfg_with("xla", "tick"))
    out = {}
    for scope in ("layer", "tick"):
        cfg = cfg_with("xla", scope)
        cache = M.init_cache(cfg, B, 32)
        # single-device reference: the psum'd per-tier mesh stats must
        # equal this split exactly (routing is row-wise)
        _, _, m1 = M.decode(cfg, params, cache, toks, serve=True,
                            collect_metrics=True, row_mask=mask,
                            tier=tier, tier_margins=margins)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        outs, tcs = {}, {}
        for backend in ("xla", "pallas"):
            c = cfg_with(backend, scope)
            with mesh, A.activation_sharding(P(("data",), None, None)):
                lg, _, m = jax.jit(lambda p, ca, t, rm, tr, tm, c_=c:
                    M.decode(c_, p, ca, t, serve=True, collect_metrics=True,
                             row_mask=rm, tier=tr, tier_margins=tm))(
                    params, cache, toks, mask, tier, margins)
            outs[backend] = np.asarray(lg)
            tcs[backend] = np.asarray(m["tier_counts"]).tolist()
        out[scope] = {
            "pallas_bitexact_vs_xla": bool(np.array_equal(outs["pallas"],
                                                          outs["xla"])),
            "tier_counts": tcs,
            "single_tier_counts": np.asarray(m1["tier_counts"]).tolist(),
            "rows": float(np.asarray(m1["tier_counts"]).sum()),
        }
    print("RESULT" + json.dumps(out))
""")


def test_mixed_tier_mesh_subprocess_8_virtual_devices():
    out = _run(_TIER_MESH)
    for scope in ("layer", "tick"):
        o = out[scope]
        assert o["pallas_bitexact_vs_xla"], scope
        # both backends agree on the psum'd per-tier split on the mesh
        assert o["tier_counts"]["pallas"] == o["tier_counts"]["xla"], scope
        assert o["rows"] == 6.0          # active rows only
    # tick scope routes ONCE from the (drift-free) embedding, so its
    # psum'd per-tier mesh stats equal the single-device split EXACTLY;
    # layer scope's deeper layers see TP-psum rounding in the hidden
    # state, so only the within-mesh backend equality above is pinned
    o = out["tick"]
    for be in ("xla", "pallas"):
        assert o["tier_counts"][be] == o["single_tier_counts"], be


needs_8_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (CI multidevice leg: XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")


@needs_8_devices
@pytest.mark.parametrize("route_scope", ["layer", "tick"])
def test_mixed_tier_mesh_inprocess(route_scope):
    """CI multidevice leg: a mixed-tier batch on the (4, 2) mesh — pallas
    == xla bit-for-bit; at tick scope (routed once, from the drift-free
    embedding) the per-tier psum stats == the single-device split."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding import activations as A
    b = 8
    tier = jnp.asarray([0, 1, 2, 2, 1, 0, 1, 2], jnp.int32)
    mask = jnp.asarray([True] * 6 + [False] * 2)
    toks = jnp.arange(1, b + 1, dtype=jnp.int32)[:, None]
    params = M.init_model(jax.random.PRNGKey(0), _approx_cfg())
    cfg1 = _approx_cfg(route_scope=route_scope, n_tiers=3)
    cache = M.init_cache(cfg1, b, 32)
    _, _, m1 = M.decode(cfg1, params, cache, toks, serve=True,
                        collect_metrics=True, row_mask=mask, tier=tier,
                        tier_margins=MARGINS)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    outs, tcs = {}, {}
    for be in ("xla", "pallas"):
        c = _approx_cfg(backend=be, interpret=True, block_t=16,
                        route_scope=route_scope, n_tiers=3)
        with mesh, A.activation_sharding(P(("data",), None, None)):
            lg, _, m = jax.jit(lambda p, ca, t, rm, tr, tm, c_=c: M.decode(
                c_, p, ca, t, serve=True, collect_metrics=True,
                row_mask=rm, tier=tr, tier_margins=tm))(
                params, cache, toks, mask, tier, MARGINS)
        outs[be] = np.asarray(lg)
        tcs[be] = np.asarray(m["tier_counts"])
        assert float(tcs[be].sum()) == 6.0      # active rows only
        if route_scope == "tick":
            # per-tier ROUTED counts are sharding-invariant (row-wise
            # routing); dispatched counts are not — per-shard capacities
            # may drop rows a whole-batch budget would keep
            np.testing.assert_array_equal(tcs[be],
                                          np.asarray(m1["tier_counts"]))
        assert (np.asarray(m["tier_dispatched"]) <= tcs[be]).all()
    np.testing.assert_array_equal(outs["pallas"], outs["xla"])
    np.testing.assert_array_equal(tcs["pallas"], tcs["xla"])


def test_sharded_engine_mixed_tier_psum_equals_single_device():
    """mcma_dispatch_sharded with tiers: global per-tier stats == the
    single-device run over the same rows, exactly (subprocess, 8 virtual
    devices)."""
    out = _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.runtime import dispatch as D

        T, N, DM, DH = 128, 3, 32, 8
        ks = jax.random.split(jax.random.PRNGKey(1), 6)
        x = jax.random.normal(ks[0], (T, DM))
        router = jax.random.normal(ks[1], (DM, N + 1)) * 0.5
        w = [jax.random.normal(k, s) * 0.2 for k, s in zip(
            ks[2:], [(N, DM, DH), (N, DH), (N, DH, DM), (N, DM)])]
        wi = jax.random.normal(jax.random.fold_in(ks[0], 1), (DM, DM))
        wo = jax.random.normal(jax.random.fold_in(ks[0], 2), (DM, DM))
        exact_fn = lambda ep, xb: jnp.dot(jnp.dot(xb, ep[0]), ep[1])
        lg = x @ router
        tier = jnp.asarray(np.random.default_rng(0).integers(0, 3, T),
                           jnp.int32)
        margins = jnp.asarray([2.0, 0.0, -2.0])
        _, s1 = D.mcma_dispatch(x, lg, lambda xb: exact_fn((wi, wo), xb),
                                *w, exact_cap=T // 2, invoke_cap=T // 8,
                                backend="xla", tier=tier,
                                tier_margins=margins)
        mesh = jax.make_mesh((8,), ("data",))
        _, s8 = D.mcma_dispatch_sharded(
            mesh, x, lg, exact_fn, (wi, wo), *w, exact_cap=T // 16,
            invoke_cap=T // 64, backend="xla", tier=tier,
            tier_margins=margins)
        print("RESULT" + json.dumps({
            "single_tc": np.asarray(s1["tier_counts"]).tolist(),
            "mesh_tc": np.asarray(s8["tier_counts"]).tolist(),
            "mesh_rows": float(np.asarray(s8["tier_counts"]).sum()),
        }))
    """))
    # routing is row-wise: the psum'd per-tier ROUTED counts are identical
    # to the single-device split no matter how the batch is sharded
    assert out["mesh_tc"] == out["single_tc"]
    assert out["mesh_rows"] == 128.0
