"""Paged KV cache: block-table serving memory vs the dense oracle.

Pins, per the PR's acceptance criteria:
  * paged == dense bit-for-bit greedy tokens — token-by-token AND
    chunked prefill, on one device and on the (data=2, model=4) mesh
    (subprocess with 8 forced virtual devices);
  * page-boundary writes: prompt lengths straddling ``page_size``
    (P-1, P, P+1, 2P+1) land inside the right pages;
  * the allocator's lifecycle: lazy acquisition as ``pos`` crosses page
    boundaries, release on finish AND on abort/strand (``pages_in_use``
    returns to 0, the free list is whole again);
  * a constrained pool (kv_pages < batch * max_len/page_size) defers
    admission (``alloc_failures`` counts the pressure) but still serves
    every request bit-identically — worst-case reservation at admit
    means lazy growth can never deadlock;
  * pool overflow is LOUD: a request that could never be scheduled is a
    submit-time ValueError, and one injected past submit() is aborted at
    admission instead of clamp-corrupting the pool;
  * page allocation/free churn never retraces the decode or chunk step
    (the block table is a same-shape traced leaf refreshed per tick);
  * MCMA dispatch invoke stats are identical to dense (the cache layout
    is invisible to routing);
  * memory: a mixed-length workload's ``kv_bytes_resident`` is strictly
    below the dense worst case when ``max_len`` overshoots the typical
    request (the whole point of paging).
"""
import dataclasses
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.jit_cache import assert_zero_retrace
from repro.configs.registry import get_config, smoke_config
from repro.models import model as M
from repro.runtime.options import LibrarySpec, ServeOptions
from repro.runtime.server import DecodeServer, Request

jax.config.update("jax_platform_name", "cpu")

_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu",
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}


def _cfg(**over):
    cfg = smoke_config(get_config("internlm2-1.8b"))
    return dataclasses.replace(cfg, approx=dataclasses.replace(
        cfg.approx, enable=True, exact_frac=1.0, invoke_frac=1.0, **over))


_PARAMS = {}


def _params(cfg):
    key = (cfg.approx.exact_frac, cfg.approx.library_size)
    if key not in _PARAMS:
        _PARAMS[key] = M.init_model(jax.random.PRNGKey(0), cfg)
    return _PARAMS[key]


def _boundary_requests(vocab, seed=0, max_new=6):
    """Prompt lengths straddling page_size=8: P-1, P, P+1, 2P+1, plus
    short/long fillers so slots churn through alloc/free cycles."""
    rng = np.random.default_rng(seed)
    lens = (7, 8, 9, 17, 3, 25, 12, 31, 5)
    return [Request(rid=i, prompt=rng.integers(1, vocab, n).astype(np.int32),
                    max_new=max_new)
            for i, n in enumerate(lens)]


def _serve(cfg, reqs, **kw):
    base = dict(batch=4, max_len=64, admission="fifo")
    base.update(kw)
    srv = DecodeServer(cfg, _params(cfg), options=ServeOptions(**base))
    for r in reqs:
        srv.submit(r)
    stats = srv.run_until_drained(2000)
    return srv, stats


# ---------------------------------------------------------------------------
# cache construction + slot reset units
# ---------------------------------------------------------------------------

def test_init_cache_paged_layout():
    cfg = _cfg()
    L = cfg.n_layers
    kh = cfg.n_kv_heads or cfg.n_heads
    hd = cfg.hd
    c = M.init_cache(cfg, 4, 64, page_size=8, kv_pages=10)
    assert c["k"].shape == (L, 10, 8, kh, hd)
    assert c["v"].shape == c["k"].shape
    assert c["block_table"].shape == (4, 8)         # max_len // page_size
    assert c["block_table"].dtype == jnp.int32
    assert (np.asarray(c["block_table"]) == -1).all()
    assert c["pos"].shape == (4,)
    with pytest.raises(AssertionError):
        M.init_cache(cfg, 4, 64, page_size=7, kv_pages=10)   # 7 ∤ 64


def test_reset_slot_clears_block_table_row_only():
    cfg = _cfg()
    c = M.init_cache(cfg, 3, 32, page_size=8, kv_pages=6)
    fresh = M.init_cache(cfg, 3, 32, page_size=8, kv_pages=6)
    c = dict(c)
    c["block_table"] = jnp.asarray(
        [[0, 1, -1, -1], [2, 3, 4, -1], [5, -1, -1, -1]], jnp.int32)
    c["k"] = c["k"] + 1.0                       # pool contents are SHARED
    c2 = M.reset_slot(cfg, c, fresh, 1)
    bt = np.asarray(c2["block_table"])
    assert (bt[1] == -1).all()                  # the reset slot's row
    assert (bt[0] == [0, 1, -1, -1]).all()      # neighbours untouched
    assert (bt[2] == [5, -1, -1, -1]).all()
    # pools must NOT be zeroed: other slots' pages live there
    np.testing.assert_array_equal(np.asarray(c2["k"]), np.asarray(c["k"]))
    assert int(c2["pos"][1]) == 0


# ---------------------------------------------------------------------------
# bit-exactness vs the dense oracle (single device)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [0, 8], ids=["token", "chunked"])
def test_paged_matches_dense_bitexact(chunk):
    """Page-boundary-straddling prompts, both prefill modes: identical
    greedy tokens, every page returned at drain."""
    cfg = _cfg()
    a = _boundary_requests(cfg.vocab)
    b = _boundary_requests(cfg.vocab)
    _, st_d = _serve(cfg, a, prefill_chunk=chunk)
    srv_p, st_p = _serve(cfg, b, prefill_chunk=chunk, kv_page_size=8)
    assert all(r.done and not r.aborted for r in a + b)
    for ra, rb in zip(a, b):
        assert ra.out == rb.out, (ra.rid, ra.out, rb.out)
    assert st_p["pages_in_use"] == 0
    assert len(srv_p._free_pages) == srv_p.n_pages
    assert st_p["page_hwm"] > 0
    assert st_p["kv_bytes_resident"] <= st_d["kv_bytes_resident"]


def test_paged_page_sizes_agree():
    """Two page sizes and the dense oracle all sample the same tokens —
    the layout is invisible to the math."""
    cfg = _cfg()
    outs = []
    for kw in (dict(), dict(kv_page_size=8), dict(kv_page_size=16)):
        reqs = _boundary_requests(cfg.vocab)
        _serve(cfg, reqs, prefill_chunk=8, **kw)
        outs.append([r.out for r in reqs])
    assert outs[0] == outs[1] == outs[2]


def test_paged_mcma_dispatch_stats_identical():
    """Routing/invocation accounting cannot see the cache layout."""
    cfg = _cfg()
    a = _boundary_requests(cfg.vocab)
    b = _boundary_requests(cfg.vocab)
    kw = dict(prefill_chunk=8, use_mcma_dispatch=True, route_scope="tick",
              backend="xla")
    _, st_d = _serve(cfg, a, **kw)
    _, st_p = _serve(cfg, b, kv_page_size=8, **kw)
    for ra, rb in zip(a, b):
        assert ra.out == rb.out
    assert st_d["invocation_rate"] == st_p["invocation_rate"]
    assert st_d["routed_per_class"] == st_p["routed_per_class"]
    assert st_d["prefill_invocation_rate"] == st_p["prefill_invocation_rate"]


def test_paged_library_residency_matches_dense():
    """Paged cache under the approximator-library engine: swaps and
    pages churn independently, tokens stay the dense oracle's."""
    cfg = _cfg(library_size=6)
    lib = LibrarySpec(library_size=6, n_resident=2, observe_window=2,
                      cooldown=2)
    a = _boundary_requests(cfg.vocab)
    b = _boundary_requests(cfg.vocab)
    kw = dict(prefill_chunk=4, use_mcma_dispatch=True, backend="xla",
              library=lib)
    _, st_d = _serve(cfg, a, **kw)
    _, st_p = _serve(cfg, b, kv_page_size=8, **kw)
    assert all(r.done for r in a + b)
    for ra, rb in zip(a, b):
        assert ra.out == rb.out, (ra.rid, ra.out, rb.out)
    assert st_d["lib_routed_per_class"] == st_p["lib_routed_per_class"]


# ---------------------------------------------------------------------------
# allocator lifecycle: constrained pool, exhaustion, abort/strand release
# ---------------------------------------------------------------------------

def test_constrained_pool_defers_admission_but_serves_all():
    """kv_pages far below batch * pages_per_slot: admission head-of-line
    blocks under pool pressure (alloc_failures counts it), every request
    still finishes with the dense oracle's tokens, and the pool drains
    back to empty."""
    cfg = _cfg()
    a = _boundary_requests(cfg.vocab)
    b = _boundary_requests(cfg.vocab)
    _, _ = _serve(cfg, a, prefill_chunk=8)
    srv, st = _serve(cfg, b, prefill_chunk=8, kv_page_size=8, kv_pages=12)
    assert all(r.done and not r.aborted for r in b)
    for ra, rb in zip(a, b):
        assert ra.out == rb.out
    assert st["alloc_failures"] > 0          # the pool really was tight
    assert st["page_hwm"] <= 12
    assert st["pages_in_use"] == 0
    assert sorted(srv._free_pages) == list(range(srv.n_pages))


def test_pool_overflow_rejected_at_submit():
    cfg = _cfg()
    srv = DecodeServer(cfg, _params(cfg), options=ServeOptions(
        batch=2, max_len=64, prefill_chunk=8, kv_page_size=8, kv_pages=4))
    # needs ceil((30+6)/8) = 5 pages > 4 in the whole pool
    with pytest.raises(ValueError, match="KV pages"):
        srv.submit(Request(rid=0, prompt=np.ones(30, np.int32), max_new=6))
    assert not srv.queue
    # the boundary case fits exactly (4 pages) and is served
    r = Request(rid=1, prompt=np.ones(26, np.int32), max_new=6)
    srv.submit(r)
    st = srv.run_until_drained(500)
    assert r.done and len(r.out) == 6
    assert st["pages_in_use"] == 0


def test_injected_never_fits_request_aborted_at_admit():
    """A request injected past submit() validation must not wedge the
    admission loop: it is aborted when picked, its (zero) pages freed,
    and the queue keeps draining."""
    cfg = _cfg()
    srv = DecodeServer(cfg, _params(cfg), options=ServeOptions(
        batch=1, max_len=64, prefill_chunk=8, kv_page_size=8, kv_pages=4))
    bad = Request(rid=0, prompt=np.ones(30, np.int32), max_new=6)
    good = Request(rid=1, prompt=np.ones(5, np.int32), max_new=4)
    srv.queue.append(bad)                    # straight past validation
    srv.submit(good)
    st = srv.run_until_drained(500)
    assert bad.aborted and not bad.out
    assert good.done and len(good.out) == 4
    assert st["pages_in_use"] == 0
    assert st["undrained_queued"] == st["undrained_inflight"] == 0


def test_pages_released_on_abort_and_strand():
    """The free-on-abort satellite: an unservable injected prompt is
    released mid-flight, and requests stranded at max_ticks exhaustion
    hand their pages back in run_until_drained — pages_in_use returns to
    0 either way (the dense window merely lingered; a page leak would
    starve the pool)."""
    cfg = _cfg()
    # (a) injected overflow aborts AFTER admission (prompt fits pages but
    # not max_len): the tick pre-write abort path must release
    srv = DecodeServer(cfg, _params(cfg), options=ServeOptions(
        batch=1, max_len=32, prefill_chunk=0, kv_page_size=8))
    bad = Request(rid=0, prompt=np.ones(40, np.int32), max_new=4)
    good = Request(rid=1, prompt=np.ones(5, np.int32), max_new=4)
    srv.queue.append(bad)
    srv.submit(good)
    st = srv.run_until_drained(500)
    assert bad.aborted and good.done
    assert st["pages_in_use"] == 0
    assert sorted(srv._free_pages) == list(range(srv.n_pages))
    # (b) stranded at tick exhaustion: pages still come back
    srv2 = DecodeServer(cfg, _params(cfg), options=ServeOptions(
        batch=1, max_len=32, prefill_chunk=0, kv_page_size=8))
    r = Request(rid=0, prompt=np.ones(10, np.int32), max_new=20)
    srv2.submit(r)
    st2 = srv2.run_until_drained(3)          # nowhere near enough ticks
    assert r.aborted and not r.done
    assert st2["undrained_inflight"] == 1
    assert st2["pages_in_use"] == 0
    assert sorted(srv2._free_pages) == list(range(srv2.n_pages))


# ---------------------------------------------------------------------------
# zero-retrace across allocation churn
# ---------------------------------------------------------------------------

def test_alloc_free_churn_never_retraces():
    """9 requests through 4 slots = multiple alloc/free cycles per slot
    with ever-different block-table contents; the decode and chunk steps
    must each have compiled exactly one program."""
    cfg = _cfg()
    reqs = _boundary_requests(cfg.vocab)
    srv, st = _serve(cfg, reqs, prefill_chunk=8, kv_page_size=8,
                     kv_pages=12)
    assert all(r.done for r in reqs)
    assert st["ticks"] > 10
    assert_zero_retrace(srv.decode, "page allocation/free churn")
    assert_zero_retrace(srv.chunk, "page allocation/free churn (chunk)")


# ---------------------------------------------------------------------------
# the (data=2, model=4) mesh, via subprocess (8 forced virtual devices)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = textwrap.dedent("""
    import dataclasses, json
    import numpy as np
    import jax
    jax.config.update("jax_platform_name", "cpu")
    from repro.configs.registry import get_config, smoke_config
    from repro.launch.mesh import make_host_mesh
    from repro.models import model as M
    from repro.runtime.options import ServeOptions
    from repro.runtime.server import DecodeServer, Request

    cfg = smoke_config(get_config("internlm2-1.8b"))
    cfg = dataclasses.replace(cfg, approx=dataclasses.replace(
        cfg.approx, enable=True, exact_frac=1.0, invoke_frac=1.0))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    mesh = make_host_mesh(data=2, model=4)
    out = {}
    for page in (0, 8):
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i,
                        prompt=rng.integers(1, cfg.vocab, n)
                        .astype(np.int32), max_new=4)
                for i, n in enumerate((7, 8, 9, 17, 25))]
        srv = DecodeServer(cfg, params, options=ServeOptions(
            batch=2, max_len=64, use_mcma_dispatch=True,
            route_scope="tick", mesh=mesh, prefill_chunk=8,
            admission="fifo", kv_page_size=page))
        for r in reqs:
            srv.submit(r)
        stats = srv.run_until_drained(500)
        out[str(page)] = {
            "tokens": {r.rid: r.out for r in reqs},
            "done": all(r.done for r in reqs),
            "pages_in_use": stats.get("pages_in_use"),
            "invocation_rate": stats["invocation_rate"],
        }
    print("RESULT" + json.dumps(out))
""")


def test_paged_matches_dense_on_mesh_subprocess():
    r = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                       capture_output=True, text=True, timeout=900,
                       env=_ENV)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.split("RESULT")[1])
    assert out["0"]["done"] and out["8"]["done"]
    assert out["0"]["tokens"] == out["8"]["tokens"]
    assert out["8"]["pages_in_use"] == 0
    assert out["0"]["invocation_rate"] == out["8"]["invocation_rate"]


needs_8_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8); covered by the CI multidevice leg")


@needs_8_devices
def test_paged_matches_dense_on_mesh_inprocess():
    """CI multidevice leg: same equality without the subprocess."""
    from repro.launch.mesh import make_host_mesh
    cfg = _cfg()
    mesh = make_host_mesh(data=2, model=4)
    a = _boundary_requests(cfg.vocab, max_new=4)
    b = _boundary_requests(cfg.vocab, max_new=4)
    _, st_d = _serve(cfg, a, batch=2, prefill_chunk=8, mesh=mesh,
                     use_mcma_dispatch=True, route_scope="tick")
    srv_p, st_p = _serve(cfg, b, batch=2, prefill_chunk=8, mesh=mesh,
                         use_mcma_dispatch=True, route_scope="tick",
                         kv_page_size=8)
    assert all(r.done for r in a + b)
    for ra, rb in zip(a, b):
        assert ra.out == rb.out, (ra.rid, ra.out, rb.out)
    assert st_p["pages_in_use"] == 0
    assert st_d["invocation_rate"] == st_p["invocation_rate"]
