"""MCMA dispatch runtime (runtime/dispatch.py): the Pallas weight-switch
serve engine against the XLA capacity-dispatch oracle, invoke_stats
invariants, and the DecodeServer end-to-end path.  Hypothesis-free by
design — the oracle (backend="xla") defines the semantics, so every test
is a direct example-based comparison.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, smoke_config
from repro.models import model as M
from repro.models.approx_ffn import approx_ffn_fwd, init_approx_ffn
from repro.runtime import dispatch as D
from repro.runtime.server import DecodeServer, Request

jax.config.update("jax_platform_name", "cpu")


def _mk_dispatch_case(key, t, n, d, d_h):
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (t, d), jnp.float32) * 0.5
    router = jax.random.normal(ks[1], (d, n + 1)) * 0.5
    w1 = jax.random.normal(ks[2], (n, d, d_h)) * 0.2
    b1 = jax.random.normal(ks[3], (n, d_h)) * 0.1
    w2 = jax.random.normal(ks[4], (n, d_h, d)) * 0.2
    b2 = jax.random.normal(ks[5], (n, d)) * 0.1
    wi = jax.random.normal(jax.random.fold_in(key, 7), (d, 2 * d)) * 0.1
    wo = jax.random.normal(jax.random.fold_in(key, 8), (2 * d, d)) * 0.1
    exact_fn = lambda xb: jnp.dot(jax.nn.silu(jnp.dot(xb, wi)), wo)
    return x, x @ router, (w1, b1, w2, b2), exact_fn


def _approx_cfg(**over):
    cfg = smoke_config(get_config("internlm2-1.8b"))
    return dataclasses.replace(cfg, approx=dataclasses.replace(
        cfg.approx, enable=True, **over))


# ---------------------------------------------------------------------------
# mcma_dispatch: Pallas backend vs XLA oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,n,d,d_h,block", [
    (200, 3, 64, 32, 64),     # generous capacity, mixed classes
    (37, 2, 24, 8, 32),       # T < block_t
    (128, 1, 32, 16, 64),     # single approximator
    (96, 5, 40, 8, 16),       # many classes, some likely sparse
])
def test_pallas_backend_matches_xla_oracle(t, n, d, d_h, block):
    key = jax.random.PRNGKey(t * 131 + n)
    x, logits, w, exact_fn = _mk_dispatch_case(key, t, n, d, d_h)
    caps = dict(exact_cap=max(t // 2, 1), invoke_cap=max(int(t * 0.4), 1))
    yx, sx = D.mcma_dispatch(x, logits, exact_fn, *w, backend="xla", **caps)
    yp, sp = D.mcma_dispatch(x, logits, exact_fn, *w, backend="pallas",
                             block_t=block, interpret=True, **caps)
    # dtype-tolerance match on ALL rows (dispatched AND zero/dropped rows)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yx),
                               rtol=1e-6, atol=1e-6)
    # routed counts and capacity accounting agree across backends
    np.testing.assert_array_equal(np.asarray(sx["class_counts"]),
                                  np.asarray(sp["class_counts"]))
    np.testing.assert_array_equal(np.asarray(sx["dispatched"]),
                                  np.asarray(sp["dispatched"]))


def test_invoke_stats_counts_sum_to_t():
    t, n = 250, 3
    x, logits, w, exact_fn = _mk_dispatch_case(jax.random.PRNGKey(0),
                                               t, n, 48, 16)
    for backend in ("xla", "pallas"):
        _, s = D.mcma_dispatch(x, logits, exact_fn, *w, exact_cap=t // 2,
                               invoke_cap=t // 3, backend=backend,
                               block_t=64, interpret=True)
        assert int(s["class_counts"].sum()) == t
        assert s["class_counts"].shape == (n + 1,)
        disp, cnt = np.asarray(s["dispatched"]), np.asarray(s["class_counts"])
        assert (disp <= cnt).all()
        assert disp[0] <= t // 2 and (disp[1:] <= t // 3).all()
        assert int(s["dropped"]) == int((cnt - disp).sum())
        assert int(s["executed_rows"]) - int(disp.sum()) \
            == int(s["padding_rows"])
        if backend == "pallas":
            # executed_rows must count the kernel's real static grid
            from repro.kernels import ops as kops
            assert int(s["executed_rows"]) \
                == t // 2 + kops.worst_case_rows(t, n + 1, 64)
        inv = float(s["invocation"])
        assert 0.0 <= inv <= 1.0
        assert inv == pytest.approx(1.0 - cnt[0] / t, abs=1e-6)


def test_all_nc_input_takes_exact_path_only():
    """Router unanimously votes class 0: invocation 0, approximators silent,
    and both backends still agree (the all-nC regime of a cold router)."""
    t, n = 130, 3
    x, _, w, exact_fn = _mk_dispatch_case(jax.random.PRNGKey(5), t, n, 32, 8)
    logits = jnp.full((t, n + 1), -10.0).at[:, 0].set(10.0)
    yx, sx = D.mcma_dispatch(x, logits, exact_fn, *w, exact_cap=t,
                             invoke_cap=16, backend="xla")
    yp, sp = D.mcma_dispatch(x, logits, exact_fn, *w, exact_cap=t,
                             invoke_cap=16, backend="pallas", block_t=32,
                             interpret=True)
    assert float(sx["invocation"]) == 0.0 == float(sp["invocation"])
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yx),
                               rtol=1e-6, atol=1e-6)
    # with full exact capacity the output equals the plain exact function
    np.testing.assert_allclose(np.asarray(yx), np.asarray(exact_fn(x)),
                               rtol=1e-5, atol=1e-5)


def test_over_capacity_rows_contribute_zero():
    """Rows ranked past the capacity must come out exactly zero (GShard
    convention) on both backends."""
    t, n = 64, 2
    x, _, w, exact_fn = _mk_dispatch_case(jax.random.PRNGKey(9), t, n, 24, 8)
    logits = jnp.full((t, n + 1), -10.0).at[:, 1].set(10.0)  # all class 1
    for backend, kw in (("xla", {}),
                        ("pallas", dict(block_t=16, interpret=True))):
        y, s = D.mcma_dispatch(x, logits, exact_fn, *w, exact_cap=4,
                               invoke_cap=10, backend=backend, **kw)
        y = np.asarray(y)
        assert not y[10:].any()              # over-capacity -> zero
        assert y[:10].any()                  # dispatched rows computed
        assert int(s["dropped"]) == t - 10


def test_scatter_gather_rows_pin_oob_and_duplicates():
    """The capacity-path primitives' degenerate-slot semantics are PINNED
    (the same contract as ops.gather_resident_stacks): an out-of-range
    slot is dropped/zero — never wrapped by jit's negative-index
    semantics or clamped onto a real slot — and duplicate scatter slots
    resolve deterministically by summation.  Checked TRACED, where jit's
    default OOB behaviors would otherwise silently diverge from eager."""
    rows = jnp.arange(1.0, 13.0).reshape(4, 3)
    keep = jnp.asarray([True, True, True, True])
    n_slots = 4

    def roundtrip(slot):
        buf = D.scatter_rows(rows, slot, keep, n_slots)
        return buf, D.gather_rows(buf, slot, keep)

    # OOB high, OOB negative: dropped on scatter, zero on gather — jit
    # would clamp the high one and wrap -1 onto the last slot
    buf, back = jax.jit(roundtrip)(jnp.asarray([0, 2, 9, -1]))
    np.testing.assert_array_equal(np.asarray(buf[0]), np.asarray(rows[0]))
    np.testing.assert_array_equal(np.asarray(buf[2]), np.asarray(rows[1]))
    np.testing.assert_array_equal(np.asarray(buf)[[1, 3]], np.zeros((2, 3)))
    np.testing.assert_array_equal(np.asarray(back[:2]), np.asarray(rows[:2]))
    np.testing.assert_array_equal(np.asarray(back[2:]), np.zeros((2, 3)))

    # duplicates: deterministic summation on scatter (not last-writer-
    # wins), plain duplication on gather
    buf, back = jax.jit(roundtrip)(jnp.asarray([1, 1, 3, 0]))
    np.testing.assert_array_equal(np.asarray(buf[1]),
                                  np.asarray(rows[0] + rows[1]))
    np.testing.assert_array_equal(np.asarray(back[0]), np.asarray(back[1]))

    # keep=False drops a VALID slot entirely
    buf2 = D.scatter_rows(rows, jnp.asarray([0, 1, 2, 3]),
                          jnp.asarray([True, False, True, True]), n_slots)
    np.testing.assert_array_equal(np.asarray(buf2[1]), np.zeros((3,)))


def test_unknown_backend_raises():
    t, n = 16, 2
    x, logits, w, exact_fn = _mk_dispatch_case(jax.random.PRNGKey(1),
                                               t, n, 16, 8)
    with pytest.raises(ValueError, match="backend"):
        D.mcma_dispatch(x, logits, exact_fn, *w, exact_cap=8, invoke_cap=8,
                        backend="cuda")


# ---------------------------------------------------------------------------
# ApproxFFN serve mode through the engine
# ---------------------------------------------------------------------------

def test_approx_ffn_serve_pallas_matches_xla():
    cfg = _approx_cfg()
    p = init_approx_ffn(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model),
                          jnp.float32) * 0.5
    cfg_x = _approx_cfg(backend="xla")
    cfg_p = _approx_cfg(backend="pallas", interpret=True, block_t=16)
    yx, ax = approx_ffn_fwd(cfg_x, p, x, serve=True)
    yp, ap = approx_ffn_fwd(cfg_p, p, x, serve=True)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yx),
                               rtol=1e-6, atol=1e-6)
    for a in (ax, ap):
        assert int(a["invoke_stats"]["class_counts"].sum()) == 2 * 16
        assert 0.0 <= float(a["invocation"]) <= 1.0


def test_approx_ffn_serve_jits_with_stats():
    """The serve path must stay jit-stable with the stats in the output."""
    cfg = _approx_cfg(backend="pallas", interpret=True, block_t=16)
    p = init_approx_ffn(jax.random.PRNGKey(3), cfg)
    f = jax.jit(lambda p, x: approx_ffn_fwd(cfg, p, x, serve=True))
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 32, cfg.d_model),
                          jnp.float32)
    y, a = f(p, x)
    assert y.shape == x.shape
    assert int(a["invoke_stats"]["class_counts"].sum()) == 32


# ---------------------------------------------------------------------------
# DecodeServer end to end
# ---------------------------------------------------------------------------

def test_decode_server_mcma_dispatch_end_to_end():
    cfg = _approx_cfg()
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    server = DecodeServer(cfg, params, batch=2, max_len=64,
                          use_mcma_dispatch=True)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 5)
                    .astype(np.int32), max_new=4) for i in range(3)]
    for r in reqs:
        server.submit(r)
    stats = server.run_until_drained(max_ticks=300)
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 4 for r in reqs)
    assert 0.0 <= stats["invocation_rate"] <= 1.0


def test_decode_server_mcma_matches_xla_serve_tokens():
    """Same params, same prompts: the Pallas dispatch server must emit the
    same greedy tokens as the XLA-backend approx server (backends agree to
    fp tolerance, and smoke logits are far from argmax ties)."""
    cfg = _approx_cfg(backend="xla")
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(1, 9, dtype=np.int32)

    outs = []
    for use_dispatch in (False, True):
        srv = DecodeServer(cfg, params, batch=1, max_len=64,
                           use_mcma_dispatch=use_dispatch)
        r = Request(rid=0, prompt=prompt, max_new=6)
        srv.submit(r)
        srv.run_until_drained(200)
        outs.append(r.out)
    assert outs[0] == outs[1], outs
