"""Pytest bootstrap: make ``import repro`` (and the tests' local helper
modules) resolve from a bare ``python -m pytest`` run at the repo root —
no ``PYTHONPATH=src`` incantation needed.  The documented
``PYTHONPATH=src python -m pytest`` command keeps working unchanged.
"""
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_ROOT = os.path.dirname(_HERE)

for _p in (os.path.join(_ROOT, "src"), _HERE, _ROOT):
    if _p not in sys.path:
        sys.path.insert(0, _p)
