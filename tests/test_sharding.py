"""Sharding rules + multi-device integration (8 fake CPU devices via a
subprocess so the main test process keeps 1 device)."""
import json
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.runtime import steps as S
from repro.sharding import rules as R

jax.config.update("jax_platform_name", "cpu")


class FakeMesh:
    """Duck-typed mesh: just axis names/sizes for the rule functions."""

    def __init__(self, shape, names):
        import numpy as np
        self.axis_names = names
        self.devices = np.empty(shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_cover_every_leaf(arch):
    cfg = get_config(arch)
    mesh = FakeMesh((16, 16), ("data", "model"))
    shapes = jax.eval_shape(lambda: S.init_train_state(jax.random.PRNGKey(0), cfg))
    specs, report = R.state_pspecs(mesh, shapes)
    n_leaves = len(jax.tree.leaves(shapes,
                                   is_leaf=lambda x: hasattr(x, "shape")))
    n_specs = len(jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)))
    assert n_leaves == n_specs


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_model_dims_shard_over_16(arch):
    """Every arch must expose enough TP parallelism for the 16-wide model
    axis on its big matmuls (d_ff / heads / experts)."""
    cfg = get_config(arch)
    mesh = FakeMesh((16, 16), ("data", "model"))
    shapes = jax.eval_shape(lambda: S.init_train_state(jax.random.PRNGKey(0), cfg))
    specs, report = R.state_pspecs(mesh, shapes)
    flat = R._tree_paths(specs["params"])
    big_unsharded = []
    flat_shapes = R._tree_paths(shapes["params"])
    for path, spec in flat.items():
        shape = flat_shapes[path].shape
        size = 1
        for d in shape:
            size *= d
        if size >= (1 << 22) and all(s is None for s in spec):
            big_unsharded.append((path, shape))
    assert not big_unsharded, big_unsharded


def test_divisibility_fallback_replicates():
    cfg = get_config("olmo-1b")
    mesh = FakeMesh((16, 24), ("data", "model"))  # 24 does not divide heads*hd
    shapes = jax.eval_shape(lambda: S.init_train_state(jax.random.PRNGKey(0), cfg))
    specs, report = R.state_pspecs(mesh, shapes)
    # d_ff=8192 % 24 != 0 -> fallback recorded, spec still valid
    assert any("w_in" in f or "w_out" in f or "wq" in f
               for f in report.fallbacks)


def test_batch_pspec_falls_back_to_sequence():
    mesh = FakeMesh((16, 16), ("data", "model"))
    long_decode = jax.ShapeDtypeStruct((1, 524288), jax.numpy.int32)
    spec = R.batch_pspec(mesh, long_decode)
    # PartitionSpec normalizes 1-tuples to bare names
    assert spec[0] is None and spec[1] in ("data", ("data",))


_MULTIDEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.registry import get_config, smoke_config
    from repro.data.pipeline import SyntheticLM
    from repro.runtime import steps as S
    from repro.sharding import rules as R
    from repro.sharding import activations as A

    cfg = dataclasses.replace(smoke_config(get_config("internlm2-1.8b")),
                              d_model=64, n_heads=4, n_kv_heads=2, vocab=256)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=32, global_batch=8)
    state = S.init_train_state(jax.random.PRNGKey(0), cfg)
    specs, _ = R.state_pspecs(mesh, state)
    ns = jax.tree.map(lambda p: NamedSharding(mesh, p), specs,
                      is_leaf=lambda x: isinstance(x, P))
    state_sharded = jax.tree.map(lambda a, s: jax.device_put(a, s), state, ns)
    step = S.make_train_step(cfg)
    batch = ds.batch_at(0)

    # sharded step
    with mesh, A.activation_sharding(P(("data",), None, None)):
        f = jax.jit(step, in_shardings=(ns, None), out_shardings=(ns, None))
        st2, m2 = f(state_sharded, batch)
    # single-device reference
    st1, m1 = jax.jit(step)(state, batch)
    out = {"loss_sharded": float(m2["loss"]), "loss_single": float(m1["loss"])}
    diffs = [float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
             for a, b in zip(jax.tree.leaves(st1["params"]),
                             jax.tree.leaves(st2["params"]))]
    out["max_param_diff"] = max(diffs)
    print("RESULT" + json.dumps(out))
""")


def test_sharded_step_matches_single_device():
    """SPMD partitioning must not change the math (8 fake devices)."""
    r = subprocess.run([sys.executable, "-c", _MULTIDEV], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.split("RESULT")[1])
    assert abs(out["loss_sharded"] - out["loss_single"]) < 1e-3
    assert out["max_param_diff"] < 5e-2  # bf16-free f32 smoke tolerance


_MOE_MANUAL = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.registry import get_config, smoke_config
    from repro.models import moe as MOE
    from repro.sharding import activations as A
    from repro.sharding import rules as R

    cfg = smoke_config(get_config("moonshot-v1-16b-a3b"))
    # 4 experts over a model axis of 2 -> manual EP path (E % md == 0)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    key = jax.random.PRNGKey(0)
    p = MOE.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (8, 16, cfg.d_model),
                          jnp.float32) * 0.5

    # single-device reference (local chunked path)
    y_ref, aux_ref = MOE._moe_chunked(cfg, p, x)

    # distributed manual path under mesh + activation context
    pspecs, _ = R.param_pspecs(mesh, {"blocks": {"moe": p}})
    ns = jax.tree.map(lambda q: NamedSharding(mesh, q), pspecs["blocks"]["moe"],
                      is_leaf=lambda q: isinstance(q, P))
    p_sh = jax.tree.map(lambda a, s: jax.device_put(a, s), p, ns)
    xs = jax.device_put(x, NamedSharding(mesh, P(("data",), None, None)))
    with mesh, A.activation_sharding(P(("data",), None, None)):
        y, aux = jax.jit(lambda p_, x_: MOE.moe_fwd(cfg, p_, x_))(p_sh, xs)
    dy = float(jnp.max(jnp.abs(y - y_ref)))
    # capacity semantics differ (per data shard vs global) -> compare where
    # both paths kept the token (no-drop tolerance via generous capacity)
    out = {"max_diff": dy, "aux_ref": float(aux_ref), "aux": float(aux)}
    print("RESULT" + json.dumps(out))
""")


def test_moe_manual_ep_matches_reference():
    """Manual expert-parallel MoE == local path (8 fake devices)."""
    r = subprocess.run([sys.executable, "-c", _MOE_MANUAL],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.split("RESULT")[1])
    assert out["max_diff"] < 1e-4, out
    # aux is a load-balance statistic: the manual path estimates it per
    # data shard and pmeans (GShard groups == shards), the reference
    # globally — an O(1/sqrt(Tg)) statistical gap, not a math error
    assert abs(out["aux"] - out["aux_ref"]) < 1e-3, out
