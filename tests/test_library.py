"""Approximator-library residency: routing over a ``library_size``-wide
head, a traced residency map folding library classes onto resident
slots, off-set fallback to exact, and runtime hot-set swapping.

Pins, per the PR's acceptance criteria:
  * the residency fold's accounting is exact: ``lib_counts`` histograms
    the FULL library demand, off-set rows land in the exact column, and
    ``off_set_exact_rows == class_counts[0] - lib_counts[0]`` — the
    off-set rows are exactly the exact path's extra rows;
  * an identity residency (every library class resident) is bit-for-bit
    the library-less engine — the fold is a pure widening;
  * pallas == xla bit-for-bit at EVERY visited residency set, on one
    device and on the 8-virtual-device (data, model) mesh;
  * promotion/demotion never retraces: one jitted program serves every
    residency vector (jit-cache-size check), at the engine level and
    through a live ``DecodeServer`` whose ResidencyController swapped;
  * the ResidencyController's hysteresis: promotes the hot off-set
    class over the cold resident, ratio + floor gates block thrash;
  * ``train_library`` co-trains ``library_size`` members behind the
    same MCMA interface.
"""
import dataclasses
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.jit_cache import assert_zero_retrace
from repro.configs.registry import get_config, smoke_config
from repro.kernels import ops
from repro.models import model as M
from repro.runtime import autotune as AT
from repro.runtime import dispatch as D
from repro.runtime.options import LibrarySpec, ServeOptions

jax.config.update("jax_platform_name", "cpu")

_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"}


def _run(script: str) -> dict:
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=600, env=_ENV)
    assert r.returncode == 0, r.stderr[-2000:]
    return json.loads(r.stdout.split("RESULT")[1])


def _mk_library_case(key, t, lib, d, d_h):
    """Inputs + library-wide router logits + PREPADDED library stacks."""
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (t, d), jnp.float32) * 0.5
    router = jax.random.normal(ks[1], (d, lib + 1)) * 0.5
    w1 = jax.random.normal(ks[2], (lib, d, d_h)) * 0.2
    b1 = jax.random.normal(ks[3], (lib, d_h)) * 0.1
    w2 = jax.random.normal(ks[4], (lib, d_h, d)) * 0.2
    b2 = jax.random.normal(ks[5], (lib, d)) * 0.1
    wi = jax.random.normal(jax.random.fold_in(key, 7), (d, 2 * d)) * 0.1
    wo = jax.random.normal(jax.random.fold_in(key, 8), (2 * d, d)) * 0.1
    exact_fn = lambda xb: jnp.dot(jax.nn.silu(jnp.dot(xb, wi)), wo)
    stacks = ops.prepad_switched_weights(w1, b1, w2, b2)
    return x, x @ router, stacks, exact_fn


def _lib_cfg(**over):
    cfg = smoke_config(get_config("internlm2-1.8b"))
    return dataclasses.replace(cfg, approx=dataclasses.replace(
        cfg.approx, enable=True, library_size=6, **over))


RESIDENCIES = ([0, 1], [2, 5], [4, 0], [3, 2])


# ---------------------------------------------------------------------------
# the residency fold: exact off-set accounting
# ---------------------------------------------------------------------------

def test_residency_fold_accounting_exact():
    t, lib, n, d, d_h = 128, 6, 2, 48, 16
    x, logits, w, exact_fn = _mk_library_case(jax.random.PRNGKey(0), t, lib,
                                              d, d_h)
    res = jnp.asarray([4, 1], jnp.int32)
    _, s = D.mcma_dispatch(x, logits, exact_fn, *w, exact_cap=t,
                           invoke_cap=t, backend="xla",
                           weights_prepadded=True, residency=res)
    s = jax.tree.map(np.asarray, s)
    # full-library histogram covers every row once
    assert s["lib_counts"].shape == (lib + 1,)
    assert s["lib_counts"].sum() == t
    # resident slots serve their library class's demand exactly
    for slot, c in enumerate([4, 1]):
        assert s["class_counts"][slot + 1] == s["lib_counts"][c + 1]
    # off-set rows are EXACTLY the exact path's extra rows
    off = sum(s["lib_counts"][c + 1] for c in range(lib) if c not in (4, 1))
    assert s["off_set_exact_rows"] == off
    assert s["class_counts"][0] == s["lib_counts"][0] + off
    # library-less stats alias: no residency -> lib_counts == class_counts
    n_all = lib
    _, s0 = D.mcma_dispatch(x, logits, exact_fn, *w, exact_cap=t,
                            invoke_cap=t, backend="xla",
                            weights_prepadded=True,
                            residency=jnp.arange(n_all, dtype=jnp.int32))
    s0 = jax.tree.map(np.asarray, s0)
    assert s0["off_set_exact_rows"] == 0


def test_identity_residency_is_library_less_engine():
    """Every library class resident, in order: output and every stat must
    be bit-identical to running the same stacks without a residency map
    — the fold is a pure widening."""
    t, lib, d, d_h = 96, 4, 48, 16
    x, logits, w, exact_fn = _mk_library_case(jax.random.PRNGKey(1), t, lib,
                                              d, d_h)
    kw = dict(exact_cap=t // 2, invoke_cap=max(t // 8, 1), backend="xla",
              weights_prepadded=True)
    y0, s0 = D.mcma_dispatch(x, logits, exact_fn, *w, **kw)
    y1, s1 = D.mcma_dispatch(x, logits, exact_fn, *w,
                             residency=jnp.arange(lib, dtype=jnp.int32),
                             **kw)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    np.testing.assert_array_equal(np.asarray(s0["class_counts"]),
                                  np.asarray(s1["class_counts"]))
    np.testing.assert_array_equal(np.asarray(s0["dispatched"]),
                                  np.asarray(s1["dispatched"]))
    np.testing.assert_array_equal(np.asarray(s1["lib_counts"]),
                                  np.asarray(s1["class_counts"]))
    assert int(np.asarray(s1["off_set_exact_rows"])) == 0


# ---------------------------------------------------------------------------
# pallas == xla at every visited residency set; swaps never retrace
# ---------------------------------------------------------------------------

def test_residency_pallas_matches_xla_every_set():
    t, lib, d, d_h = 128, 6, 48, 16
    x, logits, w, exact_fn = _mk_library_case(jax.random.PRNGKey(2), t, lib,
                                              d, d_h)
    for res in RESIDENCIES:
        outs, stats = {}, {}
        for backend in ("xla", "pallas"):
            y, s = D.mcma_dispatch(
                x, logits, exact_fn, *w, exact_cap=t // 2,
                invoke_cap=max(t // 6, 1), backend=backend, block_t=32,
                interpret=backend == "pallas", weights_prepadded=True,
                residency=jnp.asarray(res, jnp.int32))
            outs[backend] = np.asarray(y)
            stats[backend] = jax.tree.map(np.asarray, s)
        np.testing.assert_array_equal(outs["pallas"], outs["xla"],
                                      err_msg=f"residency={res}")
        for k in ("class_counts", "dispatched", "lib_counts",
                  "off_set_exact_rows"):
            np.testing.assert_array_equal(stats["pallas"][k],
                                          stats["xla"][k], err_msg=str(res))


def test_swap_is_traced_never_retraces():
    """One jitted program serves every residency vector — a promotion is
    a new traced value through the SAME compiled step."""
    t, lib, d, d_h = 64, 6, 32, 8
    x, logits, w, exact_fn = _mk_library_case(jax.random.PRNGKey(3), t, lib,
                                              d, d_h)
    fn = jax.jit(lambda res: D.mcma_dispatch(
        x, logits, exact_fn, *w, exact_cap=t // 2, invoke_cap=t // 4,
        backend="xla", weights_prepadded=True, residency=res))
    seen = []
    for res in RESIDENCIES:
        _, s = fn(jnp.asarray(res, jnp.int32))
        seen.append(float(s["off_set_exact_rows"]))
    assert_zero_retrace(fn, "a residency swap")
    assert len(set(seen)) > 1, "residency had no effect on routing"


# ---------------------------------------------------------------------------
# decode path: the tick/layer scopes, metrics export
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("route_scope", ["layer", "tick"])
def test_decode_residency_pallas_matches_xla(route_scope):
    b = 6
    params = M.init_model(jax.random.PRNGKey(0), _lib_cfg())
    mask = jnp.asarray([True] * 5 + [False])
    toks = jnp.arange(1, b + 1, dtype=jnp.int32)[:, None]
    res = jnp.asarray([3, 5], jnp.int32)
    outs, ms = {}, {}
    for be, kw in (("xla", {}),
                   ("pallas", dict(interpret=True, block_t=16))):
        cfg = _lib_cfg(backend=be, route_scope=route_scope, **kw)
        cache = M.init_cache(cfg, b, 32)
        lg, _, m = M.decode(cfg, params, cache, toks, serve=True,
                            collect_metrics=True, row_mask=mask,
                            residency=res)
        outs[be], ms[be] = np.asarray(lg), jax.tree.map(np.asarray, m)
    np.testing.assert_array_equal(outs["pallas"], outs["xla"])
    np.testing.assert_array_equal(ms["pallas"]["lib_counts"],
                                  ms["xla"]["lib_counts"])
    m = ms["xla"]
    assert m["lib_counts"].shape == (7,)        # library_size + 1
    assert float(m["lib_counts"].sum()) == 5.0  # active rows only
    assert m["off_set_exact_rows"] >= 0


# ---------------------------------------------------------------------------
# ResidencyController: hysteresis law
# ---------------------------------------------------------------------------

def _spec(**over):
    kw = dict(library_size=6, n_resident=2, observe_window=1, cooldown=0,
              ema=1.0)
    kw.update(over)
    return LibrarySpec(**kw)


def test_controller_promotes_hot_off_set_class():
    ctrl = AT.ResidencyController(_spec())
    # library class 3 (lib_counts entry 4) dominates; residents 0/1 cold
    lib = np.asarray([10.0, 2.0, 1.0, 0.0, 30.0, 0.0, 0.0])
    res = ctrl.observe({"lib_counts": lib})
    assert 3 in res
    assert ctrl.history[0].promoted == 3
    assert ctrl.history[0].demoted in (0, 1)


def test_controller_ratio_gate_blocks_borderline_thrash():
    """A challenger near parity with the coldest resident never swaps."""
    ctrl = AT.ResidencyController(_spec(promote_margin=1.5))
    # cold resident share 10/93 (below the demote floor, so ONLY the
    # ratio gate stands); challenger 13/93 < 1.5x that — no swap
    lib = np.asarray([60.0, 10.0, 10.0, 13.0, 0.0, 0.0, 0.0])
    for _ in range(8):
        res = ctrl.observe({"lib_counts": lib})
    assert res == (0, 1)
    assert not ctrl.history


def test_controller_floor_gate_protects_busy_resident():
    """A resident above the demote floor is never demoted, whatever is
    knocking."""
    ctrl = AT.ResidencyController(_spec(demote_margin=0.25))
    # cold resident holds 26% of traffic (above the floor); the
    # challenger's 45% clears the ratio gate — floor alone must block
    lib = np.asarray([0.0, 26.0, 29.0, 45.0, 0.0, 0.0, 0.0])
    for _ in range(8):
        res = ctrl.observe({"lib_counts": lib})
    assert res == (0, 1)
    assert not ctrl.history


def test_controller_cooldown_spaces_swaps():
    ctrl = AT.ResidencyController(_spec(observe_window=1, cooldown=3))
    hot = np.zeros(7)
    hot[3] = 50.0           # library class 2, off-set
    hot[1] = 1.0
    for _ in range(4):
        ctrl.observe({"lib_counts": hot})
    assert len(ctrl.history) == 1          # cooldown swallowed the rest


def test_library_spec_validation():
    with pytest.raises(AssertionError):
        LibrarySpec(library_size=2, n_resident=4)
    with pytest.raises(AssertionError):
        LibrarySpec(library_size=4, n_resident=2, promote_margin=0.5)
    with pytest.raises(AssertionError):
        LibrarySpec(library_size=4, n_resident=2, start=(0, 9))
    assert LibrarySpec(4, 2).initial_residency() == (0, 1)
    assert LibrarySpec(4, 2, start=(3, 1)).initial_residency() == (3, 1)


# ---------------------------------------------------------------------------
# server end to end: swaps happen, zero retraces, stats surface
# ---------------------------------------------------------------------------

def test_server_library_swaps_without_retrace():
    from repro.runtime.server import DecodeServer, Request
    cfg = _lib_cfg(backend="xla")
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    srv = DecodeServer(cfg, params, options=ServeOptions(
        batch=4, max_len=64, use_mcma_dispatch=True, prefill_chunk=4,
        library=LibrarySpec(library_size=6, n_resident=2,
                            observe_window=2, cooldown=2)))
    assert srv.cfg.approx.n_approx == 2          # serving slots
    assert srv.cfg.approx.library_size == 6
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(1, cfg.vocab, 6)
                    .astype(np.int32), max_new=6) for i in range(10)]
    for r in reqs:
        srv.submit(r)
    stats = srv.run_until_drained(max_ticks=400)
    assert all(r.done for r in reqs)
    # the drain summary carries the library ledger
    lib = stats["lib_routed_per_class"]
    assert len(lib) == 7
    assert stats["off_set_exact_rows"] >= 0
    summ = stats["residency"]
    assert len(summ["final_residency"]) == 2
    # swapping (if any happened) cost ZERO retraces: the decode and chunk
    # steps each compiled exactly once
    assert_zero_retrace(srv.decode, "a live residency swap (decode step)")
    assert_zero_retrace(srv.chunk, "a live residency swap (chunk step)")
    # off-set rows reconcile against the full-library demand histogram
    resident_demand = sum(lib[c + 1] for c in summ["final_residency"])
    assert stats["off_set_exact_rows"] <= sum(lib[1:])


def test_server_library_requires_matching_config():
    from repro.runtime.server import DecodeServer
    cfg = _lib_cfg()
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    with pytest.raises(AssertionError, match="library_size"):
        DecodeServer(cfg, params, options=ServeOptions(
            use_mcma_dispatch=True,
            library=LibrarySpec(library_size=4, n_resident=2)))
    with pytest.raises(AssertionError, match="dispatch engine"):
        DecodeServer(cfg, params, options=ServeOptions(
            library=LibrarySpec(library_size=6, n_resident=2),
            use_mcma_dispatch=False))


# ---------------------------------------------------------------------------
# train_library: error-clustered co-training at library scale
# ---------------------------------------------------------------------------

def test_train_library_smoke():
    from repro.apps.registry import get_app, make_dataset
    from repro.core.mcma import train_library
    app = get_app("fft")
    x, y, xt, yt = make_dataset(app, jax.random.PRNGKey(0), 256, 128)
    m = train_library(app, jax.random.PRNGKey(1), x, y, library_size=4,
                      iters=2, epochs=40, lr=1e-2)
    assert m.n_approx == 4
    assert len(m.history) == 2
    cls = np.asarray(m.classify(xt))
    assert cls.min() >= 0 and cls.max() <= 4    # library classes + nC


# ---------------------------------------------------------------------------
# mesh: residency on 8 virtual devices (subprocess)
# ---------------------------------------------------------------------------

_MESH = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs.registry import get_config, smoke_config
    from repro.models import model as M
    from repro.sharding import activations as A

    def cfg_with(backend, scope):
        cfg = smoke_config(get_config("internlm2-1.8b"))
        return dataclasses.replace(cfg, approx=dataclasses.replace(
            cfg.approx, enable=True, backend=backend, interpret=True,
            block_t=16, route_scope=scope, library_size=6))

    B = 8
    mask = jnp.asarray([True] * 6 + [False] * 2)
    toks = jnp.arange(1, B + 1, dtype=jnp.int32)[:, None]
    params = M.init_model(jax.random.PRNGKey(0), cfg_with("xla", "tick"))
    out = {}
    for scope in ("layer", "tick"):
        per_res = {}
        for res in ([0, 1], [4, 2]):
            resv = jnp.asarray(res, jnp.int32)
            cfg = cfg_with("xla", scope)
            cache = M.init_cache(cfg, B, 32)
            _, _, m1 = M.decode(cfg, params, cache, toks, serve=True,
                                collect_metrics=True, row_mask=mask,
                                residency=resv)
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            outs, libs = {}, {}
            for backend in ("xla", "pallas"):
                c = cfg_with(backend, scope)
                with mesh, A.activation_sharding(P(("data",), None, None)):
                    lg, _, m = jax.jit(
                        lambda p, ca, t, rm, rv, c_=c: M.decode(
                            c_, p, ca, t, serve=True, collect_metrics=True,
                            row_mask=rm, residency=rv))(
                        params, cache, toks, mask, resv)
                outs[backend] = np.asarray(lg)
                libs[backend] = np.asarray(m["lib_counts"]).tolist()
            per_res[str(res)] = {
                "pallas_bitexact_vs_xla": bool(
                    np.array_equal(outs["pallas"], outs["xla"])),
                "lib_counts": libs,
                "single_lib_counts":
                    np.asarray(m1["lib_counts"]).tolist(),
            }
        out[scope] = per_res
    print("RESULT" + json.dumps(out))
""")


def test_residency_mesh_subprocess_8_virtual_devices():
    out = _run(_MESH)
    for scope in ("layer", "tick"):
        for res, o in out[scope].items():
            assert o["pallas_bitexact_vs_xla"], (scope, res)
            assert o["lib_counts"]["pallas"] == o["lib_counts"]["xla"], \
                (scope, res)
    # tick scope routes once from the drift-free embedding: the psum'd
    # full-library histogram equals the single-device one exactly
    for res, o in out["tick"].items():
        for be in ("xla", "pallas"):
            assert o["lib_counts"][be] == o["single_lib_counts"], (be, res)


needs_8_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (CI multidevice leg: XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")


@needs_8_devices
@pytest.mark.parametrize("route_scope", ["layer", "tick"])
def test_residency_mesh_inprocess(route_scope):
    """CI multidevice leg: pallas == xla on the (4, 2) mesh at every
    visited residency set, and swaps through one jitted program never
    retrace even under shard_map."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding import activations as A
    b = 8
    mask = jnp.asarray([True] * 6 + [False] * 2)
    toks = jnp.arange(1, b + 1, dtype=jnp.int32)[:, None]
    params = M.init_model(jax.random.PRNGKey(0), _lib_cfg())
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    fns = {}
    for be, kw in (("xla", {}),
                   ("pallas", dict(interpret=True, block_t=16))):
        c = _lib_cfg(backend=be, route_scope=route_scope, **kw)
        fns[be] = jax.jit(lambda p, ca, t, rm, rv, c_=c: M.decode(
            c_, p, ca, t, serve=True, collect_metrics=True,
            row_mask=rm, residency=rv))
    for res in ([0, 1], [5, 3], [2, 4]):
        resv = jnp.asarray(res, jnp.int32)
        outs, libs = {}, {}
        for be in ("xla", "pallas"):
            cache = M.init_cache(_lib_cfg(), b, 32)
            with mesh, A.activation_sharding(P(("data",), None, None)):
                lg, _, m = fns[be](params, cache, toks, mask, resv)
            outs[be] = np.asarray(lg)
            libs[be] = np.asarray(m["lib_counts"])
        np.testing.assert_array_equal(outs["pallas"], outs["xla"],
                                      err_msg=str(res))
        np.testing.assert_array_equal(libs["pallas"], libs["xla"])
        assert float(libs["xla"].sum()) == 6.0   # active rows only
    for be in ("xla", "pallas"):
        assert_zero_retrace(fns[be],
                            f"{be}: a residency swap under the mesh")
