"""The paper's eight benchmark applications (Fig. 6).

Each app bundles the exact target function ("CPU path"), an input generator,
the paper's approximator/classifier topologies, a default error bound, and
the per-invocation CPU cost constant used by the NPU cost model.
"""
from repro.apps.registry import APPS, App, get_app, make_dataset

__all__ = ["APPS", "App", "get_app", "make_dataset"]
