"""Exact implementations of the eight target functions, in JAX.

These are the "CPU" paths that approximate computing replaces.  All accept
float32 arrays of shape (n, d_in) and return (n, d_out).  They are jittable
so the quality-control loop, the benchmarks, and the property tests can call
them cheaply; scipy is used only in tests as an independent oracle (Bessel).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# 1. Black-Scholes (6 inputs: spot, strike, rate, dividend, vol, time -> call)
# ---------------------------------------------------------------------------

def _ncdf(x):
    return 0.5 * (1.0 + jax.lax.erf(x / jnp.sqrt(2.0)))


def blackscholes(x: jax.Array) -> jax.Array:
    s, k, r, q, vol, t = [x[:, i] for i in range(6)]
    vol = jnp.maximum(vol, 1e-3)
    t = jnp.maximum(t, 1e-3)
    srt = vol * jnp.sqrt(t)
    d1 = (jnp.log(s / k) + (r - q + 0.5 * vol * vol) * t) / srt
    d2 = d1 - srt
    call = s * jnp.exp(-q * t) * _ncdf(d1) - k * jnp.exp(-r * t) * _ncdf(d2)
    return call[:, None]


# ---------------------------------------------------------------------------
# 2. FFT twiddle (1 input -> (re, im) of exp(-2*pi*i * w * x)); oscillatory,
#    deliberately hard for a 1->2->2->2 MLP — the paper finds FFT "not
#    suitable for approximation".
# ---------------------------------------------------------------------------

_FFT_FREQ = 16.0


def fft_twiddle(x: jax.Array) -> jax.Array:
    ang = -2.0 * jnp.pi * _FFT_FREQ * x[:, 0]
    return jnp.stack([jnp.cos(ang), jnp.sin(ang)], axis=-1)


# ---------------------------------------------------------------------------
# 3. inversek2j: 2-joint arm inverse kinematics (x, y) -> (theta1, theta2)
# ---------------------------------------------------------------------------

_L1, _L2 = 0.5, 0.5


def inversek2j(x: jax.Array) -> jax.Array:
    px, py = x[:, 0], x[:, 1]
    r2 = px * px + py * py
    c2 = jnp.clip((r2 - _L1 * _L1 - _L2 * _L2) / (2 * _L1 * _L2), -1.0, 1.0)
    t2 = jnp.arccos(c2)
    t1 = jnp.arctan2(py, px) - jnp.arctan2(_L2 * jnp.sin(t2), _L1 + _L2 * jnp.cos(t2))
    return jnp.stack([t1, t2], axis=-1)


# ---------------------------------------------------------------------------
# 4. jmeint: triangle-triangle intersection (18 inputs -> one-hot 2 classes)
#    Separating-axis test over 11 candidate axes.
# ---------------------------------------------------------------------------

def _project(tri, axis):
    # tri: (n, 3, 3); axis: (n, 3) -> (min, max) over vertices
    d = jnp.einsum("nvk,nk->nv", tri, axis)
    return d.min(axis=1), d.max(axis=1)


def _sat_separated(t1, t2, axis):
    # returns True where axis separates the triangles
    mn1, mx1 = _project(t1, axis)
    mn2, mx2 = _project(t2, axis)
    degenerate = jnp.sum(axis * axis, axis=-1) < 1e-12
    return jnp.where(degenerate, False, (mx1 < mn2) | (mx2 < mn1))


def jmeint(x: jax.Array) -> jax.Array:
    t1 = x[:, :9].reshape(-1, 3, 3)
    t2 = x[:, 9:].reshape(-1, 3, 3)
    e1 = jnp.stack([t1[:, 1] - t1[:, 0], t1[:, 2] - t1[:, 1], t1[:, 0] - t1[:, 2]], axis=1)
    e2 = jnp.stack([t2[:, 1] - t2[:, 0], t2[:, 2] - t2[:, 1], t2[:, 0] - t2[:, 2]], axis=1)
    n1 = jnp.cross(e1[:, 0], e1[:, 1])
    n2 = jnp.cross(e2[:, 0], e2[:, 1])
    sep = _sat_separated(t1, t2, n1) | _sat_separated(t1, t2, n2)
    for i in range(3):
        for j in range(3):
            axis = jnp.cross(e1[:, i], e2[:, j])
            sep = sep | _sat_separated(t1, t2, axis)
    intersect = (~sep).astype(jnp.float32)
    return jnp.stack([1.0 - intersect, intersect], axis=-1)  # one-hot


# ---------------------------------------------------------------------------
# 5. JPEG: 8x8 block lossy roundtrip IDCT(quant(DCT(block))) (64 -> 64)
# ---------------------------------------------------------------------------

def _dct_matrix(n=8):
    k = jnp.arange(n)[:, None].astype(jnp.float32)
    i = jnp.arange(n)[None, :].astype(jnp.float32)
    m = jnp.sqrt(2.0 / n) * jnp.cos(jnp.pi * (2 * i + 1) * k / (2 * n))
    return m.at[0].mul(1.0 / jnp.sqrt(2.0))

# Standard JPEG luminance quantization table.
_QTAB = jnp.array(
    [[16, 11, 10, 16, 24, 40, 51, 61],
     [12, 12, 14, 19, 26, 58, 60, 55],
     [14, 13, 16, 24, 40, 57, 69, 56],
     [14, 17, 22, 29, 51, 87, 80, 62],
     [18, 22, 37, 56, 68, 109, 103, 77],
     [24, 35, 55, 64, 81, 104, 113, 92],
     [49, 64, 78, 87, 103, 121, 120, 101],
     [72, 92, 95, 98, 112, 100, 103, 99]], dtype=jnp.float32)


def jpeg_block(x: jax.Array) -> jax.Array:
    blocks = x.reshape(-1, 8, 8) * 255.0 - 128.0
    d = _dct_matrix()
    coef = jnp.einsum("ij,njk,lk->nil", d, blocks, d)
    q = jnp.round(coef / _QTAB) * _QTAB
    rec = jnp.einsum("ji,njk,kl->nil", d, q, d)
    return ((rec + 128.0) / 255.0).reshape(-1, 64)


# ---------------------------------------------------------------------------
# 6. k-means: distance between two rgb points (6 -> 1), the NPU kernel.
# ---------------------------------------------------------------------------

def kmeans_dist(x: jax.Array) -> jax.Array:
    a, b = x[:, :3], x[:, 3:]
    return jnp.sqrt(jnp.sum((a - b) ** 2, axis=-1, keepdims=True) + 1e-12)


# ---------------------------------------------------------------------------
# 7. sobel: 3x3 patch -> gradient magnitude (9 -> 1)
# ---------------------------------------------------------------------------

_GX = jnp.array([[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]], dtype=jnp.float32)
_GY = _GX.T


def sobel(x: jax.Array) -> jax.Array:
    p = x.reshape(-1, 3, 3)
    gx = jnp.sum(p * _GX, axis=(1, 2))
    gy = jnp.sum(p * _GY, axis=(1, 2))
    return jnp.clip(jnp.sqrt(gx * gx + gy * gy) / 4.0, 0.0, 1.0)[:, None]


# ---------------------------------------------------------------------------
# 8. Bessel: (x, y) -> J0(x) * J1(y)   (GNU GSL-flavored scientific kernel;
#    2-D input so the cluster structure of Fig. 10 is plottable)
# ---------------------------------------------------------------------------

def _j0(x):
    """Bessel J0 via Abramowitz & Stegun 9.4.1/9.4.3 rational approximations."""
    ax = jnp.abs(x)
    # |x| <= 3
    t = (x / 3.0) ** 2
    small = (1.0 - 2.2499997 * t + 1.2656208 * t**2 - 0.3163866 * t**3
             + 0.0444479 * t**4 - 0.0039444 * t**5 + 0.0002100 * t**6)
    # |x| > 3
    z = 3.0 / jnp.maximum(ax, 1e-9)
    f0 = (0.79788456 - 0.00000077 * z - 0.00552740 * z**2 - 0.00009512 * z**3
          + 0.00137237 * z**4 - 0.00072805 * z**5 + 0.00014476 * z**6)
    t0 = (ax - 0.78539816 - 0.04166397 * z - 0.00003954 * z**2 + 0.00262573 * z**3
          - 0.00054125 * z**4 - 0.00029333 * z**5 + 0.00013558 * z**6)
    big = f0 * jnp.cos(t0) / jnp.sqrt(jnp.maximum(ax, 1e-9))
    return jnp.where(ax <= 3.0, small, big)


def _j1(x):
    """Bessel J1 via Abramowitz & Stegun 9.4.4/9.4.6."""
    ax = jnp.abs(x)
    t = (x / 3.0) ** 2
    small = x * (0.5 - 0.56249985 * t + 0.21093573 * t**2 - 0.03954289 * t**3
                 + 0.00443319 * t**4 - 0.00031761 * t**5 + 0.00001109 * t**6)
    z = 3.0 / jnp.maximum(ax, 1e-9)
    f1 = (0.79788456 + 0.00000156 * z + 0.01659667 * z**2 + 0.00017105 * z**3
          - 0.00249511 * z**4 + 0.00113653 * z**5 - 0.00020033 * z**6)
    t1 = (ax - 2.35619449 + 0.12499612 * z + 0.00005650 * z**2 - 0.00637879 * z**3
          + 0.00074348 * z**4 + 0.00079824 * z**5 - 0.00029166 * z**6)
    big = jnp.sign(x) * f1 * jnp.cos(t1) / jnp.sqrt(jnp.maximum(ax, 1e-9))
    return jnp.where(ax <= 3.0, small, big)


def bessel(x: jax.Array) -> jax.Array:
    return (_j0(x[:, 0]) * _j1(x[:, 1]))[:, None]
