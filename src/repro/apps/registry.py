"""Registry binding each benchmark app to its topologies, data generator,
error semantics and NPU cost constants (Fig. 6 of the paper).

``cpu_cycles`` follows the dynamic-instruction scale of the NPU paper
(Esmaeilzadeh et al., MICRO'12) that both the paper and we use for the
Fig. 8 speedup/energy estimates; see DESIGN.md §6.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.apps import functions as F
from repro.core.mlp import MLPSpec


@dataclasses.dataclass(frozen=True)
class App:
    name: str
    domain: str
    fn: Callable[[jax.Array], jax.Array]          # exact target function
    gen: Callable[[jax.Array, int], jax.Array]    # (key, n) -> inputs
    approx_topo: str                              # Fig. 6 approximator topology
    cls_topo: str                                 # Fig. 6 classifier topology (binary head)
    n_in: int
    n_out: int
    error_bound: float                            # default quality requirement
    err_kind: str                                 # "rmse_rel" | "class"
    cpu_cycles: float                             # exact-path cost per call
    n_train: int                                  # paper-scale training set size
    n_test: int
    in_lo: tuple = ()                             # input-normalization bounds
    in_hi: tuple = ()

    def normalize(self, x_raw: jax.Array) -> jax.Array:
        """Map raw inputs into [-1, 1] for the neural networks."""
        lo = jnp.asarray(self.in_lo, jnp.float32)
        hi = jnp.asarray(self.in_hi, jnp.float32)
        return (x_raw - lo) / (hi - lo) * 2.0 - 1.0

    @property
    def approx_spec(self) -> MLPSpec:
        return MLPSpec.parse(self.approx_topo)

    def cls_spec(self, n_classes: int = 2) -> MLPSpec:
        """Classifier spec; the last layer widens for MCMA multiclass heads."""
        sizes = MLPSpec.parse(self.cls_topo).sizes[:-1] + (n_classes,)
        return MLPSpec(sizes=sizes, out_act="linear")


def _uniform(lo, hi):
    def gen(key, n):
        lo_a = jnp.asarray(lo, jnp.float32)
        hi_a = jnp.asarray(hi, jnp.float32)
        return jax.random.uniform(key, (n, lo_a.shape[0]), jnp.float32) * (hi_a - lo_a) + lo_a
    return gen


def _gen_patches(key, n):
    """Natural-image-like 3x3 patches: luminance ramp + small noise (sobel).

    Pure-noise patches make the Sobel magnitude unlearnable for a 9->8->1
    net; real image patches are locally smooth directional gradients.
    """
    k1, k2, k3, k4 = jax.random.split(key, 4)
    base = jax.random.uniform(k1, (n, 1))
    theta = jax.random.uniform(k2, (n, 1)) * 2 * jnp.pi
    slope = jax.random.uniform(k3, (n, 1), minval=-0.4, maxval=0.4)
    ii = jnp.arange(3.0) - 1
    ramp = slope[:, 0, None, None] * (
        ii[None, :, None] * jnp.cos(theta)[:, :, None]
        + ii[None, None, :] * jnp.sin(theta)[:, :, None])
    eps = jax.random.uniform(k4, (n, 3, 3), minval=-0.05, maxval=0.05)
    return jnp.clip(base[:, :, None] + ramp + eps, 0.0, 1.0).reshape(n, 9)


def _gen_blocks(key, n):
    """8x8 blocks: DC level + 2 random low-frequency cosines + noise (jpeg)."""
    k1, k2, k3 = jax.random.split(key, 3)
    dc = jax.random.uniform(k1, (n, 1, 1))
    fx = jax.random.randint(k2, (n, 2), 0, 4).astype(jnp.float32)
    amp = jax.random.uniform(k3, (n, 2), minval=-0.3, maxval=0.3)
    ii = jnp.arange(8.0)
    wave = (amp[:, 0, None, None] * jnp.cos(jnp.pi * fx[:, 0, None, None] * ii[None, :, None] / 8.0)
            + amp[:, 1, None, None] * jnp.cos(jnp.pi * fx[:, 1, None, None] * ii[None, None, :] / 8.0))
    return jnp.clip(dc + wave, 0.0, 1.0).reshape(n, 64)


def _gen_triangles(key, n):
    """Triangle pairs with centers drawn close enough that ~half intersect."""
    k1, k2, k3 = jax.random.split(key, 3)
    t1 = jax.random.uniform(k1, (n, 9), minval=-1.0, maxval=1.0)
    offset = jax.random.uniform(k2, (n, 1, 3), minval=-0.8, maxval=0.8)
    t2 = jax.random.uniform(k3, (n, 3, 3), minval=-1.0, maxval=1.0) * 0.9 + offset
    return jnp.concatenate([t1, t2.reshape(n, 9)], axis=-1)


APPS: dict[str, App] = {}


def _register(app: App):
    APPS[app.name] = app
    return app


_register(App("blackscholes", "Financial Analysis", F.blackscholes,
              _uniform([0.5, 0.5, 0.0, 0.0, 0.05, 0.1], [1.5, 1.5, 0.1, 0.05, 0.5, 2.0]),
              "6->8->1", "6->8->2", 6, 1, 0.05, "rmse_rel", 1000.0, 70_000, 30_000,
              (0.5, 0.5, 0.0, 0.0, 0.05, 0.1), (1.5, 1.5, 0.1, 0.05, 0.5, 2.0)))
_register(App("fft", "Signal Processing", F.fft_twiddle,
              _uniform([0.0], [1.0]),
              "1->2->2->2", "1->2->2", 1, 2, 0.10, "rmse_rel", 70.0, 8_000, 3_000,
              (0.0,), (1.0,)))
_register(App("inversek2j", "Robotics", F.inversek2j,
              # reachable annulus-ish box for a (0.5, 0.5) arm
              _uniform([0.05, 0.05], [0.9, 0.9]),
              "2->8->2", "2->8->2", 2, 2, 0.05, "rmse_rel", 600.0, 70_000, 30_000,
              (0.05, 0.05), (0.9, 0.9)))
_register(App("jmeint", "3D gaming", F.jmeint,
              _gen_triangles,
              "18->32->16->2", "18->16->2", 18, 2, 0.05, "class", 1100.0, 70_000, 30_000,
              (-1.8,) * 18, (1.8,) * 18))
_register(App("jpeg", "Compression", F.jpeg_block,
              _gen_blocks,
              "64->16->64", "64->16->2", 64, 64, 0.05, "rmse_rel", 1300.0, 4_096, 4_096,
              (0.0,) * 64, (1.0,) * 64))
_register(App("kmeans", "Machine Learning", F.kmeans_dist,
              _uniform([0.0] * 6, [1.0] * 6),
              "6->8->4->1", "6->8->4->2", 6, 1, 0.05, "rmse_rel", 30.0, 100_000, 50_000,
              (0.0,) * 6, (1.0,) * 6))
_register(App("sobel", "Image Processing", F.sobel,
              _gen_patches,
              "9->8->1", "9->8->2", 9, 1, 0.05, "rmse_rel", 90.0, 4_096, 4_096,
              (0.0,) * 9, (1.0,) * 9))
_register(App("bessel", "Scientific Computing", F.bessel,
              _uniform([0.0, 0.0], [5.0, 5.0]),
              "2->4->4->1", "2->4->2", 2, 1, 0.05, "rmse_rel", 900.0, 70_000, 30_000,
              (0.0, 0.0), (5.0, 5.0)))


def get_app(name: str) -> App:
    return APPS[name]


def function_zoo(domain: str | None = None,
                 names: tuple | None = None) -> tuple[App, ...]:
    """The registry as the approximator-library function zoo.

    A library deployment (runtime/options.LibrarySpec) co-hosts the
    specialists for many invocation sites; this returns the apps whose
    kernels make up that zoo, in a stable (sorted-by-name) order so zoo
    index == library class id is reproducible across runs.  Filter by
    ``domain`` (e.g. "Signal Processing") or an explicit ``names`` tuple.
    Sizing rule of thumb: ``LibrarySpec.library_size`` covers the zoo
    (one or more specialists per app, core/mcma.train_library), while
    ``n_resident`` tracks however many apps are hot at once."""
    if names is not None:
        return tuple(APPS[n] for n in names)
    apps = sorted(APPS.values(), key=lambda a: a.name)
    if domain is not None:
        apps = [a for a in apps if a.domain == domain]
    return tuple(apps)


def make_dataset(app: App, key: jax.Array, n_train: int | None = None,
                 n_test: int | None = None):
    """Generate (x_train, y_train, x_test, y_test) for an app.

    Sizes default to the paper's (Fig. 6) but can be reduced for CI speed.
    Inputs are returned NORMALIZED to [-1, 1] (what the networks consume);
    targets are the exact function of the raw inputs.
    """
    n_train = n_train or app.n_train
    n_test = n_test or app.n_test
    k1, k2 = jax.random.split(key)
    x_tr = app.gen(k1, n_train)
    x_te = app.gen(k2, n_test)
    return (app.normalize(x_tr), app.fn(x_tr),
            app.normalize(x_te), app.fn(x_te))
