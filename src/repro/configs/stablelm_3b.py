"""stablelm-3b [dense] — 32L d=2560 32H (kv=32) d_ff=6912 vocab=50304.

StableLM-3B-4E1T: LayerNorm, SwiGLU FFN, partial rotary (25%).
[hf:stabilityai/stablelm-3b-4e1t; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=6912,
    vocab=50304, norm="layernorm", act="silu", gated_ffn=True,
    rope_pct=0.25, rope_base=10_000.0,
    grad_accum=4,
)
