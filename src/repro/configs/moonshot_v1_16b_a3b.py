"""moonshot-v1-16b-a3b [moe] — 48L d=2048 16H (kv=16) d_ff=1408
vocab=163840, MoE 64 experts top-6 (Moonlight-16B-A3B).
[hf:moonshotai/Moonlight-16B-A3B; hf]

MCMA-applicability note (DESIGN.md §7): the MoE router is itself a
multiclass dispatcher; ApproxFFN stays off by default to avoid double
routing, and the technique is exercised on the dense archs instead.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=163840, norm="rmsnorm", act="silu", gated_ffn=True,
    moe=MoEConfig(n_experts=64, top_k=6, capacity_factor=1.25),
    grad_accum=4,
)
