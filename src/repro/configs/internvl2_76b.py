"""internvl2-76b [vlm] — 80L d=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.

InternVL2-Llama3-76B language backbone (Llama-3-70B shape); the InternViT
frontend is a STUB (input_specs() provides precomputed patch embeddings,
per the assignment). [arXiv:2404.16821; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab=128256, norm="rmsnorm", act="silu", gated_ffn=True,
    rope_base=500_000.0, input_mode="embeddings",
    grad_accum=16,
    act_shard="fp",
)
