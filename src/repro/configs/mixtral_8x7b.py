"""mixtral-8x7b [moe] — 32L d=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
MoE 8 experts top-2, sliding-window attention (4096).
[arXiv:2401.04088; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=32000, norm="rmsnorm", act="silu", gated_ffn=True,
    sliding_window=4096, rope_base=1_000_000.0,
    moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=1.25),
    grad_accum=8,
)
