"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

48L, d_model=2048, 4 heads (kv=4), d_ff=0 (the mLSTM block's up/down
projections play the FFN role), vocab=50304.  One sLSTM block per 8 layers
(groups of 7 mLSTM + 1 sLSTM).  Deviations from the official code are noted
in models/xlstm.py and DESIGN.md §8.
"""
import dataclasses

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, head_dim=0, norm="layernorm", gated_ffn=False,
    rope_pct=0.0,  # xLSTM has no attention, hence no RoPE
    ssm=SSMConfig(d_state=0, expand=2, head_dim=0, chunk=256, slstm_every=8),
    grad_accum=4,
)
