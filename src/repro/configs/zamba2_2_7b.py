"""zamba2-2.7b [hybrid] — 54L d=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64.  Mamba2 backbone + ONE shared attention+FFN block applied
every 6 layers (9 applications, one parameter set) — Zamba2's
parameter-sharing design. [arXiv:2411.15242; hf]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab=32000, norm="rmsnorm", act="silu", gated_ffn=True,
    attn_every=6,
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64, chunk=256),
    grad_accum=8,
)
