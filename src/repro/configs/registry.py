"""Architecture registry: maps ``--arch`` ids to ModelConfigs, provides the
reduced smoke variants and the dry-run input specs (ShapeDtypeStruct
stand-ins, no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

_MODULES = {
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "stablelm-3b": "repro.configs.stablelm_3b",
    "olmo-1b": "repro.configs.olmo_1b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "internlm2-1.8b": "repro.configs.internlm2_1_8b",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
    "musicgen-large": "repro.configs.musicgen_large",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "internvl2-76b": "repro.configs.internvl2_76b",
}

ARCH_IDS = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(_MODULES[arch])
    return mod.CONFIG


def full_attention_only(cfg: ModelConfig) -> bool:
    """True when the arch has no sub-quadratic path (long_500k is skipped)."""
    return cfg.family in ("dense", "moe", "audio", "vlm") and not cfg.sliding_window


def cells(arch: str):
    """The (shape, step-kind) cells assigned to an arch, honoring skips."""
    cfg = get_config(arch)
    out = []
    for name, sh in SHAPES.items():
        if name == "long_500k" and full_attention_only(cfg):
            continue  # noted in DESIGN.md §Arch-applicability
        out.append(sh)
    return out


# ---------------------------------------------------------------------------
# Reduced smoke variants (same family wiring, tiny dims, CPU-runnable)
# ---------------------------------------------------------------------------

def smoke_config(cfg: ModelConfig) -> ModelConfig:
    kv_ratio = max(1, cfg.n_heads // cfg.n_kv_heads)
    n_heads = 4
    ssm = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16, chunk=32,
                              slstm_every=2)
    moe = dataclasses.replace(cfg.moe, n_experts=min(cfg.moe.n_experts, 4),
                              top_k=min(cfg.moe.top_k, 2))
    approx = dataclasses.replace(cfg.approx, n_approx=2, d_hidden=32)
    if cfg.family == "ssm":
        n_layers, attn_every = 4, 0
    elif cfg.family == "hybrid":
        n_layers, attn_every = 4, 2
    else:
        n_layers, attn_every = 2, 0
    return dataclasses.replace(
        cfg, n_layers=n_layers, d_model=64, n_heads=n_heads,
        n_kv_heads=max(1, n_heads // kv_ratio), head_dim=16,
        d_ff=128 if cfg.d_ff else 0, vocab=512,
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window else 0,
        attn_every=attn_every, ssm=ssm, moe=moe, approx=approx,
        param_dtype="float32", act_dtype="float32", remat=False,
        q_block=32, kv_block=32)


# ---------------------------------------------------------------------------
# Dry-run input specs (weak-type-correct, shardable, no device allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of one cell.

    train:   {"inputs", "labels"}
    prefill: {"inputs"}
    decode:  {"inputs", "cache"} — one new token against a seq_len cache.
    """
    b, s = shape.global_batch, shape.seq_len
    tok = jnp.int32
    if cfg.input_mode == "embeddings":
        def inp(n):
            return jax.ShapeDtypeStruct((b, n, cfg.d_model), cfg.adtype)
    else:
        def inp(n):
            return jax.ShapeDtypeStruct((b, n), tok)

    if shape.kind == "train":
        return {"inputs": inp(s), "labels": jax.ShapeDtypeStruct((b, s), tok)}
    if shape.kind == "prefill":
        return {"inputs": inp(s)}
    # decode: cache sized for the context length
    from repro.models.model import init_cache  # late import (jax state)
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
    return {"inputs": inp(1), "cache": cache}
