"""Model/run configuration schema.

One ``ModelConfig`` instance fully determines an architecture; the ten
assigned architectures live in sibling modules and register themselves in
``repro.configs.registry``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ApproxConfig:
    """MCMA-as-FFN (DESIGN.md §4): n approximators + exact fallback."""

    enable: bool = False
    n_approx: int = 3
    # approximator-library residency: 0 disables (n_approx approximators,
    # all resident — the historic engine).  > 0 trains/stores a LIBRARY of
    # library_size approximators while only n_approx of them occupy the
    # prepadded weight stacks at any moment: routing happens over the full
    # library (router/tick-router heads carry library_size + 1 logits), a
    # runtime residency map folds library classes onto resident slots, and
    # off-set classes fall back to the exact path until promoted
    # (runtime/autotune.ResidencyController).  Must be >= n_approx.
    library_size: int = 0
    d_hidden: int = 256          # approximator hidden width (<< d_ff)
    error_bound: float = 0.10    # relative L2 error vs the exact FFN
    scheme: str = "competitive"  # label scheme for router co-training
    router_weight: float = 0.01  # aux loss weights
    distill_weight: float = 1.0
    # serve-mode capacity fractions (of total tokens): exact path and each
    # approximator.  FLOP savings vs dense FFN = 1 - exact_frac.
    exact_frac: float = 0.5
    invoke_frac: float = 0.4
    # asymmetric per-class capacity fractions (len n_approx) — () keeps the
    # shared invoke_frac for every class.  Heavy-tailed served mixes derive
    # these from per-class count quantiles (runtime/autotune.
    # ladder_from_counts) so a hot approximator gets capacity a cold one
    # would waste as padding.
    invoke_fracs: tuple = ()
    # per-request QoS tiers: n_tiers is the STATIC tier count (shapes of
    # the per-tier invoke stats); tier_bounds are the ascending per-tier
    # error bounds a server quantizes Request.error_bound against (() =
    # single-tier, the global error_bound above); tier_margins are the
    # default per-tier exact-logit router margins — a TRACED input at
    # serve time (runtime/dispatch.route), these are only the static
    # fallback when a caller passes a tier vector without margins.
    n_tiers: int = 1
    tier_bounds: tuple = ()
    tier_margins: tuple = ()
    # per-shard capacity over-provisioning under a mesh (the engine
    # dispatches each data shard's rows against its own budgets, so a
    # class hot on one shard drops rows even when another shard has
    # slack).  >1 buys headroom; sharding/rules.shard_capacity applies it.
    shard_slack: float = 1.0
    # serve-mode dispatch engine (runtime/dispatch.py): "xla" = portable
    # per-class capacity dispatch (the test oracle); "pallas" = the
    # scalar-prefetch weight-switch kernel (kernels/switched_mlp.py);
    # "pallas_fused" = the same kernel with the class-sort gather/scatter
    # fused in (kernels/fused_dispatch.py) — one HBM pass over
    # activations per layer.
    backend: str = "xla"
    # routing granularity at decode (runtime/dispatch.py plan/execute):
    # "layer" = per-layer route -> sort -> dispatch (today's semantics, the
    # only scope the train path uses); "tick" = the paper's one decision
    # per input datum — ONE DispatchPlan per decode tick from the model's
    # tick-router head, reused by every layer of the scan (each layer is
    # just a weight-switch kernel launch on already-sorted rows).
    route_scope: str = "layer"
    block_t: int = 128           # Pallas dispatch row-tile size
    interpret: bool = False      # Pallas interpreter mode (CPU/CI runs)

    @property
    def n_live(self) -> int:
        """Trained approximator count: the library size when a library is
        configured, else n_approx (the historic all-resident engine).
        Weight stacks and router/tick-router heads are sized by THIS;
        capacities and the dispatch plan stay sized by n_approx (the
        resident slots)."""
        return self.library_size or self.n_approx


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    aux_weight: float = 0.01     # load-balancing loss weight
    # GShard-style token groups: tokens are dispatched in chunks of this
    # many (scan), bounding the (E, cap, d) buffers; 0 = one group.
    scan_chunk: int = 32768


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / mLSTM knobs."""

    d_state: int = 64
    expand: int = 2              # d_inner = expand * d_model
    head_dim: int = 64           # Mamba2 P (state head dim)
    chunk: int = 256             # SSD / mLSTM chunk length
    slstm_every: int = 8         # xLSTM: one sLSTM block per this many blocks


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    norm: str = "rmsnorm"        # rmsnorm | layernorm | nonparam_ln
    act: str = "silu"            # FFN activation; "swiglu" = gated
    gated_ffn: bool = True
    rope_base: float = 10_000.0
    rope_pct: float = 1.0        # partial rotary (stablelm-2: 0.25)
    parallel_block: bool = False # attn+FFN in parallel (stablelm-2 style)
    qkv_bias: bool = False
    sliding_window: int = 0      # 0 = full attention (mixtral: 4096)
    tie_embeddings: bool = False
    input_mode: str = "tokens"   # tokens | embeddings (audio/vlm stubs)
    moe: MoEConfig = MoEConfig()
    ssm: SSMConfig = SSMConfig()
    approx: ApproxConfig = ApproxConfig()
    # hybrid wiring (zamba2): shared attention block applied every N layers
    attn_every: int = 0          # 0 = no shared-attn interleave
    # numerics / structure
    param_dtype: str = "bfloat16"
    act_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    # microbatch gradient-accumulation factor for train_4k (memory knob;
    # sized so per-chip residuals fit v5e HBM — see EXPERIMENTS.md §Dry-run)
    grad_accum: int = 1
    # residual-stream sharding between blocks: "dp" batch-only (baseline),
    # "fp" feature-sharded over the TP axis (shards the per-layer remat
    # saves 16x — required for the 76B train cell; see EXPERIMENTS.md §Perf)
    act_shard: str = "dp"
    # attention flash-scan block sizes (perf knobs for §Perf)
    q_block: int = 512
    kv_block: int = 512
    decode_flash_threshold: int = 8192   # decode uses direct attn below this

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adtype(self):
        return jnp.dtype(self.act_dtype)

    def params_count(self) -> int:
        """Analytic parameter count N (for 6*N*D model FLOPs)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "audio", "vlm"):
            attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
                + (self.n_heads * hd) * d
            if self.moe.n_experts:
                ffn = self.moe.n_experts * (3 if self.gated_ffn else 2) * d * self.d_ff \
                    + d * self.moe.n_experts
            else:
                ffn = (3 if self.gated_ffn else 2) * d * self.d_ff
            per_layer = attn + ffn
        elif self.family == "ssm":      # xLSTM blocks (see mlstm.py)
            d_in = self.ssm.expand * d
            per_layer = 2 * d * d_in + d_in * d + 3 * d_in  # up/gate/down + qkv-ish
        elif self.family == "hybrid":   # mamba2 + shared attn + FFN
            d_in = self.ssm.expand * d
            mamba = d * (2 * d_in + 2 * self.ssm.d_state) + d_in * d
            ffn = (3 if self.gated_ffn else 2) * d * self.d_ff
            per_layer = mamba + ffn
        return emb + self.n_layers * per_layer

    def active_params_count(self) -> int:
        """Active parameters per token (MoE: top_k of n_experts)."""
        if not self.moe.n_experts:
            return self.params_count()
        d = self.d_model
        full = self.params_count()
        all_experts = self.n_layers * self.moe.n_experts * \
            (3 if self.gated_ffn else 2) * d * self.d_ff
        active = self.n_layers * self.moe.top_k * \
            (3 if self.gated_ffn else 2) * d * self.d_ff
        return full - all_experts + active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned per architecture)."""

    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                    # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}
