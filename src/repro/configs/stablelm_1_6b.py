"""stablelm-1.6b [dense] — 24L d=2048 32H (kv=32) d_ff=5632 vocab=100352.

StableLM-2-1.6B: LayerNorm (with bias), partial rotary 25%, qkv biases.
[hf:stabilityai/stablelm-2-1_6b; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=5632,
    vocab=100352, norm="layernorm", act="silu", gated_ffn=True,
    rope_pct=0.25, qkv_bias=True,
    grad_accum=2,
)
