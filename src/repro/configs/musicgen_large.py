"""musicgen-large [audio] — 48L d=2048 32H (kv=32) d_ff=8192 vocab=2048.

Decoder-only transformer over EnCodec tokens; the EnCodec frontend is a
STUB (input_specs() provides precomputed frame embeddings, per the
assignment).  LayerNorm + GELU (non-gated) per the MusicGen/AudioCraft
decoder. [arXiv:2306.05284; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=2048, norm="layernorm", act="gelu", gated_ffn=False,
    input_mode="embeddings",
    grad_accum=4,
)
