"""internlm2-1.8b [dense] — 24L d=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.

InternLM2: GQA (2 query heads per kv head), RMSNorm, SwiGLU, full rotary.
[arXiv:2403.17297; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b", family="dense",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=8192,
    vocab=92544, norm="rmsnorm", act="silu", gated_ffn=True,
    rope_pct=1.0, rope_base=1_000_000.0,
    grad_accum=2,
)
