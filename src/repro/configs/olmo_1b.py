"""olmo-1b [dense] — 16L d=2048 16H (kv=16) d_ff=8192 vocab=50304.

OLMo's distinguishing choice: NON-PARAMETRIC LayerNorm (no learnable
affine), SwiGLU, full rotary, untied embeddings. [arXiv:2402.00838; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab=50304, norm="nonparam_ln", act="silu", gated_ffn=True,
    rope_pct=1.0,
    grad_accum=2,
)
