"""Declarative sharding rules: param/cache/batch pytrees -> PartitionSpecs.

Scheme (DESIGN.md §5): the mesh has axes ("data", "model") — plus a leading
pure-DP "pod" axis in the multi-pod mesh.  Parameters are tensor-parallel
over "model" on their widest semantically-shardable dim and FSDP-sharded
over "data" on a complementary dim.  Divisibility is checked per-dim; a dim
that does not divide falls back to replication (recorded per rule so tests
can assert what happened).

Path-driven rules (matched on the param path suffix):
  embed/tok        (V, d)        -> P(model, data)      vocab-parallel
  embed/unembed    (d, V)        -> P(data, model)
  attn wq/wk/wv    (d, H*hd)     -> P(data, model)      head-parallel
  attn wo          (H*hd, d)     -> P(model, data)
  ffn w_in/w_gate  (d, f)        -> P(data, model)      Megatron col
  ffn w_out        (f, d)        -> P(model, data)      Megatron row
  moe w_*          (E, d, f)     -> P(model, data, None) EP when E % |model|
                                    else P(None, data, model) TP-in-expert
  mamba/mlstm/slstm projections  -> widest dim over model
  biases / norms / scalars       -> replicated
Stacked-scan params have 1-2 leading layer dims -> prepended None.

Caches: KV (L, B, S, kv, hd): batch over data when divisible, else
sequence over data (context-parallel decode, the long_500k B=1 case);
kv-heads over model when divisible, else hd over model.
SSM states (..., B, H, P, N): H over model, B over data if divisible.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def _dp_axes(mesh: Mesh):
    """The data-parallel meta-axis: ("pod","data") multi-pod, else "data"."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_axes(mesh: Mesh):
    """Public alias of the DP meta-axis tuple (used by the dispatch engine,
    the serve context, and tests)."""
    return _dp_axes(mesh)


def _dp_size(mesh: Mesh) -> int:
    return int(np.prod([_axis_size(mesh, a) for a in _dp_axes(mesh)]))


def _fits(dim: int, size: int) -> bool:
    return dim % size == 0 and dim >= size


class ShardingReport:
    """Collects which rules applied / fell back (asserted in tests)."""

    def __init__(self):
        self.fallbacks: list[str] = []

    def fallback(self, path: str, why: str):
        self.fallbacks.append(f"{path}: {why}")


def _spec2d(mesh, path, shape, lead, col_model: bool, report):
    """Rule for a 2D matmul weight (possibly with leading stack dims).

    col_model=True shards the LAST dim over model (column-parallel);
    otherwise the first non-lead dim.  The complementary dim FSDPs over
    data.  Falls back per-dim on divisibility.
    """
    md = _axis_size(mesh, "model")
    dp = _dp_size(mesh)
    rows, cols = shape[lead], shape[lead + 1]
    if col_model:
        model_dim, data_dim = cols, rows
        spec = [None] * lead + [_dp_axes(mesh) if _fits(rows, dp) else None,
                                "model" if _fits(cols, md) else None]
    else:
        model_dim, data_dim = rows, cols
        spec = [None] * lead + ["model" if _fits(rows, md) else None,
                                _dp_axes(mesh) if _fits(cols, dp) else None]
    if not _fits(model_dim, md):
        report.fallback(path, f"model dim {model_dim} % {md} != 0")
    if not _fits(data_dim, dp):
        report.fallback(path, f"data dim {data_dim} % {dp} != 0")
    return P(*spec)


# param-path suffixes that are column-parallel (last dim over model)
_COL = ("wq", "wk", "wv", "w_in", "w_gate", "w_up", "w_x", "w_xz", "w_bc",
        "w_q", "w_k", "w_v", "w_z", "unembed", "a_w1", "router", "w_if",
        "w_dt")
# row-parallel (first matmul dim over model)
_ROW = ("wo", "w_out", "w_down", "a_w2", "tok")


def _param_rule(mesh, path: str, arr, report) -> P:
    name = path.split("/")[-1]
    shape = arr.shape
    nd = len(shape)
    md = _axis_size(mesh, "model")
    # Embedding tables: vocab-TP ONLY (no FSDP on the feature dim).  Sharding
    # d over "data" here poisons the gather/unembed with token-replication
    # ("involuntary full rematerialization" in the SPMD partitioner).
    if name == "tok":
        return P("model" if _fits(shape[0], md) else None, None)
    if name == "unembed":
        return P(None, "model" if _fits(shape[1], md) else None)
    # ApproxFFN: approximators + router are tiny (n x d x d_hidden); TP
    # sharding them only buys per-layer all-reduces of the (n, T, h)
    # activations (§Perf C.2) — replicate instead.  The stacks (and their
    # biases, 2D since the serving-form prepad) must stay whole: the serve
    # shard_map declares them replicated in approx_serve_specs.
    if "approx/" in path and name in ("a_w1", "a_w2", "router",
                                      "a_b1", "a_b2"):
        return P(*([None] * nd))
    # the tick-router head (models/model.py, route_scope="tick") is one
    # (d, n+1) classifier applied once per decode tick — replicated for
    # the same reason the per-layer routers are
    if name == "tick_router":
        return P(*([None] * nd))
    # count leading stack dims: params under blocks/ carry 1 (uniform) or 2
    # (xlstm/hybrid inner) scan dims; detect by path prefix
    lead = 0
    if path.startswith(("blocks/", "mlstm/", "slstm/", "mamba/")):
        lead = 2 if path.startswith(("mlstm/", "mamba/")) else 1
    mat_nd = nd - lead

    if mat_nd <= 1:
        return P()  # biases, norms, scalars: replicated
    if name in ("w_in", "w_gate", "w_out") and mat_nd == 3:
        # MoE expert-stacked weights (E, d, f)/(E, f, d): EP over model
        e = shape[lead]
        md = _axis_size(mesh, "model")
        if _fits(e, md):
            return P(*([None] * lead), "model",
                     _dp_axes(mesh) if _fits(shape[lead + 1], _dp_size(mesh)) else None,
                     None)
        report.fallback(path, f"EP: {e} experts % {md} != 0 -> TP-in-expert")
        col = name != "w_out"
        inner = _spec2d(mesh, path, shape, lead + 1, col, report)
        return P(*([None] * (lead + 1)), *inner[lead + 1:])
    if name in ("a_w1", "a_w2", "w_h") and mat_nd == 3:
        # stacked approximators (n, d, h) / sLSTM per-head recurrent (H, hd, 4hd)
        inner = _spec2d(mesh, path, shape, lead + 1, name != "a_w2", report)
        return P(*([None] * (lead + 1)), *inner[lead + 1:])
    if mat_nd == 2:
        if name in _COL:
            return _spec2d(mesh, path, shape, lead, True, report)
        if name in _ROW:
            return _spec2d(mesh, path, shape, lead, False, report)
        # unknown 2D param: shard the larger dim over model if it divides
        return _spec2d(mesh, path, shape, lead, shape[lead + 1] >= shape[lead],
                       report)
    report.fallback(path, f"no rule for ndim={nd}; replicated")
    return P(*([None] * nd))


def _tree_paths(tree) -> dict[str, Any]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def param_pspecs(mesh: Mesh, params) -> tuple[Any, ShardingReport]:
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs)."""
    report = ShardingReport()
    flat = _tree_paths(params)
    specs = {k: _param_rule(mesh, k, v, report) for k, v in flat.items()}

    def rebuild(path_prefix, subtree):
        if isinstance(subtree, dict):
            return {k: rebuild(f"{path_prefix}{k}/", v) for k, v in subtree.items()}
        if isinstance(subtree, (list, tuple)):
            return type(subtree)(rebuild(f"{path_prefix}{i}/", v)
                                 for i, v in enumerate(subtree))
        return specs[path_prefix[:-1]]
    return rebuild("", params), report


# ---------------------------------------------------------------------------
# shard_map in/out specs for the serving dispatch paths.  These are the
# declarative contracts the manual (shard_map) serve code is written
# against; keeping them here (next to the param rules) means tests and
# benches can build the exact same shardings the models use.
# ---------------------------------------------------------------------------

def shard_capacity(t_local: int, frac: float, *, slack: float = 1.0) -> int:
    """Per-shard capacity for a capacity fraction of a row-sharded batch.

    The engine dispatches per data shard, so a class hot on ONE shard drops
    rows even when another shard has slack.  ``slack`` is the rebalancing
    hook: it over-provisions every shard's budget (slack > 1 trades
    ``(slack - 1) * frac * t_local`` rows of padded compute per shard for
    headroom against cross-shard skew; a capacity autotuner raises it when
    drops persist at an operating point whose GLOBAL budget looks
    sufficient).  ``slack=1.0`` reproduces the historic per-shard formula
    exactly; the result is clamped to the shard's row count (capacity past
    t_local can never fill).
    """
    return max(min(int(t_local * frac * slack), t_local), 1)


def mcma_dispatch_specs(mesh: Mesh, *, data_axes=None,
                        with_mask: bool = False,
                        with_tier: bool = False,
                        with_residency: bool = False) -> dict:
    """Specs for ``runtime/dispatch.mcma_dispatch_sharded`` on flat (T, d)
    row batches: x/logits/y row-sharded over the data axes; exact params,
    router logits producer, and the stacked approximator weights
    replicated; invoke_stats replicated out (psum-reduced inside).
    ``with_mask`` appends the (T,) active-row mask, row-sharded like x;
    ``with_tier`` appends the (T,) QoS tier vector (row-sharded) plus the
    (n_tiers,) traced margins vector (replicated — every shard applies
    the same tier->margin map to its own rows); ``with_residency``
    appends the (n_resident,) library-residency map (replicated — every
    shard folds library classes onto the same resident slots, and the
    lib/off-set stats psum to the same global totals)."""
    dp = tuple(data_axes) if data_axes is not None else _dp_axes(mesh)
    row = P(dp, None)
    # in: (x, logits, exact_params, a_w1, a_b1, a_w2, a_b2[, row_mask]
    #      [, tier, tier_margins][, residency]);
    # P() prefixes cover arbitrary exact_params pytrees.
    ins = (row, row, P(), P(None, None, None), P(None, None),
           P(None, None, None), P(None, None))
    if with_mask:
        ins = ins + (P(dp),)
    if with_tier:
        ins = ins + (P(dp), P(None))
    if with_residency:
        ins = ins + (P(None),)
    return {"in": ins, "out": (row, P())}


def dispatch_plan_specs(mesh: Mesh, like=None, *, data_axes=None,
                        n_approx=None, exact_cap=None, invoke_cap=None,
                        block_t=None, backend=None, n_tiers=1,
                        library_size=0):
    """PartitionSpecs for a ``runtime/dispatch.DispatchPlan`` built and
    consumed inside the same shard_map region over the data axes.

    Row-shaped fields (``cls``/``rank``/``eff``/``order``/``pos``/
    ``exact_keep``/``exact_slot``/``tier``) are row-sharded — their
    values are SHARD-LOCAL indices, which is exactly what re-entering a
    shard_map with the same row sharding restores; ``tile_cls`` shards
    its per-shard tile runs the same way; the psum-reduced count fields
    (``counts``/``dispatched``/``t_total``/``executed``, the per-tier
    ``tier_counts``/``tier_dispatched`` matrices, and the library
    ``lib_counts``/``off_set_rows``) are replicated.
    Returns a DispatchPlan-of-specs (the spec tree a shard_map in/out
    position needs), carrying the same static metadata — pass ``like=``
    an existing plan to copy its metadata, or give the meta kwargs
    explicitly when building the out-spec before any plan exists."""
    from repro.runtime.dispatch import DispatchPlan
    if like is not None:
        (n_approx, exact_cap, invoke_cap, block_t, backend, n_tiers,
         library_size) = (
            like.n_approx, like.exact_cap, like.invoke_cap, like.block_t,
            like.backend, like.n_tiers, like.library_size)
    dp = tuple(data_axes) if data_axes is not None else _dp_axes(mesh)
    row, rep = P(dp), P()
    return DispatchPlan(cls=row, rank=row, eff=row, order=row, pos=row,
                        tile_cls=row, exact_keep=row, exact_slot=row,
                        counts=rep, dispatched=rep, t_total=rep,
                        executed=rep, tier=row, tier_counts=rep,
                        tier_dispatched=rep, lib_counts=rep,
                        off_set_rows=rep, n_approx=n_approx,
                        exact_cap=exact_cap, invoke_cap=invoke_cap,
                        block_t=block_t, backend=backend, n_tiers=n_tiers,
                        library_size=library_size)


def approx_serve_specs(mesh: Mesh, *, gated: bool, plan=None,
                       with_tier: bool = False,
                       mask2d: bool = False,
                       with_residency: bool = False) -> dict:
    """Specs for the manual ApproxFFN serve path (models/approx_ffn.py):
    exact FFN weights Megatron-TP over "model" + FSDP over the data axes;
    router/approximators replicated (tiny — TP would only buy per-layer
    all-reduces, §Perf C.2); tokens batch-sharded with their (B,)
    active-slot mask; stats replicated.  ``with_tier`` appends the (B,)
    QoS tier vector (batch-sharded like the mask) and the (n_tiers,)
    traced margins vector (replicated).  ``mask2d`` declares the mask as
    the chunked-prefill TOKEN mask (B, S) — batch-sharded on its leading
    dim like the tokens it gates.  ``plan`` (a DispatchPlan, tick
    scope) swaps the mask+stats plumbing for the precomputed plan: in =
    (weights, x, plan), out = y only (the plan already carries the global
    stats — and the tier split, so no tier args re-enter).
    ``with_residency`` appends the replicated (n_resident,) library
    residency map (layer scope; a tick plan already embeds the fold and
    the stacks are gathered outside the shard_map)."""
    dp = _dp_axes(mesh)
    ffn = {"w_in": P(dp, "model"), "w_out": P("model", dp)}
    if gated:
        ffn["w_gate"] = P(dp, "model")
    weights = {"ffn": ffn, "router": P(None, None),
               "a_w1": P(None, None, None), "a_b1": P(None, None),
               "a_w2": P(None, None, None), "a_b2": P(None, None)}
    if plan is not None:
        return {"in": (weights, P(dp, None, None),
                       dispatch_plan_specs(mesh, plan, data_axes=dp)),
                "out": P(dp, None, None)}
    ins = (weights, P(dp, None, None), P(dp))
    if with_tier:
        ins = ins + (P(dp), P(None))
    if with_residency:
        ins = ins + (P(None),)
    return {"in": ins, "out": (P(dp, None, None), P())}


def moe_manual_specs(mesh: Mesh, *, gated: bool) -> dict:
    """Specs for the manual expert-parallel MoE path (models/moe.py):
    expert stacks EP over "model" + FSDP over data; router TP'd over both;
    tokens batch-sharded; aux loss replicated."""
    dp = _dp_axes(mesh)
    weights = {"router": P(dp, "model"),
               "w_in": P("model", dp, None), "w_out": P("model", dp, None)}
    if gated:
        weights["w_gate"] = P("model", dp, None)
    return {"in": (weights, P(dp, None, None)),
            "out": (P(dp, None, None), P())}


def batch_pspec(mesh: Mesh, arr_or_spec) -> P:
    """Inputs/labels: batch over the DP meta-axis; embeddings also feature-
    sharded over model.  Falls back to sequence sharding when B is small
    (long_500k decode with B=1)."""
    shape = arr_or_spec.shape
    dp = _dp_axes(mesh)
    b = shape[0]
    if _fits(b, _dp_size(mesh)):
        spec = [dp] + [None] * (len(shape) - 1)
    elif len(shape) >= 2 and _fits(shape[1], _dp_size(mesh)):
        spec = [None, dp] + [None] * (len(shape) - 2)   # sequence-sharded
    else:
        spec = [None] * len(shape)
    if len(shape) == 3 and _fits(shape[-1], _axis_size(mesh, "model")):
        spec[-1] = "model"                               # stub embeddings
    return P(*spec)


def _cache_rule(mesh, path: str, arr, *, paged: bool = False) -> P:
    name = path.split("/")[-1]
    shape = arr.shape
    md = _axis_size(mesh, "model")
    dp = _dp_size(mesh)
    dpa = _dp_axes(mesh)
    if name == "pos" or len(shape) <= 1:
        return P()
    if name == "block_table":
        # (B, n_pp) int32: rides next to the batch like the k/v rows it
        # indexes
        return P(dpa if _fits(shape[0], dp) else None, None)
    if name in ("k", "v") and paged:
        # paged pool (L, n_pages, page_size, KV, hd): pages REPLICATE
        # over data — any data shard's slot may hold any pool page, and
        # sharding pages over data would partition the gathered Skv
        # contraction (a different reduction order than the dense oracle,
        # breaking bit-exactness).  Heads (else head_dim) shard over
        # model exactly like the dense cache, so the attention einsums
        # see the same per-shard operands either way.
        l_, npg, ps, kv, hd = shape
        spec = [None, None, None, None, None]
        if _fits(kv, md):
            spec[3] = "model"
        elif _fits(hd, md):
            spec[4] = "model"
        return P(*spec)
    if name in ("k", "v"):
        # (L, B, S, KV, hd) or (G, B, S, KV, hd)
        l_, b, s, kv, hd = shape
        spec = [None,
                dpa if _fits(b, dp) else None,
                None, None, None]
        if spec[1] is None and _fits(s, dp):
            spec[2] = dpa                                # context-parallel
        if _fits(kv, md):
            spec[3] = "model"
        elif _fits(hd, md):
            spec[4] = "model"
        return P(*spec)
    # SSM/mLSTM/sLSTM states: (..., B, H, ...) — find B = first dim that
    # matches known batch position: states are (G[,k], B, H, ...)
    lead = 2 if path.startswith(("mlstm/", "mamba/")) else 1
    spec = [None] * len(shape)
    if _fits(shape[lead], dp):
        spec[lead] = dpa
    if len(shape) > lead + 1 and _fits(shape[lead + 1], md):
        spec[lead + 1] = "model"
    return P(*spec)


def cache_pspecs(mesh: Mesh, cache):
    flat = _tree_paths(cache)
    paged = any(k.split("/")[-1] == "block_table" for k in flat)
    specs = {k: _cache_rule(mesh, k, v, paged=paged) for k, v in flat.items()}

    def rebuild(prefix, subtree):
        if isinstance(subtree, dict):
            return {k: rebuild(f"{prefix}{k}/", v) for k, v in subtree.items()}
        if isinstance(subtree, (list, tuple)):
            return type(subtree)(rebuild(f"{prefix}{i}/", v)
                                 for i, v in enumerate(subtree))
        return specs[prefix[:-1]]
    return rebuild("", cache)


def state_pspecs(mesh: Mesh, state):
    """TrainState {"params", "opt": {"m","v"}, "step"}: optimizer moments
    shard exactly like their parameters (FSDP)."""
    pspecs, report = param_pspecs(mesh, state["params"])
    return {"params": pspecs,
            "opt": jax.tree.map(lambda _: pspecs, state["opt"],
                                is_leaf=lambda x: x is state["opt"]["m"]
                                or x is state["opt"]["v"]),
            "step": P()}, report
