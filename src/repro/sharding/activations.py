"""Activation sharding constraints, injected without threading a mesh
through the model code.

``activation_sharding(spec)`` is a context manager holding the
PartitionSpec to constrain the residual stream to at block boundaries;
``constrain(x)`` applies it (no-op outside the context or when the spec's
rank doesn't match).  The dry-run/trainer set it around tracing:

    with mesh, activation_sharding(P(("pod", "data"), None, None)):
        lowered = jax.jit(step, ...).lower(...)

Baseline = batch-sharded residuals; the SP variant (P(dp, "model", None))
shards the sequence over the TP axis between blocks (Megatron-style
sequence parallelism) — a §Perf lever for the memory term.
"""
from __future__ import annotations

import contextlib
from contextvars import ContextVar

import jax

_SPEC: ContextVar = ContextVar("activation_spec", default=None)


@contextlib.contextmanager
def activation_sharding(spec):
    tok = _SPEC.set(spec)
    try:
        yield
    finally:
        _SPEC.reset(tok)


def constrain(x: jax.Array) -> jax.Array:
    spec = _SPEC.get()
    if spec is None or len(spec) != x.ndim:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_logits(x: jax.Array) -> jax.Array:
    """(B, S, V) logits: batch like the residual stream, vocab over model."""
    spec = _SPEC.get()
    if spec is None or x.ndim != 3:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(spec[0], None, "model"))


def constrain_tokens(x: jax.Array) -> jax.Array:
    """(B, S) per-token values (labels, losses): batch-sharded."""
    spec = _SPEC.get()
    if spec is None or x.ndim != 2:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(spec[0], None))


def manual_dp_context():
    """(mesh, dp_axes) when tracing under a mesh with an activation spec —
    lets modules (MoE) shard_map themselves over the data axes while the
    model axis stays auto.  (None, ()) outside distributed tracing."""
    spec = _SPEC.get()
    if spec is None or spec[0] is None:
        return None, ()
    try:
        from jax._src.mesh import thread_resources
        mesh = thread_resources.env.physical_mesh
    except Exception:
        return None, ()
    if mesh is None or mesh.empty:
        return None, ()
    dp = spec[0]
    return mesh, tuple(dp) if isinstance(dp, (tuple, list)) else (dp,)
