"""jax API compatibility for shard_map.

The manual-dispatch paths are written against the modern top-level
``jax.shard_map(..., axis_names=..., check_vma=...)`` API; older jax
releases (<= 0.4.x) only ship ``jax.experimental.shard_map.shard_map``
with ``check_rep``/``auto``.  ``shard_map_compat`` bridges the two:
``axis_names`` lists the MANUAL axes, everything else in the mesh stays
auto — on the old API that is ``auto = mesh.axis_names - axis_names``.
"""
from __future__ import annotations

import jax


def shard_map_compat(f, *, mesh, in_specs, out_specs, axis_names,
                     check: bool = False):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             axis_names=frozenset(axis_names),
                             check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check, auto=auto)
