"""Production meshes.  Functions, not module constants — importing this
module never touches jax device state.

Single pod: (16, 16) ("data", "model") = 256 chips (one v5e pod).
Multi-pod:  (2, 16, 16) ("pod", "data", "model") = 512 chips; the leading
"pod" axis is pure data-parallelism whose collectives cross the
data-center interconnect (gradient all-reduce only — see
optim/compression.py for the int8 cross-pod variant).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = data or (n // model)
    return jax.make_mesh((data, model), ("data", "model"))
