import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# NOTE: the two lines above MUST run before any jax import — jax locks the
# device count on first init.  Everything below is ordinary code.
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract the roofline inputs from the compiled
artifact (no device allocation — inputs are ShapeDtypeStructs).

    python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k --mesh multi
    python -m repro.launch.dryrun --all            # sweep, one subprocess per cell

Per cell this writes runs/dryrun/<arch>__<shape>__<mesh>[__tag].json with:
  memory_analysis   (per-chip bytes: args/outputs/temps/alias)
  cost_analysis     (per-chip HLO flops + bytes accessed)
  collectives       (wire-bytes per chip by op kind, ring cost model,
                     pod-crossing bytes counted separately)
  model_flops       (6*N*D train / 2*N*D forward, N = active params)
  timings           (lower/compile wall seconds)
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "runs", "dryrun")

def _count_params(tree) -> int:
    import jax
    return sum(int(l.size) for l in jax.tree_util.tree_leaves(tree)
               if hasattr(l, "size"))


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             approx: bool = False, act_shard: str = "",
             tag: str = "") -> dict:
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config, input_specs
    from repro.launch.mesh import make_production_mesh
    from repro.models.model import init_cache
    from repro.runtime import steps as S
    from repro.sharding import activations as A
    from repro.sharding import rules as R

    cfg = get_config(arch)
    if approx:
        cfg = dataclasses.replace(cfg, approx=dataclasses.replace(
            cfg.approx, enable=True))
    act_shard = act_shard or cfg.act_shard
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    pod_boundary = 256 if mesh_kind == "multi" else None
    specs = input_specs(cfg, shape)

    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "chips": int(n_chips), "approx": approx, "act_shard": act_shard,
              "ok": False}

    ns = lambda spec: jax.tree.map(lambda p: NamedSharding(mesh, p), spec,
                                   is_leaf=lambda x: isinstance(x, P))
    dp = ("pod", "data") if mesh_kind == "multi" else ("data",)
    act_spec = {"dp": P(dp, None, None), "sp": P(dp, "model", None),
                "fp": P(dp, None, "model"), "none": None}[act_shard]

    t0 = time.time()
    if shape.kind == "train":
        state_shapes = jax.eval_shape(
            lambda: S.init_train_state(jax.random.PRNGKey(0), cfg))
        state_specs, report = R.state_pspecs(mesh, state_shapes)
        batch_specs = {k: R.batch_pspec(mesh, v) for k, v in specs.items()}
        step = S.make_train_step(cfg, grad_accum=cfg.grad_accum)
        jitted = jax.jit(step,
                         in_shardings=(ns(state_specs), ns(batch_specs)),
                         out_shardings=(ns(state_specs), None),
                         donate_argnums=(0,))
        args = (state_shapes, specs)
        n_params = _count_params(state_shapes["params"])
        tokens = shape.global_batch * shape.seq_len
        flops_mult = 6 * tokens
    elif shape.kind == "prefill":
        params_shapes = jax.eval_shape(
            lambda: __import__("repro.models.model", fromlist=["init_model"])
            .init_model(jax.random.PRNGKey(0), cfg))
        param_specs, report = R.param_pspecs(mesh, params_shapes)
        batch_specs = {k: R.batch_pspec(mesh, v) for k, v in specs.items()}
        step = S.make_prefill_step(cfg)
        jitted = jax.jit(step,
                         in_shardings=(ns(param_specs), ns(batch_specs)),
                         out_shardings=None)
        args = (params_shapes, specs)
        n_params = _count_params(params_shapes)
        flops_mult = 2 * shape.global_batch * shape.seq_len
    else:  # decode
        from repro.models.model import init_model
        params_shapes = jax.eval_shape(
            lambda: init_model(jax.random.PRNGKey(0), cfg))
        param_specs, report = R.param_pspecs(mesh, params_shapes)
        cache_shapes = specs["cache"]
        cache_specs = R.cache_pspecs(mesh, cache_shapes)
        in_spec = R.batch_pspec(mesh, specs["inputs"])
        step = S.make_decode_step(cfg)
        jitted = jax.jit(step,
                         in_shardings=(ns(param_specs), ns(cache_specs),
                                       NamedSharding(mesh, in_spec)),
                         out_shardings=(None, ns(cache_specs)),
                         donate_argnums=(1,))
        args = (params_shapes, cache_shapes, specs["inputs"])
        n_params = _count_params(params_shapes)
        flops_mult = 2 * shape.global_batch

    # MoE: only the routed experts' FLOPs are "useful"
    if cfg.moe.n_experts:
        dense_ffn = cfg.n_layers * (3 if cfg.gated_ffn else 2) \
            * cfg.d_model * cfg.d_ff
        n_active = n_params - (cfg.moe.n_experts - cfg.moe.top_k) * dense_ffn
    else:
        n_active = n_params
    result["n_params"] = int(n_params)
    result["n_active"] = int(n_active)
    result["model_flops"] = float(flops_mult) * n_active
    result["sharding_fallbacks"] = report.fallbacks

    try:
        with mesh, A.activation_sharding(act_spec):
            lowered = jitted.lower(*args)
            result["t_lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            result["t_compile_s"] = round(time.time() - t1, 1)
    except Exception as e:  # a failed cell is a bug — record and surface
        result["error"] = f"{type(e).__name__}: {e}"[:2000]
        return result

    ma = compiled.memory_analysis()
    result["memory"] = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "peak_bytes": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                       + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
    }
    result["fits_16g"] = result["memory"]["peak_bytes"] <= 16 * 1024 ** 3
    # analytic HBM-traffic floor: every argument read once, output written
    # once, temp written+read once.  The walker bytes above are the upper
    # bound (CPU fusion granularity); true TPU traffic lies between.
    result["bytes_floor_per_chip"] = float(
        ma.argument_size_in_bytes + ma.output_size_in_bytes
        + 2 * ma.temp_size_in_bytes)
    ca = compiled.cost_analysis() or {}
    # raw cost_analysis kept for reference; the roofline uses the
    # trip-count-aware walker (cost_analysis counts while bodies ONCE)
    result["cost_analysis_raw"] = {
        "flops_per_chip": float(ca.get("flops", -1.0)),
        "bytes_per_chip": float(ca.get("bytes accessed", -1.0))}
    from repro.launch import hlo_cost
    hc = hlo_cost.analyze(compiled.as_text(),
                          pod_size=256 if pod_boundary else None)
    result["cost"] = {"flops_per_chip": hc.flops, "bytes_per_chip": hc.bytes}
    result["collectives"] = {
        "wire_bytes_per_chip": hc.wire_bytes, "dci_bytes_per_chip": hc.dci_bytes,
        "by_kind": hc.coll_by_kind, "counts": hc.coll_counts,
        "n_while": hc.n_while, "max_trip": hc.max_trip}
    result["ok"] = True
    return result


# ---------------------------------------------------------------------------
# Roofline terms (v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s link
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
DCI_BW = 25e9  # cross-pod per-chip share (DESIGN.md §5)


def roofline_terms(cell: dict) -> dict:
    c = cell["cost"]
    coll = cell["collectives"]
    t_compute = c["flops_per_chip"] / PEAK_FLOPS
    t_memory = c["bytes_per_chip"] / HBM_BW
    t_coll = (coll["wire_bytes_per_chip"] - coll["dci_bytes_per_chip"]) / LINK_BW \
        + coll["dci_bytes_per_chip"] / DCI_BW
    dom = max((("compute", t_compute), ("memory", t_memory),
               ("collective", t_coll)), key=lambda kv: kv[1])[0]
    total_flops = c["flops_per_chip"] * cell["chips"]
    return {"t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "bottleneck": dom,
            "useful_flops_ratio": cell["model_flops"] / max(total_flops, 1.0),
            "roofline_frac": t_compute / max(t_compute, t_memory, t_coll, 1e-30)}


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _cell_path(out_dir, arch, shape, mesh, tag):
    name = f"{arch}__{shape}__{mesh}" + (f"__{tag}" if tag else "")
    return os.path.join(out_dir, name + ".json")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--approx", action="store_true",
                    help="enable the ApproxFFN (MCMA) layer")
    ap.add_argument("--act-shard", choices=["", "dp", "sp", "fp", "none"], default="")
    ap.add_argument("--tag", default="")
    ap.add_argument("--all", action="store_true",
                    help="sweep every cell in fresh subprocesses")
    ap.add_argument("--mesh-all", action="store_true",
                    help="with --all: both meshes (default: single only)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        from repro.configs.registry import ARCH_IDS, cells
        meshes = ["single", "multi"] if args.mesh_all else ["single"]
        todo = [(a, sh.name, m) for a in ARCH_IDS for sh in cells(a)
                for m in meshes]
        done = failed = 0
        for a, s, m in todo:
            path = _cell_path(args.out, a, s, m, args.tag)
            if os.path.exists(path) and not args.force:
                print(f"skip {a} {s} {m} (exists)")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", a,
                   "--shape", s, "--mesh", m, "--out", args.out]
            if args.tag:
                cmd += ["--tag", args.tag]
            if args.act_shard:
                cmd += ["--act-shard", args.act_shard]
            print(f"[{done + failed + 1}/{len(todo)}] {a} {s} {m} ...", flush=True)
            r = subprocess.run(cmd, capture_output=True, text=True)
            ok = False
            if os.path.exists(path):
                ok = json.load(open(path)).get("ok", False)
            done += ok
            failed += not ok
            if not ok:
                print(r.stdout[-1500:], r.stderr[-1500:], flush=True)
        print(f"sweep: {done} ok, {failed} failed")
        return 1 if failed else 0

    cell = run_cell(args.arch, args.shape, args.mesh, approx=args.approx,
                    act_shard=args.act_shard, tag=args.tag)
    if cell["ok"]:
        cell["roofline"] = roofline_terms(cell)
    path = _cell_path(args.out, args.arch, args.shape, args.mesh, args.tag)
    with open(path, "w") as f:
        json.dump(cell, f, indent=1)
    print(json.dumps(cell, indent=1))
    return 0 if cell["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
