"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \\
        --steps 50 --ckpt-dir runs/ck_olmo

On a real cluster this binary runs once per host (jax.distributed
initializes from the cluster env); here it drives the same Trainer on
whatever devices exist.  ``--smoke`` selects the reduced config;
``--mesh data,model`` shards over local devices.
"""
from __future__ import annotations

import argparse
import dataclasses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--approx", action="store_true",
                    help="enable the MCMA ApproxFFN layer")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--mesh", default="", help="e.g. '4,2' => (data, model)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    from repro.configs.registry import get_config, smoke_config
    from repro.data.pipeline import SyntheticLM
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    if args.approx:
        cfg = dataclasses.replace(cfg, approx=dataclasses.replace(
            cfg.approx, enable=True))
    mesh = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        mesh = jax.make_mesh(shape, ("data", "model")[:len(shape)])

    ds = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq_len,
                     global_batch=args.batch, seed=args.seed)
    tc = TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir, base_lr=args.lr,
                       warmup=max(args.steps // 10, 1),
                       grad_accum=args.grad_accum)
    out = Trainer(cfg, tc, ds, mesh=mesh, seed=args.seed).run()
    print(f"done: {out}")
    return out


if __name__ == "__main__":
    main()
