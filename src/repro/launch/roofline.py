"""Roofline report: aggregates runs/dryrun/*.json into the §Roofline table.

    python -m repro.launch.roofline [--dir runs/dryrun] [--tag TAG]

Per cell: the three terms (seconds), the dominant bottleneck, the useful-
FLOPs ratio (MODEL_FLOPS / HLO_FLOPs), and a one-line lever suggestion.
Markdown to stdout + runs/roofline.md.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.launch.dryrun import roofline_terms

LEVERS = {
    "compute": "raise MXU utilization: bigger per-chip tiles (less TP), "
               "causal block-skip in attention, fewer remat recomputes",
    "memory": "cut HBM traffic: fuse approximator/attention blocks (Pallas), "
              "bf16 residuals, sequence-parallel residual saves",
    "collective": "overlap/shrink collectives: 2D-shard FFN all-reduce -> "
                  "reduce-scatter+all-gather, int8 cross-pod grads, "
                  "latency-hiding scheduler",
}


def load_cells(dir_: str, tag: str = ""):
    cells = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        name = os.path.basename(path)[:-5]
        parts = name.split("__")
        cell_tag = parts[3] if len(parts) > 3 else ""
        if cell_tag != tag:
            continue
        d = json.load(open(path))
        if d.get("ok"):
            d["roofline"] = roofline_terms(d)
        cells.append(d)
    return cells


def fmt_table(cells, mesh="single"):
    rows = [c for c in cells if c["mesh"] == mesh]
    out = ["| arch | shape | compute s | memory s | coll s | bound | "
           "useful | peak GiB | fits |",
           "|---|---|---|---|---|---|---|---|---|"]
    for c in sorted(rows, key=lambda c: (c["arch"], c["shape"])):
        if not c.get("ok"):
            out.append(f"| {c['arch']} | {c['shape']} | FAILED: "
                       f"{c.get('error', '?')[:60]} | | | | | | |")
            continue
        r = c["roofline"]
        out.append(
            f"| {c['arch']} | {c['shape']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"{r['bottleneck'][:4]} | {r['useful_flops_ratio']:.2f} | "
            f"{c['memory']['peak_bytes'] / 2**30:.1f} | "
            f"{'Y' if c['fits_16g'] else 'N'} |")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="runs/roofline.md")
    args = ap.parse_args(argv)
    cells = load_cells(args.dir, args.tag)
    ok = [c for c in cells if c.get("ok")]
    lines = [f"# Roofline table ({len(ok)}/{len(cells)} cells ok, "
             f"tag='{args.tag}')", ""]
    for mesh in ("single", "multi"):
        sub = [c for c in cells if c["mesh"] == mesh]
        if not sub:
            continue
        lines += [f"## mesh = {mesh} ({sub[0]['chips']} chips)", "",
                  fmt_table(cells, mesh), ""]
    # bottleneck histogram + worst cells
    from collections import Counter
    hist = Counter(c["roofline"]["bottleneck"] for c in ok)
    lines += [f"Bottlenecks: {dict(hist)}", ""]
    worst = sorted((c for c in ok if c["mesh"] == "single"),
                   key=lambda c: c["roofline"]["roofline_frac"])[:5]
    lines += ["Worst roofline fraction (single pod):"]
    for c in worst:
        r = c["roofline"]
        lines.append(f"- {c['arch']} {c['shape']}: frac={r['roofline_frac']:.3f}"
                     f" bound={r['bottleneck']} -> {LEVERS[r['bottleneck']]}")
    text = "\n".join(lines)
    print(text)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write(text + "\n")


if __name__ == "__main__":
    main()
