"""Serving launcher: batched decode with the continuous-batching server.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \\
        --smoke --requests 16 --max-new 24

Mesh deployments (``--data``/``--model``) shard params/cache by the
declarative rules and serve every decode tick through the
shard_map-native MCMA dispatch when ``--mcma-dispatch`` is on (on 8 CPU
devices: XLA_FLAGS=--xla_force_host_platform_device_count=8 and
``--data 4 --model 2``).

Serving flags come from the shared ``runtime/cli.add_serve_options``
inventory (one surface with examples/serve_decode.py and
benchmarks/bench_serve.py) and fold into a ``ServeOptions`` via
``ServeOptions.from_args`` — only launcher-specific knobs (arch, mesh
shape, workload) are declared here.
"""
from __future__ import annotations

import argparse
import dataclasses

from repro.runtime.cli import add_serve_options


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--approx", action="store_true")
    ap.add_argument("--data", type=int, default=0,
                    help="mesh data-axis size (0 = no mesh, single device)")
    ap.add_argument("--model", type=int, default=1,
                    help="mesh model-axis size (with --data)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    add_serve_options(ap, batch=4, max_len=128)
    args = ap.parse_args(argv)

    import jax
    import numpy as np
    from repro.configs.registry import get_config, smoke_config
    from repro.models import model as M
    from repro.runtime.options import ServeOptions
    from repro.runtime.server import DecodeServer, Request

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    mesh = None
    if args.data:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(data=args.data, model=args.model)
        assert args.batch % args.data == 0, \
            "--batch must divide by --data for the sharded dispatch path"
    options = ServeOptions.from_args(args, mesh=mesh)
    if args.approx or options.use_mcma_dispatch:
        cfg = dataclasses.replace(cfg, approx=dataclasses.replace(
            cfg.approx, enable=True,
            library_size=options.library.library_size
            if options.library else cfg.approx.library_size))
    params = M.init_model(jax.random.PRNGKey(args.seed), cfg)
    server = DecodeServer(cfg, params, options=options)

    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, args.prompt_len)
                    .astype(np.int32), max_new=args.max_new)
            for i in range(args.requests)]
    if options.qos_tiers:
        # mixed-tier request wave: cycle the tier table's bounds (plus a
        # default-tier request) so one batch carries every QoS level
        bounds = server.tier_bounds
        for i, r in enumerate(reqs):
            choices = list(bounds) + [None]
            r.error_bound = choices[i % len(choices)]
    for r in reqs:
        server.submit(r)
    stats = server.run_until_drained()
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out) for r in reqs)
    print(f"served {done}/{len(reqs)} requests, {toks} tokens, "
          f"{stats['ticks']} ticks ({stats['prefill_ticks']} prefill, "
          f"chunk={server.prefill_chunk}), {stats['wall_s']:.1f}s "
          f"({toks / max(stats['wall_s'], 1e-9):.1f} tok/s aggregate)")
    ttft = [r.first_token_tick - r.arrival_tick for r in reqs
            if r.first_token_tick is not None]
    if ttft:
        print(f"ttft: mean {np.mean(ttft):.1f} ticks, "
              f"max {max(ttft)} ticks")
    if stats["undrained_queued"] or stats["undrained_inflight"]:
        print(f"WARNING: undrained — {stats['undrained_queued']} queued, "
              f"{stats['undrained_inflight']} in flight marked aborted")
    if mesh is not None:
        print(f"mesh: data={args.data} model={args.model} "
              f"({len(jax.devices())} devices, shard_map-native dispatch)")
    if "invocation_rate" in stats:
        print(f"mean invocation rate: {stats['invocation_rate']:.3f}")
    if "served_invocation_rate" in stats:
        print(f"served invocation rate: {stats['served_invocation_rate']:.3f}"
              f" (dropped {stats['dropped_rows']:.1f} rows,"
              f" frac {stats['dropped_frac']:.4f})")
    if "per_tier" in stats:
        for p in stats["per_tier"]:
            print(f"tier {p['tier']} (bound {p['error_bound']:.3f}, "
                  f"margin {p['margin']:+.2f}): {p['rows']:.0f} rows, "
                  f"served invocation {p['served_invocation_rate']:.3f}, "
                  f"dropped_frac {p['dropped_frac']:.4f}")
    if "residency" in stats:
        r = stats["residency"]
        print(f"residency: final hot set {r['final_residency']} after "
              f"{r['swap_count']} swaps "
              f"(off-set exact rows {stats['off_set_exact_rows']:.1f})")
    if "autotune" in stats:
        a = stats["autotune"]
        print(f"autotune: final point {a['final_point']} after "
              f"{len(a['switches'])} switches")
        if server.routed_history:
            lad = server.derived_ladder()
            print("ladder_from_counts (served class-count quantiles -> "
                  "asymmetric per-class rungs for the next deployment):")
            for r in lad:
                print(f"  exact_frac={r.exact_frac:.3f} "
                      f"invoke_fracs={tuple(round(f, 3) for f in r.invoke_fracs)}")
    assert done == len(reqs), "server failed to drain"
    return stats


if __name__ == "__main__":
    main()
