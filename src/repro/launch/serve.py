"""Serving launcher: batched decode with the continuous-batching server.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \\
        --smoke --requests 16 --max-new 24

Mesh deployments (``--data``/``--model``) shard params/cache by the
declarative rules and serve every decode tick through the
shard_map-native MCMA dispatch when ``--mcma-dispatch`` is on (on 8 CPU
devices: XLA_FLAGS=--xla_force_host_platform_device_count=8 and
``--data 4 --model 2``).
"""
from __future__ import annotations

import argparse
import dataclasses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--approx", action="store_true")
    ap.add_argument("--mcma-dispatch", action="store_true",
                    help="serve the ApproxFFN through the Pallas "
                         "weight-switch dispatch engine (implies --approx)")
    ap.add_argument("--autotune", action="store_true",
                    help="adapt serve capacities online from the served "
                         "invoke_stats (runtime/autotune.py; implies "
                         "--mcma-dispatch): the controller walks a ladder "
                         "of precompiled operating points targeting "
                         "--drop-budget dropped rows at max invocation")
    ap.add_argument("--drop-budget", type=float, default=0.05,
                    help="autotune target: max fraction of routed rows "
                         "dropped over capacity (default 0.05)")
    ap.add_argument("--route-scope", choices=("layer", "tick"), default=None,
                    help="MCMA routing granularity at decode: 'tick' makes "
                         "ONE dispatch plan per tick (tick-router head, "
                         "reused by every layer of the scan — the paper's "
                         "per-input decision); 'layer' routes per layer "
                         "(default: the config's route_scope)")
    ap.add_argument("--qos", action="store_true",
                    help="per-request QoS tiers (implies --mcma-dispatch): "
                         "each request carries an error_bound, validated "
                         "and quantized onto the tier table at submit "
                         "time; the request wave mixes tiers and the "
                         "drain summary reports served invocation + "
                         "dropped_frac per tier")
    ap.add_argument("--qos-app", default=None,
                    help="apps/registry.py app whose quality.py error "
                         "bound anchors the QoS tier table and the "
                         "submit-time validation (implies --qos; default "
                         "anchor: the config's approx.error_bound)")
    ap.add_argument("--tier-bounds", default=None,
                    help="comma-separated ascending error bounds "
                         "overriding the default (tight, base, loose) "
                         "tier table, e.g. 0.05,0.1,0.2")
    ap.add_argument("--data", type=int, default=0,
                    help="mesh data-axis size (0 = no mesh, single device)")
    ap.add_argument("--model", type=int, default=1,
                    help="mesh model-axis size (with --data)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="chunked prefill: S prompt tokens per prefill "
                         "tick through the compiled chunk step, "
                         "interleaved with decode ticks (0 = token-by-"
                         "token reference mode; non-uniform families "
                         "fall back automatically)")
    ap.add_argument("--admission", choices=("cost", "fifo"), default="cost",
                    help="queue admission: 'cost' = prompt length x QoS "
                         "tier multiplier with aging (default), 'fifo' = "
                         "strict arrival order")
    ap.add_argument("--overflow", choices=("reject", "trim"),
                    default="reject",
                    help="submit-time policy when prompt + max_new "
                         "exceeds max_len: reject loudly (default) or "
                         "trim the prompt to its last max_len - max_new "
                         "tokens")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import numpy as np
    from repro.configs.registry import get_config, smoke_config
    from repro.models import model as M
    from repro.runtime.server import DecodeServer, Request

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    if args.qos_app or args.tier_bounds:
        args.qos = True
    if args.autotune or args.qos:
        args.mcma_dispatch = True
    if args.approx or args.mcma_dispatch:
        cfg = dataclasses.replace(cfg, approx=dataclasses.replace(
            cfg.approx, enable=True))
    mesh = None
    if args.data:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(data=args.data, model=args.model)
        assert args.batch % args.data == 0, \
            "--batch must divide by --data for the sharded dispatch path"
    params = M.init_model(jax.random.PRNGKey(args.seed), cfg)
    qos_tiers = True if args.qos else None
    if args.tier_bounds:
        qos_tiers = tuple(float(b) for b in args.tier_bounds.split(","))
    server = DecodeServer(cfg, params, batch=args.batch, max_len=args.max_len,
                          use_mcma_dispatch=args.mcma_dispatch, mesh=mesh,
                          autotune=args.autotune,
                          drop_budget=args.drop_budget,
                          route_scope=args.route_scope,
                          qos_tiers=qos_tiers, qos_app=args.qos_app,
                          prefill_chunk=args.prefill_chunk,
                          admission=args.admission, overflow=args.overflow)

    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, args.prompt_len)
                    .astype(np.int32), max_new=args.max_new)
            for i in range(args.requests)]
    if args.qos:
        # mixed-tier request wave: cycle the tier table's bounds (plus a
        # default-tier request) so one batch carries every QoS level
        bounds = server.tier_bounds
        for i, r in enumerate(reqs):
            choices = list(bounds) + [None]
            r.error_bound = choices[i % len(choices)]
    for r in reqs:
        server.submit(r)
    stats = server.run_until_drained()
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out) for r in reqs)
    print(f"served {done}/{len(reqs)} requests, {toks} tokens, "
          f"{stats['ticks']} ticks ({stats['prefill_ticks']} prefill, "
          f"chunk={server.prefill_chunk}), {stats['wall_s']:.1f}s "
          f"({toks / max(stats['wall_s'], 1e-9):.1f} tok/s aggregate)")
    ttft = [r.first_token_tick - r.arrival_tick for r in reqs
            if r.first_token_tick is not None]
    if ttft:
        print(f"ttft: mean {np.mean(ttft):.1f} ticks, "
              f"max {max(ttft)} ticks")
    if stats["undrained_queued"] or stats["undrained_inflight"]:
        print(f"WARNING: undrained — {stats['undrained_queued']} queued, "
              f"{stats['undrained_inflight']} in flight marked aborted")
    if mesh is not None:
        print(f"mesh: data={args.data} model={args.model} "
              f"({len(jax.devices())} devices, shard_map-native dispatch)")
    if "invocation_rate" in stats:
        print(f"mean invocation rate: {stats['invocation_rate']:.3f}")
    if "served_invocation_rate" in stats:
        print(f"served invocation rate: {stats['served_invocation_rate']:.3f}"
              f" (dropped {stats['dropped_rows']:.1f} rows,"
              f" frac {stats['dropped_frac']:.4f})")
    if "per_tier" in stats:
        for p in stats["per_tier"]:
            print(f"tier {p['tier']} (bound {p['error_bound']:.3f}, "
                  f"margin {p['margin']:+.2f}): {p['rows']:.0f} rows, "
                  f"served invocation {p['served_invocation_rate']:.3f}, "
                  f"dropped_frac {p['dropped_frac']:.4f}")
    if "autotune" in stats:
        a = stats["autotune"]
        print(f"autotune: final point {a['final_point']} after "
              f"{len(a['switches'])} switches")
        if server.routed_history:
            lad = server.derived_ladder()
            print("ladder_from_counts (served class-count quantiles -> "
                  "asymmetric per-class rungs for the next deployment):")
            for r in lad:
                print(f"  exact_frac={r.exact_frac:.3f} "
                      f"invoke_fracs={tuple(round(f, 3) for f in r.invoke_fracs)}")
    assert done == len(reqs), "server failed to drain"
    return stats


if __name__ == "__main__":
    main()
