"""Trip-count-aware cost model over optimized HLO text.

``compiled.cost_analysis()`` visits every computation ONCE — a lax.scan
over 80 layers contributes 1/80th of its true FLOPs/bytes/collective
traffic.  Since the whole framework stacks layers with scan (deliberately,
for O(1)-in-depth compile time), we re-derive the three roofline inputs by
walking the HLO text and multiplying through ``while`` ops using the
``known_trip_count`` backend config XLA attaches after loop analysis.

Models (documented assumptions, see EXPERIMENTS.md §Roofline):
* FLOPs: dot ops only (2 * prod(result dims) * prod(contracting dims));
  elementwise/VPU work is ignored — the MXU term dominates on every cell.
* HBM bytes: for each materializing op (fusion, dot, copy, collectives,
  dynamic-(update-)slice, gather/scatter/sort/reduce/broadcast/...) count
  result + operand bytes once.  Post-optimization HLO keeps elementwise
  chains inside fusions, so top-level ops approximate buffer traffic.
* Collective wire bytes per chip (ring algorithms):
    all-reduce 2(k-1)/k * n   all-gather (k-1)/k * n (n = gathered size)
    reduce-scatter (k-1) * n (n = shard)   all-to-all (k-1)/k * n
    collective-permute n
  Groups spanning the pod boundary (device id >= pod_size) are counted
  separately (DCI vs ICI bandwidth).
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16}

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^=]*?\)|[\w]+\[[\d,]*\](?:\{[^}]*\})?)"
    r"\s+([\w\-]+)\((.*)$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\s*\{\s*"n":\s*"(\d+)"')
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_CALL_RE = re.compile(r"(?:body|calls|to_apply)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id", "while",
               "conditional", "call", "custom-call"}


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dt, dims = m.group(1), m.group(2)
    return dt, [int(d) for d in dims.split(",") if d]


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    rtype: str
    op: str
    rest: str          # operand list + attrs (raw tail of the line)

    def operands(self):
        # operand refs up to the closing paren of the operand list
        depth = 1
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return re.findall(r"%([\w.\-]+)", self.rest[:i])
        return re.findall(r"%([\w.\-]+)", self.rest)

    @property
    def attrs(self) -> str:
        return self.rest


_OP_RE = re.compile(r"([\w\-]+)\(")


def _parse_instr(line: str) -> Instr | None:
    """Manual parse — regexes break on tuple types with /*index=N*/ comments."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    rest = s[eq + 3:]
    if rest.startswith("("):                   # tuple type: balanced scan
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i + 1
                    break
        rtype, rest2 = rest[:end], rest[end:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        rtype, rest2 = rest[:sp], rest[sp + 1:].lstrip()
    m = _OP_RE.match(rest2)
    if not m:
        return None
    return Instr(name, rtype, m.group(1), rest2[m.end():])


def parse_module(hlo: str):
    comps: dict[str, list[Instr]] = {}
    types: dict[str, str] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY") or (line.startswith("%") and line.rstrip().endswith("{")):
            m = _COMP_RE.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.startswith("ENTRY"):
                    entry = cur
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        ins = _parse_instr(line)
        if ins:
            comps[cur].append(ins)
            types.setdefault(ins.name, ins.rtype)
    return comps, types, entry


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    wire_bytes: float = 0.0
    dci_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    coll_counts: dict = dataclasses.field(default_factory=dict)
    n_while: int = 0
    max_trip: int = 1

    def add(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.wire_bytes += other.wire_bytes
        self.dci_bytes += other.dci_bytes
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v
        self.n_while += other.n_while
        self.max_trip = max(self.max_trip, other.max_trip)


def _dot_flops(ins: Instr, types: dict) -> float:
    _, rdims = _shape_dims(ins.rtype)
    ops = ins.operands()
    if not ops:
        return 0.0
    lhs_type = types.get(ops[0], "")
    _, ldims = _shape_dims(lhs_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    contract = 1
    if m and ldims:
        for d in m.group(1).split(","):
            if d:
                contract *= ldims[int(d)]
    rsize = 1
    for d in rdims:
        rsize *= d
    return 2.0 * rsize * contract


def _collective(ins: Instr, pod_size: int | None):
    nbytes = _type_bytes(ins.rtype)
    kind = ins.op.replace("-start", "")
    k = 1
    crosses = False
    gm = _GROUPS_LIST_RE.search(ins.rest)
    if gm:
        ids = [int(x) for x in gm.group(1).split(",") if x.strip()]
        k = len(ids)
        if pod_size is not None and ids:
            crosses = min(ids) < pod_size <= max(ids)
    else:
        gm = _GROUPS_IOTA_RE.search(ins.rest)
        if gm:
            k = int(gm.group(2))
            if pod_size is not None:
                # iota groups [G,k]<=[dims]T(perm): a group crosses pods iff
                # its device stride reaches across the boundary; conservative:
                # crosses when the flattened span exceeds one pod
                crosses = int(gm.group(1)) * k > pod_size and k > 1
    if k <= 1:
        return kind, 0.0, False
    if kind == "all-reduce":
        w = 2.0 * (k - 1) / k * nbytes
    elif kind == "all-gather":
        w = (k - 1) / k * nbytes
    elif kind == "reduce-scatter":
        w = float(k - 1) * nbytes
    elif kind == "all-to-all":
        w = (k - 1) / k * nbytes
    else:  # collective-permute
        w = float(nbytes)
    return kind, w, crosses


def analyze(hlo: str, pod_size: int | None = None) -> Cost:
    comps, types, entry = parse_module(hlo)
    memo_guard: set = set()

    def walk(comp: str, mult: float, in_fusion: bool = False) -> Cost:
        cost = Cost()
        if comp not in comps or comp in memo_guard:
            return cost
        memo_guard.add(comp)
        for ins in comps[comp]:
            if ins.op == "while":
                tm = _TRIP_RE.search(ins.rest)
                trip = int(tm.group(1)) if tm else 1
                cost.n_while += 1
                cost.max_trip = max(cost.max_trip, trip)
                bm = _CALL_RE.search(ins.rest)
                if bm:
                    cost.add(walk(bm.group(1), mult * trip))
                continue
            if ins.op in ("fusion", "call", "conditional", "map"):
                bm = _CALL_RE.search(ins.rest)
                if bm:
                    # inside a fusion only FLOPs count — buffer traffic is
                    # the fusion op's own operands/result (counted below)
                    cost.add(walk(bm.group(1), mult,
                                  in_fusion or ins.op == "fusion"))
            if ins.op == "dot":
                cost.flops += _dot_flops(ins, types) * mult
            if ins.op.replace("-start", "") in _COLLECTIVES:
                kind, w, crosses = _collective(ins, pod_size)
                cost.coll_by_kind[kind] = cost.coll_by_kind.get(kind, 0.0) + w * mult
                cost.coll_counts[kind] = cost.coll_counts.get(kind, 0) + 1
                cost.wire_bytes += w * mult
                if crosses:
                    cost.dci_bytes += w * mult
            if (not in_fusion and ins.op not in _SKIP_BYTES
                    and not ins.op.endswith("-done")):
                b = _type_bytes(ins.rtype)
                for o in ins.operands():
                    b += _type_bytes(types.get(o, ""))
                cost.bytes += b * mult
        memo_guard.discard(comp)
        return cost

    if entry is None:
        return Cost()
    return walk(entry, 1.0)
