"""Mamba2 (State-Space Duality) block, chunked-scan formulation.

Implements the SSD recurrence  h_t = exp(a_t) * h_{t-1} + b_t x_t^T,
y_t = c_t^T h_t  with scalar-per-head decay a_t = -softplus(dt) (Mamba2's
``A`` is scalar per head).  Training/prefill uses the chunkwise algorithm:
within-chunk quadratic attention-like term + cross-chunk recurrent state
pass (one lax.scan over chunks), so memory is O(S * chunk) and the sequential
depth is S / chunk.  Decode is the O(1) recurrent update.

State layout: h (B, H, P, N) with P = head dim, N = d_state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def init_mamba(key, cfg: ModelConfig):
    d = cfg.d_model
    d_in = cfg.ssm.expand * d
    n, p_hd = cfg.ssm.d_state, cfg.ssm.head_dim
    n_heads = d_in // p_hd
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        # input projections: x (value path), z (gate), B, C, dt
        "w_xz": jax.random.normal(ks[0], (d, 2 * d_in), cfg.pdtype) * s,
        "w_bc": jax.random.normal(ks[1], (d, 2 * n), cfg.pdtype) * s,
        "w_dt": jax.random.normal(ks[2], (d, n_heads), cfg.pdtype) * s,
        "dt_bias": jnp.zeros((n_heads,), cfg.pdtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(cfg.pdtype),
        "d_skip": jnp.ones((n_heads,), cfg.pdtype),
        "w_out": jax.random.normal(ks[3], (d_in, d), cfg.pdtype) * d_in ** -0.5,
        "norm_scale": jnp.ones((d_in,), cfg.pdtype),
    }


def _split_heads(x, n_heads, p_hd):
    return x.reshape(*x.shape[:-1], n_heads, p_hd)


def _gated_rmsnorm(x, z, scale):
    xf = (x * jax.nn.silu(z)).astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
    return (xf * r).astype(x.dtype) * scale.astype(x.dtype)


def _proj(cfg: ModelConfig, p, u: jax.Array):
    """Shared input projections.  u: (B, S, d)."""
    d_in = cfg.ssm.expand * cfg.d_model
    p_hd = cfg.ssm.head_dim
    n_heads = d_in // p_hd
    xz = jnp.dot(u, p["w_xz"].astype(u.dtype))
    x, z = jnp.split(xz, 2, axis=-1)                      # (B, S, d_in) each
    bc = jnp.dot(u, p["w_bc"].astype(u.dtype))
    b, c = jnp.split(bc, 2, axis=-1)                      # (B, S, N) each
    dt = jax.nn.softplus(jnp.dot(u, p["w_dt"].astype(u.dtype)).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))      # (B, S, H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))          # (H,)
    da = dt * a                                           # log-decay, (B, S, H)
    xh = _split_heads(x, n_heads, p_hd)                   # (B, S, H, P)
    return xh, z, b, c, dt, da


def mamba_fwd(cfg: ModelConfig, p, u: jax.Array, state: dict | None = None):
    """Mamba2 SSD.  u: (B, S, d) -> (y, new_state).

    ``state`` (decode): {"h": (B, H, P, N)} — one-token update when S == 1.
    """
    bsz, s, d = u.shape
    xh, z, b, c, dt, da = _proj(cfg, p, u)
    n_heads, p_hd = xh.shape[2], xh.shape[3]

    if state is not None and s == 1:
        # O(1) decode update: h = exp(da) h + dt * x b^T ; y = h c
        h = state["h"]
        decay = jnp.exp(da[:, 0]).astype(jnp.float32)     # (B, H)
        xb = jnp.einsum("bhp,bn->bhpn", xh[:, 0].astype(jnp.float32),
                        b[:, 0].astype(jnp.float32))
        h = h * decay[..., None, None] + xb * dt[:, 0][..., None, None]
        y = jnp.einsum("bhpn,bn->bhp", h, c[:, 0].astype(jnp.float32))
        y = y + xh[:, 0].astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, :, None]
        y = y.reshape(bsz, 1, n_heads * p_hd).astype(u.dtype)
        y = _gated_rmsnorm(y, z, p["norm_scale"])
        return jnp.dot(y, p["w_out"].astype(u.dtype)), {"h": h}

    # ----- chunked SSD (train / prefill) ------------------------------------
    ck = min(cfg.ssm.chunk, s)
    assert s % ck == 0, (s, ck)
    nc = s // ck
    rs = lambda t: t.reshape(bsz, nc, ck, *t.shape[2:]).swapaxes(0, 1)
    xh_c, b_c, c_c, dt_c, da_c = map(rs, (xh, b, c, dt, da))

    def chunk_step(h, inp):
        xc, bc_, cc, dtc, dac = inp                       # (B, ck, ...)
        # cumulative log-decay within chunk (inclusive)
        cum = jnp.cumsum(dac, axis=1)                     # (B, ck, H)
        total = cum[:, -1]                                # (B, H)
        # 1) contribution of the incoming state: y_intra_state[t] = c_t (prod decay<=t) h
        decay_in = jnp.exp(cum)                           # (B, ck, H)
        y_state = jnp.einsum("bln,bhpn->blhp", cc.astype(jnp.float32), h) \
            * decay_in[..., None]
        # 2) within-chunk "attention": L[t, s_] = exp(cum_t - cum_s) for s_ <= t
        rel = cum[:, :, None, :] - cum[:, None, :, :]     # (B, ck, ck, H)
        mask = jnp.tril(jnp.ones((ck, ck), bool))
        # mask BEFORE exp: where(mask, exp(rel), 0) NaNs the backward pass
        # when the masked upper triangle overflows (0 * inf cotangent).
        rel = jnp.where(mask[None, :, :, None], rel, -1e30)
        l_mat = jnp.exp(rel)
        scores = jnp.einsum("bln,bsn->bls", cc.astype(jnp.float32),
                            bc_.astype(jnp.float32))      # (B, ck, ck)
        w = scores[..., None] * l_mat                     # (B, l, s_, H)
        y_intra = jnp.einsum("blsh,bsh,bshp->blhp", w, dtc,
                             xh_cast := xc.astype(jnp.float32))
        # 3) state update: h' = exp(total) h + sum_s exp(total - cum_s) dt_s x_s b_s^T
        # contract s directly — do NOT materialize the (B, ck, H, P, N)
        # outer product (see xlstm.py chunk_step, §Perf A.1)
        carry_decay = jnp.exp(total[:, None] - cum)       # (B, ck, H)
        xz = xh_cast * (carry_decay * dtc)[..., None]
        h_new = h * jnp.exp(total)[..., None, None] + jnp.einsum(
            "bshp,bsn->bhpn", xz, bc_.astype(jnp.float32))
        return h_new, (y_state + y_intra)

    h0 = (state["h"] if state is not None
          else jnp.zeros((bsz, n_heads, p_hd, cfg.ssm.d_state), jnp.float32))
    # checkpoint the chunk body: backward recomputes the within-chunk
    # (ck x ck) decay/score tiles instead of saving them for every chunk
    step_fn = jax.checkpoint(chunk_step) if cfg.remat else chunk_step
    h_fin, y_c = jax.lax.scan(step_fn, h0, (xh_c, b_c, c_c, dt_c, da_c))
    y = y_c.swapaxes(0, 1).reshape(bsz, s, n_heads, p_hd)
    y = y + xh.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(bsz, s, n_heads * p_hd).astype(u.dtype)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    return jnp.dot(y, p["w_out"].astype(u.dtype)), {"h": h_fin}


def init_mamba_state(cfg: ModelConfig, batch: int):
    d_in = cfg.ssm.expand * cfg.d_model
    n_heads = d_in // cfg.ssm.head_dim
    return {"h": jnp.zeros((batch, n_heads, cfg.ssm.head_dim, cfg.ssm.d_state),
                           jnp.float32)}
