"""ApproxFFN — the paper's MCMA generalized to a first-class LM layer.

Semantics (DESIGN.md §4): the exact FFN is the target function ("CPU"
path); ``n_approx`` small identical-topology tanh MLPs are the
approximators; an (n+1)-way router is the multiclass classifier (class 0 =
exact).  Co-training follows the paper's competitive scheme: per-token
relative L2 error of each approximator against the exact FFN output defines
the label (argmin error if under the bound, else class 0), the router trains
on those labels (xent) and each approximator distills on its territory.

Two execution modes, both shape-static:

* ``train``: exact FFN for every token (teacher) + all approximators on all
  tokens (errors/labels need them anyway).  Output = exact FFN (training is
  never approximated); aux losses carry the co-training signal.
* ``serve``: MoE-style capacity dispatch.  Tokens are routed by the
  router's argmax; class 0 tokens go through the exact FFN (capacity
  ``exact_frac``·T), classes 1..n through their approximator (capacity
  ``invoke_frac``·T each).  Over-capacity tokens contribute zero (residual
  carries them) — the GShard convention.  FLOP savings vs a dense FFN =
  1 - exact_frac (approximator FLOPs are ~d_hidden/d_ff of the FFN's).

The serve-mode grouped approximator matmul is exactly the access pattern of
the Pallas ``switched_mlp`` kernel (kernels/switched_mlp.py): rows sorted by
class, per-tile weight switch via scalar prefetch.  The XLA path here is the
portable fallback; the kernel is used by ops.switched_apply for 2D token
batches on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.ops import prepad_switched_weights
from repro.models.layers import ffn_fwd, init_ffn


def init_approx_ffn(key, cfg: ModelConfig):
    d, a = cfg.d_model, cfg.approx
    n = a.n_live                 # full LIBRARY when one is configured
    ks = jax.random.split(key, 4)
    s_in, s_h = d ** -0.5, a.d_hidden ** -0.5
    # stacked identical-topology approximators (paper §III-D requirement),
    # stored in SERVING form from the start: the zero-weight nC
    # pseudo-class appended and feature dims lane-padded
    # (kernels/ops.prepad_switched_weights), so the decode hot path ships
    # the stacks into the weight-switch kernel with no per-call copies.
    # With a library (a.library_size > 0) the stacks hold ALL library_size
    # approximators and the router head covers the full library — at serve
    # time ops.gather_resident_stacks pulls the resident rows out.
    # Padded regions are exact zeros and STAY zero under training: the
    # train path only reads/derives gradients through the logical views
    # (approx_stacks), so their grads — and hence AdamW updates — are zero.
    w1 = jax.random.normal(ks[2], (n, d, a.d_hidden), cfg.pdtype) * s_in
    b1 = jnp.zeros((n, a.d_hidden), cfg.pdtype)
    w2 = jax.random.normal(ks[3], (n, a.d_hidden, d), cfg.pdtype) * s_h
    b2 = jnp.zeros((n, d), cfg.pdtype)
    w1, b1, w2, b2 = prepad_switched_weights(w1, b1, w2, b2)
    return {"ffn": init_ffn(ks[0], cfg),
            "router": jax.random.normal(ks[1], (d, n + 1),
                                        cfg.pdtype) * s_in,
            "a_w1": w1, "a_b1": b1, "a_w2": w2, "a_b2": b2}


def approx_stacks(cfg: ModelConfig, p):
    """Logical (n_live, d, d_hidden)-shaped views of the serving-form
    stacks — what the train path and error labelling operate on."""
    a, d = cfg.approx, cfg.d_model
    n = a.n_live
    return (p["a_w1"][:n, :d, :a.d_hidden],
            p["a_b1"][:n, :a.d_hidden],
            p["a_w2"][:n, :a.d_hidden, :d],
            p["a_b2"][:n, :d])


def _apply_all_approx(cfg, p, x):
    """All approximators on all tokens.  x: (T, d) -> (n, T, d)."""
    w1, b1, w2, b2 = approx_stacks(cfg, p)
    h = jnp.einsum("td,ndh->nth", x, w1.astype(x.dtype))
    h = jnp.tanh(h + b1[:, None, :].astype(x.dtype))
    y = jnp.einsum("nth,nhd->ntd", h, w2.astype(x.dtype))
    return y + b2[:, None, :].astype(x.dtype)


def _rel_err(y_hat, y, eps=1e-6):
    """Per-token relative L2 error (competitive-scheme label signal)."""
    num = jnp.linalg.norm((y_hat - y).astype(jnp.float32), axis=-1)
    den = jnp.linalg.norm(y.astype(jnp.float32), axis=-1)
    return num / jnp.maximum(den, eps)


def approx_ffn_train(cfg: ModelConfig, p, x: jax.Array):
    """Training path.  x: (B, S, d) -> (exact FFN out, aux dict).

    aux = {"loss": distill + router xent (weighted), "invocation": fraction
    of tokens whose best approximator is under the bound}.
    """
    a = cfg.approx
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    exact = ffn_fwd(cfg, p["ffn"], xt)                      # (T, d) teacher
    approx = _apply_all_approx(cfg, p, xt)                  # (n, T, d)
    errs = jax.vmap(lambda yh: _rel_err(yh, exact))(approx)  # (n, T)

    # competitive labels: argmin error if under bound, else 0 (exact)
    best = jnp.argmin(errs, axis=0)
    safe = errs.min(0) <= a.error_bound
    labels = jnp.where(safe, best + 1, 0)                   # 0 = exact path

    logits = jnp.dot(xt, p["router"].astype(xt.dtype)).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, -1)
    router_loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))

    # distillation: each approximator fits its territory (stop-grad teacher)
    tgt = jax.lax.stop_gradient(exact.astype(jnp.float32))
    own = jax.nn.one_hot(labels - 1, a.n_live, axis=0) * safe   # (n, T)
    sq = jnp.sum((approx.astype(jnp.float32) - tgt[None]) ** 2, -1)  # (n, T)
    # territory tokens at weight 1; all tokens at small weight (exploration)
    w = own + 0.05
    distill = jnp.sum(sq * w) / jnp.maximum(jnp.sum(w), 1.0) / d

    aux = {"loss": a.router_weight * router_loss + a.distill_weight * distill,
           "invocation": jnp.mean(safe.astype(jnp.float32)),
           "router_acc": jnp.mean((jnp.argmax(logits, -1) == labels)
                                  .astype(jnp.float32)),
           # per-token one-hot competitive labels — the model (model.py)
           # sums these over the layer scan to train the TICK router head
           # on the across-layer modal label (route_scope="tick")
           "label_votes": jax.nn.one_hot(labels, a.n_live + 1,
                                         dtype=jnp.float32)}
    return exact.reshape(b, s, d), aux


def _manual_serve_ctx(cfg: ModelConfig, b: int):
    """(mesh, dp, n_data_shards) when the shard_map-native serve path
    engages for a batch of ``b`` rows under the active distributed trace
    context, else (None, (), 1).  The SAME predicate gates plan
    construction (make_tick_plan) and per-layer consumption, so a tick
    plan is always built with exactly the sharding its consumers expect."""
    from repro.sharding.activations import manual_dp_context
    mesh, dp = manual_dp_context()
    if mesh is None or "model" not in mesh.axis_names:
        return None, (), 1
    import numpy as _np
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    g = int(_np.prod([sizes[ax] for ax in dp]))
    if b % g == 0 and cfg.d_ff % sizes["model"] == 0:
        return mesh, dp, g
    return None, (), 1


def serve_caps(cfg: ModelConfig, t_local: int):
    """(exact_cap, invoke_cap) for a shard of ``t_local`` rows — the ONE
    place the config's capacity fractions become row budgets.  With
    ``approx.invoke_fracs`` set (asymmetric per-class capacities, e.g.
    from runtime/autotune.ladder_from_counts) ``invoke_cap`` is the
    per-class tuple the engine accepts."""
    from repro.sharding.rules import shard_capacity
    a = cfg.approx
    ec = shard_capacity(t_local, a.exact_frac, slack=a.shard_slack)
    if a.invoke_fracs:
        assert len(a.invoke_fracs) == a.n_approx, \
            (a.invoke_fracs, a.n_approx)
        return ec, tuple(shard_capacity(t_local, f, slack=a.shard_slack)
                         for f in a.invoke_fracs)
    return ec, shard_capacity(t_local, a.invoke_frac, slack=a.shard_slack)


def _default_margins(cfg: ModelConfig) -> jax.Array:
    """The config's static per-tier margin fallback (zeros when unset) —
    the ONE definition every serve path defaults from when a caller
    passes tiers without a margins vector."""
    a = cfg.approx
    return jnp.asarray(a.tier_margins or (0.0,) * a.n_tiers, jnp.float32)


def _row_mask_tokens(row_mask, s: int):
    """Normalize an active mask to per-ROW (B*S,) bools.  Accepts the
    server's per-slot (B,) mask (every token of a slot shares its state)
    or a chunked-prefill TOKEN mask (B, S) (each slot live only up to its
    ``n_valid`` tokens this chunk)."""
    if row_mask is None:
        return None
    rm = row_mask.astype(bool)
    return rm.reshape(-1) if rm.ndim == 2 else jnp.repeat(rm, s)


def _tier_args(cfg: ModelConfig, tier, tier_margins, s: int):
    """Normalize the per-slot QoS args for an (B, S) row batch: expand the
    (B,) tier vector to (B*S,) rows and default the margins vector from
    the config when the caller passed tiers without one."""
    if tier is None:
        return None, None
    tr = jnp.repeat(tier.astype(jnp.int32), s)
    if tier_margins is None:
        tier_margins = _default_margins(cfg)
    return tr, tier_margins


def make_tick_plan(cfg: ModelConfig, params, x: jax.Array,
                   row_mask: jax.Array | None = None,
                   tier: jax.Array | None = None,
                   tier_margins: jax.Array | None = None,
                   residency: jax.Array | None = None):
    """One DispatchPlan per decode tick (route_scope="tick").

    Classifies with the model's TICK-router head (``params["tick_router"]``,
    co-trained on the across-layer competitive labels) on the pre-layer
    hidden state ``x`` (B, S=1, d), runs capacity + class-sort once, and
    returns the plan every layer of the decode scan executes against.
    ``tier`` ((B,) int32 per-slot QoS tier) + ``tier_margins`` ((n_tiers,)
    traced) apply the per-request exact-logit margins to the ONE tick
    decision, so a mixed-tier batch routes each row at its own quality
    bound; the plan then carries the per-tier invoke-stat split for every
    layer.  ``residency`` ((n_resident,) int32 library ids, TRACED) folds
    full-library routing onto the resident slots (the tick-router head is
    library-wide when ``approx.library_size`` is set); the per-layer
    executors then run against residency-gathered stacks of the same
    slot count.  Under a distributed trace context the plan is built per
    data shard inside a shard_map — the identical sharding the per-layer
    manual serve path consumes it with — and its count fields are
    psum-reduced to global totals, so the autotuner (and the
    ResidencyController, via ``lib_counts``) reads ONE exact observation
    per tick.
    """
    from repro.runtime.dispatch import make_dispatch_plan
    a = cfg.approx
    b, s, d = x.shape
    t = b * s
    assert "tick_router" in params, (
        "route_scope='tick' needs the tick-router head, but these params "
        "have none — they predate the head (init_model now adds "
        "'tick_router' whenever approx.enable); re-init or serve with "
        "route_scope='layer'")
    router = params["tick_router"]
    mesh, dp, g = _manual_serve_ctx(cfg, b)
    if mesh is not None:
        from jax.sharding import PartitionSpec as P
        from repro.sharding.compat import shard_map_compat
        from repro.sharding.rules import dispatch_plan_specs
        tl = t // g
        ec, ic = serve_caps(cfg, tl)
        if row_mask is None:
            row_mask = jnp.ones((b,), bool)
        mask2d = row_mask.ndim == 2
        has_tier = tier is not None
        if has_tier and tier_margins is None:
            tier_margins = _default_margins(cfg)
        nt = int(tier_margins.shape[0]) if has_tier else 1
        has_res = residency is not None

        def local(rt, x_l, m_l, *extra):
            extra = list(extra)
            t_l, tm = (extra.pop(0), extra.pop(0)) if has_tier \
                else (None, None)
            res = extra.pop(0) if has_res else None
            bl, sl, _ = x_l.shape
            xt = x_l.reshape(bl * sl, d)
            lg = jnp.dot(xt, rt.astype(xt.dtype)).astype(jnp.float32)
            return make_dispatch_plan(
                lg, _row_mask_tokens(m_l, sl), exact_cap=ec,
                invoke_cap=ic, backend=a.backend, block_t=a.block_t,
                stats_axes=dp,
                tier=None if t_l is None else jnp.repeat(t_l, sl),
                tier_margins=tm, residency=res)

        in_specs = (P(None, None), P(dp, None, None),
                    P(dp, None) if mask2d else P(dp))
        args = (router, x, row_mask)
        if has_tier:
            in_specs = in_specs + (P(dp), P(None))
            args = args + (tier.astype(jnp.int32), tier_margins)
        if has_res:
            in_specs = in_specs + (P(None),)
            args = args + (residency.astype(jnp.int32),)
        fn = shard_map_compat(
            local, mesh=mesh, in_specs=in_specs,
            out_specs=dispatch_plan_specs(
                mesh, data_axes=dp,
                n_approx=int(residency.shape[0]) if has_res else a.n_approx,
                exact_cap=ec, invoke_cap=ic, block_t=a.block_t,
                backend=a.backend, n_tiers=nt,
                library_size=a.library_size if has_res else 0),
            axis_names=frozenset(tuple(dp) + ("model",)), check=False)
        return fn(*args)

    xt = x.reshape(t, d)
    logits = jnp.dot(xt, router.astype(xt.dtype)).astype(jnp.float32)
    rm = _row_mask_tokens(row_mask, s)
    tr, tier_margins = _tier_args(cfg, tier, tier_margins, s)
    ec, ic = serve_caps(cfg, t)
    return make_dispatch_plan(
        logits, rm, exact_cap=ec, invoke_cap=ic,
        backend=a.backend, block_t=a.block_t,
        tier=tr, tier_margins=tier_margins, residency=residency)


def approx_ffn_serve(cfg: ModelConfig, p, x: jax.Array,
                     row_mask: jax.Array | None = None, plan=None,
                     tier: jax.Array | None = None,
                     tier_margins: jax.Array | None = None,
                     residency: jax.Array | None = None):
    """Serving path with capacity dispatch.  x: (B, S, d) -> (out, aux).

    Exact FFN runs on ``exact_frac``·T tokens only — the paper's invocation
    gain realized as a FLOP reduction.  invoke capacity per approximator is
    sized for a balanced dispatch with slack (or per class via
    ``approx.invoke_fracs``).

    ``row_mask`` (optional, (B,) bool) marks the ACTIVE batch rows — a
    decode server's occupied slots.  Idle rows are excluded from dispatch
    and from every invoke stat, so invocation/exact_frac (and any capacity
    autotuner reading them) stay exact on partially-full slot tables.

    ``tier`` (optional, (B,) int32) + ``tier_margins`` ((n_tiers,)
    traced): per-request QoS — each slot routes at its own error-bound
    tier via the exact-logit margin (runtime/dispatch.route) and the
    invoke stats gain the per-tier split.

    ``plan`` (optional, a runtime/dispatch.DispatchPlan): tick-scope
    routing — the decision was made ONCE above the layer scan
    (make_tick_plan) and this layer only executes against it; no router
    matmul, sort, or stats collective runs here, and ``row_mask``/
    ``tier`` are ignored (the plan already embeds them).

    ``residency`` (optional, (n_resident,) int32 library ids, TRACED):
    approximator-library serving — the stored stacks hold the full
    library and the router head is library-wide; the resident rows are
    gathered out per layer (ops.gather_resident_stacks — an
    (n_resident + 1)-row gather, tiny) and library routing folds onto the
    resident slots (runtime/dispatch).  A hot-set swap is a new vector
    through the same compiled program.  With a tick ``plan`` the fold
    already happened in make_tick_plan (pass the SAME residency there);
    here it only selects the executed weights.

    The engine is ``runtime/dispatch.mcma_dispatch`` (classify -> capacity
    -> class-sort -> weight-switch kernel / XLA oracle -> exact -> scatter);
    ``cfg.approx.backend`` picks the backend.  Under a distributed mesh the
    shard_map path below runs the SAME engine per data shard with
    psum-reduced invoke_stats (global cumsum ranking across a
    token-sharded dim would force the partitioner to replicate tokens, so
    each data shard ranks/gathers only its own tokens — §Perf B/C).
    """
    from repro.runtime.dispatch import (execute_dispatch, mcma_dispatch,
                                        plan_invoke_stats)
    a = cfg.approx
    b, s, d = x.shape
    t = b * s
    mesh, dp, _ = _manual_serve_ctx(cfg, b)
    if mesh is not None:
        return _approx_serve_manual(cfg, p, x, mesh, dp,
                                    row_mask=row_mask, plan=plan,
                                    tier=tier, tier_margins=tier_margins,
                                    residency=residency)

    if plan is not None:
        stacks = (p["a_w1"], p["a_b1"], p["a_w2"], p["a_b2"])
        if residency is not None:
            from repro.kernels.ops import gather_resident_stacks
            stacks = gather_resident_stacks(*stacks,
                                            residency.astype(jnp.int32))
        out = execute_dispatch(
            plan, x.reshape(t, d), lambda xb: ffn_fwd(cfg, p["ffn"], xb),
            *stacks, interpret=a.interpret, weights_prepadded=True)
        stats = plan_invoke_stats(plan)
    else:
        xt = x.reshape(t, d)
        rm = _row_mask_tokens(row_mask, s)
        tr, tier_margins = _tier_args(cfg, tier, tier_margins, s)
        ec, ic = serve_caps(cfg, t)
        logits = jnp.dot(xt, p["router"].astype(x.dtype)).astype(jnp.float32)
        out, stats = mcma_dispatch(
            xt, logits, lambda xb: ffn_fwd(cfg, p["ffn"], xb),
            p["a_w1"], p["a_b1"], p["a_w2"], p["a_b2"],
            exact_cap=ec, invoke_cap=ic,
            backend=a.backend, block_t=a.block_t, interpret=a.interpret,
            row_mask=rm, weights_prepadded=True,
            tier=tr, tier_margins=tier_margins, residency=residency)

    aux = {"loss": jnp.zeros((), jnp.float32),
           "invocation": stats["invocation"],
           "router_acc": jnp.zeros((), jnp.float32),
           "invoke_stats": stats}
    return out.reshape(b, s, d), aux


def _approx_serve_manual(cfg: ModelConfig, p, x, mesh, dp, row_mask=None,
                         plan=None, tier=None, tier_margins=None,
                         residency=None):
    """Shard_map-native serve dispatch: the SAME ``mcma_dispatch`` engine
    as the single-device path, run per data shard (each shard classifies /
    capacities / class-sorts / weight-switches its OWN tokens — no
    cross-shard dispatch traffic, same lesson as the manual MoE path,
    §Perf B/C).  The exact FFN runs Megatron-TP over "model" with one psum
    inside the engine's capacity gather; the approximators are replicated
    (tiny) and run locally; invoke_stats are psum-reduced over the data
    axes so every shard reports the global totals.  Per-shard capacities
    come from sharding/rules.shard_capacity (``cfg.approx.shard_slack``
    over-provisions them against cross-shard class skew).

    ``plan`` (tick scope): the DispatchPlan was built per data shard by
    ``make_tick_plan`` under the SAME row sharding this region re-enters,
    so each shard executes its local rows against its local plan fields;
    the plan's count fields are already psum-reduced global totals, so
    the stats come straight off the plan with no collective here.

    ``tier``/``tier_margins`` (layer scope only — a tick plan already
    embeds the tiers): the (B,) per-slot QoS tiers ride through the
    shard_map batch-sharded like the mask, the margins replicated, and
    the per-tier stats psum-reduce with the rest.

    ``residency`` (library serving): the stacks in ``weights`` hold the
    full replicated library; on the plan path the resident rows are
    gathered OUTSIDE the shard_map (the gathered stacks are replicated
    with the same specs, just a smaller leading dim — specs are
    shape-agnostic), on the layer-scope path the replicated residency
    vector rides in and ``mcma_dispatch`` gathers per shard.
    """
    from repro.runtime.dispatch import (execute_dispatch, mcma_dispatch,
                                        plan_invoke_stats)
    from repro.sharding.compat import shard_map_compat
    from repro.sharding.rules import approx_serve_specs
    a = cfg.approx
    b, s, d = x.shape
    axes = tuple(dp) + ("model",)
    weights = {**{k: p[k] for k in ("router", "a_w1", "a_b1", "a_w2",
                                    "a_b2")}, "ffn": p["ffn"]}
    if residency is not None and plan is not None:
        from repro.kernels.ops import gather_resident_stacks
        weights["a_w1"], weights["a_b1"], weights["a_w2"], weights["a_b2"] \
            = gather_resident_stacks(
                weights["a_w1"], weights["a_b1"], weights["a_w2"],
                weights["a_b2"], residency.astype(jnp.int32))

    def tp_exact_fn(p_loc):
        # FSDP unshard-on-use of the exact FFN's TP slices
        w_in = jax.lax.all_gather(p_loc["ffn"]["w_in"], dp, axis=0, tiled=True)
        w_out = jax.lax.all_gather(p_loc["ffn"]["w_out"], dp, axis=1, tiled=True)
        w_gate = (jax.lax.all_gather(p_loc["ffn"]["w_gate"], dp, axis=0,
                                     tiled=True)
                  if "w_gate" in p_loc["ffn"] else None)

        def exact_fn(xb):
            # Megatron-TP: d_ff sharded over "model", one psum per call
            h = jnp.dot(xb, w_in.astype(xb.dtype))
            if w_gate is not None:
                h = jax.nn.silu(jnp.dot(xb, w_gate.astype(xb.dtype))) * h
            else:
                h = jax.nn.silu(h)
            return jax.lax.psum(jnp.dot(h, w_out.astype(h.dtype)), "model")
        return exact_fn

    if plan is not None:
        specs = approx_serve_specs(mesh, gated="w_gate" in p["ffn"],
                                   plan=plan)

        def local_plan(p_loc, x_loc, plan_loc):
            bl, sl, _ = x_loc.shape
            xt = x_loc.reshape(bl * sl, d)
            out = execute_dispatch(
                plan_loc, xt, tp_exact_fn(p_loc),
                p_loc["a_w1"], p_loc["a_b1"], p_loc["a_w2"], p_loc["a_b2"],
                interpret=a.interpret, weights_prepadded=True)
            return out.reshape(bl, sl, d)

        fn = shard_map_compat(local_plan, mesh=mesh, in_specs=specs["in"],
                              out_specs=specs["out"],
                              axis_names=frozenset(axes), check=False)
        out = fn(weights, x, plan)
        stats = plan_invoke_stats(plan)
    else:
        has_tier = tier is not None
        has_res = residency is not None
        if row_mask is None:
            row_mask = jnp.ones((b,), bool)
        specs = approx_serve_specs(mesh, gated="w_gate" in p["ffn"],
                                   with_tier=has_tier,
                                   mask2d=row_mask.ndim == 2,
                                   with_residency=has_res)
        if has_tier and tier_margins is None:
            tier_margins = _default_margins(cfg)

        def local(p_loc, x_loc, m_loc, *extra):
            extra = list(extra)
            t_l, tm = (extra.pop(0), extra.pop(0)) if has_tier \
                else (None, None)
            res = extra.pop(0) if has_res else None
            bl, sl, _ = x_loc.shape
            tl = bl * sl
            xt = x_loc.reshape(tl, d)
            rm = _row_mask_tokens(m_loc, sl)
            ec, ic = serve_caps(cfg, tl)
            logits = jnp.dot(xt, p_loc["router"].astype(xt.dtype)) \
                .astype(jnp.float32)
            out, stats = mcma_dispatch(
                xt, logits, tp_exact_fn(p_loc),
                p_loc["a_w1"], p_loc["a_b1"], p_loc["a_w2"], p_loc["a_b2"],
                exact_cap=ec, invoke_cap=ic,
                backend=a.backend, block_t=a.block_t, interpret=a.interpret,
                stats_axes=dp, row_mask=rm, weights_prepadded=True,
                tier=None if t_l is None else jnp.repeat(t_l, sl),
                tier_margins=tm, residency=res)
            return out.reshape(bl, sl, d), stats

        fn = shard_map_compat(local, mesh=mesh, in_specs=specs["in"],
                              out_specs=specs["out"],
                              axis_names=frozenset(axes), check=False)
        args = (weights, x, row_mask)
        if has_tier:
            args = args + (tier.astype(jnp.int32), tier_margins)
        if has_res:
            args = args + (residency.astype(jnp.int32),)
        out, stats = fn(*args)
    aux = {"loss": jnp.zeros((), jnp.float32),
           "invocation": stats["invocation"],
           "router_acc": jnp.zeros((), jnp.float32),
           "invoke_stats": stats}
    return out, aux


def approx_ffn_fwd(cfg: ModelConfig, p, x: jax.Array, *, serve: bool = False,
                   row_mask: jax.Array | None = None, plan=None,
                   tier: jax.Array | None = None,
                   tier_margins: jax.Array | None = None,
                   residency: jax.Array | None = None):
    if serve:
        return approx_ffn_serve(cfg, p, x, row_mask=row_mask, plan=plan,
                                tier=tier, tier_margins=tier_margins,
                                residency=residency)
    return approx_ffn_train(cfg, p, x)
