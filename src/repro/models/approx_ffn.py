"""ApproxFFN — the paper's MCMA generalized to a first-class LM layer.

Semantics (DESIGN.md §4): the exact FFN is the target function ("CPU"
path); ``n_approx`` small identical-topology tanh MLPs are the
approximators; an (n+1)-way router is the multiclass classifier (class 0 =
exact).  Co-training follows the paper's competitive scheme: per-token
relative L2 error of each approximator against the exact FFN output defines
the label (argmin error if under the bound, else class 0), the router trains
on those labels (xent) and each approximator distills on its territory.

Two execution modes, both shape-static:

* ``train``: exact FFN for every token (teacher) + all approximators on all
  tokens (errors/labels need them anyway).  Output = exact FFN (training is
  never approximated); aux losses carry the co-training signal.
* ``serve``: MoE-style capacity dispatch.  Tokens are routed by the
  router's argmax; class 0 tokens go through the exact FFN (capacity
  ``exact_frac``·T), classes 1..n through their approximator (capacity
  ``invoke_frac``·T each).  Over-capacity tokens contribute zero (residual
  carries them) — the GShard convention.  FLOP savings vs a dense FFN =
  1 - exact_frac (approximator FLOPs are ~d_hidden/d_ff of the FFN's).

The serve-mode grouped approximator matmul is exactly the access pattern of
the Pallas ``switched_mlp`` kernel (kernels/switched_mlp.py): rows sorted by
class, per-tile weight switch via scalar prefetch.  The XLA path here is the
portable fallback; the kernel is used by ops.switched_apply for 2D token
batches on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.ops import prepad_switched_weights
from repro.models.layers import ffn_fwd, init_ffn


def init_approx_ffn(key, cfg: ModelConfig):
    d, a = cfg.d_model, cfg.approx
    ks = jax.random.split(key, 4)
    s_in, s_h = d ** -0.5, a.d_hidden ** -0.5
    # stacked identical-topology approximators (paper §III-D requirement),
    # stored in SERVING form from the start: the zero-weight nC
    # pseudo-class appended and feature dims lane-padded
    # (kernels/ops.prepad_switched_weights), so the decode hot path ships
    # the stacks into the weight-switch kernel with no per-call copies.
    # Padded regions are exact zeros and STAY zero under training: the
    # train path only reads/derives gradients through the logical views
    # (approx_stacks), so their grads — and hence AdamW updates — are zero.
    w1 = jax.random.normal(ks[2], (a.n_approx, d, a.d_hidden), cfg.pdtype) * s_in
    b1 = jnp.zeros((a.n_approx, a.d_hidden), cfg.pdtype)
    w2 = jax.random.normal(ks[3], (a.n_approx, a.d_hidden, d), cfg.pdtype) * s_h
    b2 = jnp.zeros((a.n_approx, d), cfg.pdtype)
    w1, b1, w2, b2 = prepad_switched_weights(w1, b1, w2, b2)
    return {"ffn": init_ffn(ks[0], cfg),
            "router": jax.random.normal(ks[1], (d, a.n_approx + 1),
                                        cfg.pdtype) * s_in,
            "a_w1": w1, "a_b1": b1, "a_w2": w2, "a_b2": b2}


def approx_stacks(cfg: ModelConfig, p):
    """Logical (n, d, d_hidden)-shaped views of the serving-form stacks —
    what the train path and error labelling operate on."""
    a, d = cfg.approx, cfg.d_model
    return (p["a_w1"][:a.n_approx, :d, :a.d_hidden],
            p["a_b1"][:a.n_approx, :a.d_hidden],
            p["a_w2"][:a.n_approx, :a.d_hidden, :d],
            p["a_b2"][:a.n_approx, :d])


def _apply_all_approx(cfg, p, x):
    """All approximators on all tokens.  x: (T, d) -> (n, T, d)."""
    w1, b1, w2, b2 = approx_stacks(cfg, p)
    h = jnp.einsum("td,ndh->nth", x, w1.astype(x.dtype))
    h = jnp.tanh(h + b1[:, None, :].astype(x.dtype))
    y = jnp.einsum("nth,nhd->ntd", h, w2.astype(x.dtype))
    return y + b2[:, None, :].astype(x.dtype)


def _rel_err(y_hat, y, eps=1e-6):
    """Per-token relative L2 error (competitive-scheme label signal)."""
    num = jnp.linalg.norm((y_hat - y).astype(jnp.float32), axis=-1)
    den = jnp.linalg.norm(y.astype(jnp.float32), axis=-1)
    return num / jnp.maximum(den, eps)


def approx_ffn_train(cfg: ModelConfig, p, x: jax.Array):
    """Training path.  x: (B, S, d) -> (exact FFN out, aux dict).

    aux = {"loss": distill + router xent (weighted), "invocation": fraction
    of tokens whose best approximator is under the bound}.
    """
    a = cfg.approx
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    exact = ffn_fwd(cfg, p["ffn"], xt)                      # (T, d) teacher
    approx = _apply_all_approx(cfg, p, xt)                  # (n, T, d)
    errs = jax.vmap(lambda yh: _rel_err(yh, exact))(approx)  # (n, T)

    # competitive labels: argmin error if under bound, else 0 (exact)
    best = jnp.argmin(errs, axis=0)
    safe = errs.min(0) <= a.error_bound
    labels = jnp.where(safe, best + 1, 0)                   # 0 = exact path

    logits = jnp.dot(xt, p["router"].astype(xt.dtype)).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, -1)
    router_loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))

    # distillation: each approximator fits its territory (stop-grad teacher)
    tgt = jax.lax.stop_gradient(exact.astype(jnp.float32))
    own = jax.nn.one_hot(labels - 1, a.n_approx, axis=0) * safe  # (n, T)
    sq = jnp.sum((approx.astype(jnp.float32) - tgt[None]) ** 2, -1)  # (n, T)
    # territory tokens at weight 1; all tokens at small weight (exploration)
    w = own + 0.05
    distill = jnp.sum(sq * w) / jnp.maximum(jnp.sum(w), 1.0) / d

    aux = {"loss": a.router_weight * router_loss + a.distill_weight * distill,
           "invocation": jnp.mean(safe.astype(jnp.float32)),
           "router_acc": jnp.mean((jnp.argmax(logits, -1) == labels)
                                  .astype(jnp.float32))}
    return exact.reshape(b, s, d), aux


def approx_ffn_serve(cfg: ModelConfig, p, x: jax.Array,
                     row_mask: jax.Array | None = None):
    """Serving path with capacity dispatch.  x: (B, S, d) -> (out, aux).

    Exact FFN runs on ``exact_frac``·T tokens only — the paper's invocation
    gain realized as a FLOP reduction.  invoke capacity per approximator is
    sized for a balanced dispatch with slack.

    ``row_mask`` (optional, (B,) bool) marks the ACTIVE batch rows — a
    decode server's occupied slots.  Idle rows are excluded from dispatch
    and from every invoke stat, so invocation/exact_frac (and any capacity
    autotuner reading them) stay exact on partially-full slot tables.

    The engine is ``runtime/dispatch.mcma_dispatch`` (classify -> capacity
    -> class-sort -> weight-switch kernel / XLA oracle -> exact -> scatter);
    ``cfg.approx.backend`` picks the backend.  Under a distributed mesh the
    shard_map path below runs the SAME engine per data shard with
    psum-reduced invoke_stats (global cumsum ranking across a
    token-sharded dim would force the partitioner to replicate tokens, so
    each data shard ranks/gathers only its own tokens — §Perf B/C).
    """
    from repro.runtime.dispatch import mcma_dispatch
    from repro.sharding.activations import manual_dp_context
    from repro.sharding.rules import shard_capacity
    a = cfg.approx
    b, s, d = x.shape
    t = b * s
    mesh, dp = manual_dp_context()
    if mesh is not None and "model" in mesh.axis_names:
        import numpy as _np
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        g = int(_np.prod([sizes[ax] for ax in dp]))
        if b % g == 0 and cfg.d_ff % sizes["model"] == 0:
            return _approx_serve_manual(cfg, p, x, mesh, dp,
                                        row_mask=row_mask)

    xt = x.reshape(t, d)
    rm = None if row_mask is None else jnp.repeat(row_mask.astype(bool), s)
    logits = jnp.dot(xt, p["router"].astype(x.dtype)).astype(jnp.float32)
    out, stats = mcma_dispatch(
        xt, logits, lambda xb: ffn_fwd(cfg, p["ffn"], xb),
        p["a_w1"], p["a_b1"], p["a_w2"], p["a_b2"],
        exact_cap=shard_capacity(t, a.exact_frac, slack=a.shard_slack),
        invoke_cap=shard_capacity(t, a.invoke_frac, slack=a.shard_slack),
        backend=a.backend, block_t=a.block_t, interpret=a.interpret,
        row_mask=rm, weights_prepadded=True)

    aux = {"loss": jnp.zeros((), jnp.float32),
           "invocation": stats["invocation"],
           "router_acc": jnp.zeros((), jnp.float32),
           "invoke_stats": stats}
    return out.reshape(b, s, d), aux


def _approx_serve_manual(cfg: ModelConfig, p, x, mesh, dp, row_mask=None):
    """Shard_map-native serve dispatch: the SAME ``mcma_dispatch`` engine
    as the single-device path, run per data shard (each shard classifies /
    capacities / class-sorts / weight-switches its OWN tokens — no
    cross-shard dispatch traffic, same lesson as the manual MoE path,
    §Perf B/C).  The exact FFN runs Megatron-TP over "model" with one psum
    inside the engine's capacity gather; the approximators are replicated
    (tiny) and run locally; invoke_stats are psum-reduced over the data
    axes so every shard reports the global totals.  Per-shard capacities
    come from sharding/rules.shard_capacity (``cfg.approx.shard_slack``
    over-provisions them against cross-shard class skew).
    """
    from repro.runtime.dispatch import mcma_dispatch
    from repro.sharding.compat import shard_map_compat
    from repro.sharding.rules import approx_serve_specs, shard_capacity
    a = cfg.approx
    b, s, d = x.shape
    axes = tuple(dp) + ("model",)
    specs = approx_serve_specs(mesh, gated="w_gate" in p["ffn"])
    if row_mask is None:
        row_mask = jnp.ones((b,), bool)

    def local(p_loc, x_loc, m_loc):
        bl, sl, _ = x_loc.shape
        tl = bl * sl
        xt = x_loc.reshape(tl, d)
        rm = jnp.repeat(m_loc.astype(bool), sl)
        # FSDP unshard-on-use of the exact FFN's TP slices
        w_in = jax.lax.all_gather(p_loc["ffn"]["w_in"], dp, axis=0, tiled=True)
        w_out = jax.lax.all_gather(p_loc["ffn"]["w_out"], dp, axis=1, tiled=True)
        w_gate = (jax.lax.all_gather(p_loc["ffn"]["w_gate"], dp, axis=0,
                                     tiled=True)
                  if "w_gate" in p_loc["ffn"] else None)

        def exact_fn(xb):
            # Megatron-TP: d_ff sharded over "model", one psum per call
            h = jnp.dot(xb, w_in.astype(xb.dtype))
            if w_gate is not None:
                h = jax.nn.silu(jnp.dot(xb, w_gate.astype(xb.dtype))) * h
            else:
                h = jax.nn.silu(h)
            return jax.lax.psum(jnp.dot(h, w_out.astype(h.dtype)), "model")

        logits = jnp.dot(xt, p_loc["router"].astype(xt.dtype)) \
            .astype(jnp.float32)
        out, stats = mcma_dispatch(
            xt, logits, exact_fn,
            p_loc["a_w1"], p_loc["a_b1"], p_loc["a_w2"], p_loc["a_b2"],
            exact_cap=shard_capacity(tl, a.exact_frac, slack=a.shard_slack),
            invoke_cap=shard_capacity(tl, a.invoke_frac,
                                      slack=a.shard_slack),
            backend=a.backend, block_t=a.block_t, interpret=a.interpret,
            stats_axes=dp, row_mask=rm, weights_prepadded=True)
        return out.reshape(bl, sl, d), stats

    fn = shard_map_compat(local, mesh=mesh, in_specs=specs["in"],
                          out_specs=specs["out"],
                          axis_names=frozenset(axes), check=False)
    out, stats = fn({**{k: p[k] for k in ("router", "a_w1", "a_b1", "a_w2",
                                          "a_b2")}, "ffn": p["ffn"]}, x,
                    row_mask)
    aux = {"loss": jnp.zeros((), jnp.float32),
           "invocation": stats["invocation"],
           "router_acc": jnp.zeros((), jnp.float32),
           "invoke_stats": stats}
    return out, aux


def approx_ffn_fwd(cfg: ModelConfig, p, x: jax.Array, *, serve: bool = False,
                   row_mask: jax.Array | None = None):
    if serve:
        return approx_ffn_serve(cfg, p, x, row_mask=row_mask)
    return approx_ffn_train(cfg, p, x)
