"""Shared LM layers: norms, RoPE, embeddings, FFN, GQA attention with
flash-scan (online softmax over KV blocks) and decode caches.

Everything is pure-functional: ``init_*`` builds param dicts keyed by layer
name; ``*_fwd`` applies them.  Shardings are applied by the caller
(sharding/rules.py) via NamedSharding on the param pytree; activations get
with_sharding_constraint hints at the block level (model.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, shape_d: int):
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((shape_d,), cfg.pdtype)}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((shape_d,), cfg.pdtype),
                "bias": jnp.zeros((shape_d,), cfg.pdtype)}
    return {}  # nonparam_ln (olmo): no learnable affine


def norm_fwd(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        r = jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
        return (xf * r).astype(x.dtype) * p["scale"].astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + 1e-6)).astype(x.dtype)
    if cfg.norm == "layernorm":
        y = y * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# RoPE (partial rotary supported — stablelm-2 uses 25%)
# ---------------------------------------------------------------------------

def rope_freqs(cfg: ModelConfig) -> jax.Array:
    rot = int(cfg.hd * cfg.rope_pct) // 2 * 2
    return 1.0 / (cfg.rope_base ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(cfg: ModelConfig, x: jax.Array, positions: jax.Array) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    rot = int(cfg.hd * cfg.rope_pct) // 2 * 2
    if rot == 0:
        return x
    freqs = rope_freqs(cfg)                               # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, rot/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out, xp], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    p = {"tok": jax.random.normal(k1, (cfg.vocab, cfg.d_model), cfg.pdtype) * 0.02}
    if not cfg.tie_embeddings:
        p["unembed"] = jax.random.normal(k2, (cfg.d_model, cfg.vocab), cfg.pdtype) * 0.02
    return p


def embed_fwd(cfg: ModelConfig, p, tokens_or_embeds: jax.Array) -> jax.Array:
    if cfg.input_mode == "embeddings":
        return tokens_or_embeds.astype(cfg.adtype)
    return jnp.take(p["tok"], tokens_or_embeds, axis=0).astype(cfg.adtype)


def unembed_fwd(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    w = p["tok"].T if cfg.tie_embeddings else p["unembed"]
    return jnp.dot(x, w.astype(x.dtype))


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------

_ACT = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu,
        "tanh": jnp.tanh}


def init_ffn(key, cfg: ModelConfig, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    s_in, s_out = d ** -0.5, f ** -0.5
    p = {"w_in": jax.random.normal(ks[0], (d, f), cfg.pdtype) * s_in,
         "w_out": jax.random.normal(ks[1], (f, d), cfg.pdtype) * s_out}
    if cfg.gated_ffn:
        p["w_gate"] = jax.random.normal(ks[2], (d, f), cfg.pdtype) * s_in
    return p


def ffn_fwd(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    act = _ACT[cfg.act if cfg.act != "swiglu" else "silu"]
    h = jnp.dot(x, p["w_in"].astype(x.dtype))
    if cfg.gated_ffn:
        h = act(jnp.dot(x, p["w_gate"].astype(x.dtype))) * h
    else:
        h = act(h)
    return jnp.dot(h, p["w_out"].astype(x.dtype))


# ---------------------------------------------------------------------------
# GQA attention with flash-scan
# ---------------------------------------------------------------------------

def init_attn(key, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.hd
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {"wq": jax.random.normal(ks[0], (d, nh * hd), cfg.pdtype) * s,
         "wk": jax.random.normal(ks[1], (d, nkv * hd), cfg.pdtype) * s,
         "wv": jax.random.normal(ks[2], (d, nkv * hd), cfg.pdtype) * s,
         "wo": jax.random.normal(ks[3], (nh * hd, d), cfg.pdtype) * (nh * hd) ** -0.5}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nh * hd,), cfg.pdtype)
        p["bk"] = jnp.zeros((nkv * hd,), cfg.pdtype)
        p["bv"] = jnp.zeros((nkv * hd,), cfg.pdtype)
    return p


def _qkv(cfg: ModelConfig, p, x: jax.Array):
    b, s, _ = x.shape
    q = jnp.dot(x, p["wq"].astype(x.dtype))
    k = jnp.dot(x, p["wk"].astype(x.dtype))
    v = jnp.dot(x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q, k, v = (q + p["bq"].astype(x.dtype), k + p["bk"].astype(x.dtype),
                   v + p["bv"].astype(x.dtype))
    q = q.reshape(b, s, cfg.n_heads, cfg.hd)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.hd)
    return q, k, v


def _repeat_kv(cfg: ModelConfig, k: jax.Array) -> jax.Array:
    """(B, S, Kh, hd) -> (B, S, H, hd) by repeating each kv head."""
    rep = cfg.n_heads // cfg.n_kv_heads
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def flash_attention(cfg: ModelConfig, q: jax.Array, k: jax.Array, v: jax.Array,
                    *, causal: bool = True, q_offset: int = 0) -> jax.Array:
    """Memory-bounded attention: scan over KV blocks with online softmax,
    vmapped-by-scan over Q blocks.  q: (B, Sq, H, hd); k, v: (B, Skv, H, hd).

    ``q_offset``: absolute position of q[0] (prefill continuation / decode).
    Sliding window masking honors cfg.sliding_window when set.
    """
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    qb, kb = min(cfg.q_block, sq), min(cfg.kv_block, skv)
    nq, nk = sq // qb, skv // kb
    assert sq % qb == 0 and skv % kb == 0, (sq, qb, skv, kb)
    scale = hd ** -0.5
    q = q.reshape(b, nq, qb, h, hd)
    k = k.reshape(b, nk, kb, h, hd)
    v = v.reshape(b, nk, kb, h, hd)
    win = cfg.sliding_window

    def q_step(_, qi):
        qblk, iq = qi                                   # (b, qb, h, hd), scalar
        q_pos = q_offset + iq * qb + jnp.arange(qb)

        def kv_step(carry, ki):
            acc, m, l = carry                           # acc: (b, h, qb, hd)
            kblk, vblk, ik = ki
            k_pos = ik * kb + jnp.arange(kb)
            s_ = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk).astype(jnp.float32) * scale
            mask = jnp.ones((qb, kb), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if win:
                mask &= q_pos[:, None] - k_pos[None, :] < win
            s_ = jnp.where(mask, s_, -1e30)
            m_new = jnp.maximum(m, s_.max(-1))          # (b, h, qb)
            p_ = jnp.exp(s_ - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p_.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p_.astype(vblk.dtype), vblk).astype(jnp.float32)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, h, qb, hd), jnp.float32)
        m0 = jnp.full((b, h, qb), -1e30, jnp.float32)
        l0 = jnp.zeros((b, h, qb), jnp.float32)
        # checkpoint the block body: the backward pass recomputes each
        # block's scores instead of materializing the (nq x nk) grid of
        # (qb, kb) probability tiles — this IS flash attention's backward
        (acc, m, l), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (acc0, m0, l0),
            (k.swapaxes(0, 1), v.swapaxes(0, 1), jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.swapaxes(1, 2).astype(cfg.adtype)  # (b, qb, h, hd)

    _, o = jax.lax.scan(jax.checkpoint(q_step), None,
                        (q.swapaxes(0, 1), jnp.arange(nq)))
    # o: (nq, b, qb, h, hd) -> (b, sq, h, hd)
    return o.swapaxes(0, 1).reshape(b, sq, h, hd)


def _gather_pages(pool: jax.Array, block_table: jax.Array) -> jax.Array:
    """Gather a paged pool back into a per-slot dense view.

    pool: (n_pages, page_size, Kh, hd); block_table: (B, n_pp) int32 with
    -1 marking unallocated entries.  Returns (B, n_pp * page_size, Kh, hd).
    Holes clamp to page 0 — whatever lives there is garbage for that slot,
    but every attended position (kpos <= pos) sits in a page the slot
    owns, and the -1e30 mask ahead of the softmax zeroes the rest, so the
    garbage never reaches an output.  With page_size dividing max_len the
    gathered view has the dense cache's exact (B, max_len) reduction
    shape — paged attention is bit-identical to the dense oracle."""
    b, n_pp = block_table.shape
    g = jnp.take(pool, jnp.maximum(block_table, 0), axis=0)
    return g.reshape(b, n_pp * pool.shape[1], *pool.shape[2:])


def _attention_chunk(cfg: ModelConfig, q, k, v, cache):
    """Chunked-prefill attention against the DECODE cache layout.

    q/k/v: (B, S, ·, hd) — S prompt tokens per slot, each slot at its own
    sequence offset ``cache["pos"]`` with ``cache["n_valid"]`` (B,) real
    tokens this chunk (the tail is padding).  Writes are a per-slot scatter
    with ``mode="drop"``: padded tokens and any index at/past the cache end
    write NOWHERE, so the cache can never be clamp-corrupted by an
    oversized prompt — the overflow family's model-level guarantee.  The
    causal mask is per-query (kpos <= pos + i), so a chunk's logits match
    feeding its tokens one decode tick at a time.  Returns (out, new_cache)
    with ``pos`` advanced by ``n_valid``.

    A cache carrying ``block_table`` is PAGED: k/v are (n_pages,
    page_size, Kh, hd) pools and each token's write lands inside its
    slot's page for that position.  Padded tokens, positions past the
    block table, and unallocated (-1) entries all remap to the
    out-of-bounds page index ``n_pages`` so ``mode="drop"`` discards them
    (-1 itself would WRAP to the last page under numpy index
    normalization and corrupt it).
    """
    b, sq = q.shape[0], q.shape[1]
    pos, nv = cache["pos"], cache["n_valid"]
    off = jnp.arange(sq)
    tok_ok = off[None, :] < nv[:, None]                     # (B, Sq)
    qpos = pos[:, None] + off[None, :]                      # (B, Sq)
    if "block_table" in cache:
        bt = cache["block_table"]
        n_pages, page_size = cache["k"].shape[0], cache["k"].shape[1]
        n_pp = bt.shape[1]
        skv = n_pp * page_size
        pg_idx, within = jnp.divmod(qpos, page_size)
        pg = jnp.take_along_axis(bt, jnp.minimum(pg_idx, n_pp - 1), axis=1)
        pg = jnp.where(tok_ok & (pg_idx < n_pp) & (pg >= 0), pg, n_pages)
        ck = cache["k"].at[pg, within].set(
            k.astype(cache["k"].dtype), mode="drop")
        cv = cache["v"].at[pg, within].set(
            v.astype(cache["v"].dtype), mode="drop")
        ak, av = _gather_pages(ck, bt), _gather_pages(cv, bt)
        new_cache = {"k": ck, "v": cv, "block_table": bt, "pos": pos + nv}
    else:
        skv = cache["k"].shape[1]
        idx = jnp.where(tok_ok, qpos, skv)
        write = jax.vmap(lambda c, new, i: c.at[i].set(new, mode="drop"))
        ck = write(cache["k"], k.astype(cache["k"].dtype), idx)
        cv = write(cache["v"], v.astype(cache["v"].dtype), idx)
        ak, av = ck, cv
        new_cache = {"k": ck, "v": cv, "pos": pos + nv}
    valid = jnp.arange(skv)[None, None, :] <= qpos[:, :, None]  # (B, Sq, Skv)
    rep = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, sq, cfg.n_kv_heads, rep, cfg.hd)
    s_ = jnp.einsum("bqgrd,bkgd->bgrqk", qg, ak).astype(jnp.float32) \
        * cfg.hd ** -0.5
    s_ = jnp.where(valid[:, None, None, :, :], s_, -1e30)
    w = jax.nn.softmax(s_, axis=-1).astype(av.dtype)
    o = jnp.einsum("bgrqk,bkgd->bqgrd", w, av)
    o = o.reshape(b, sq, cfg.n_heads, cfg.hd)
    return o, new_cache


def attention_fwd(cfg: ModelConfig, p, x: jax.Array, positions: jax.Array,
                  cache: dict | None = None):
    """Self-attention.  Without a cache: full-sequence flash attention
    (train/prefill).  With a cache: single-step decode — update the cache at
    ``positions`` and attend over it.  A cache carrying ``n_valid`` takes
    the chunked-prefill path instead (S tokens per slot appended at per-slot
    offsets; dense caches only — ring buffers feed token-by-token).  A
    cache carrying ``block_table`` is PAGED (init_attn_cache(page_size=))
    on either path: writes scatter into the slot's pages with
    ``mode="drop"`` and attention gathers the pages back into the dense
    per-slot view — the block table is a TRACED input, so page
    allocation changes never retrace.

    Returns (out, new_cache).
    """
    b = x.shape[0]
    q, k, v = _qkv(cfg, p, x)
    q = apply_rope(cfg, q, positions)
    k = apply_rope(cfg, k, positions)

    if cache is not None and "n_valid" in cache:
        assert not cfg.sliding_window, \
            "chunked prefill targets dense decode caches; sliding-window " \
            "ring buffers feed their prompts token-by-token"
        o, new_cache = _attention_chunk(cfg, q, k, v, cache)
        o = o.reshape(b, o.shape[1], cfg.n_heads * cfg.hd)
        return jnp.dot(o, p["wo"].astype(o.dtype)), new_cache

    if cache is None:
        o = flash_attention(cfg, q, _repeat_kv(cfg, k), _repeat_kv(cfg, v))
        # Return the full-seq K/V (post-rope) so prefill can stack a decode
        # cache from scan outputs; the train path drops them (XLA while-loop
        # simplification DCEs unused scan ys).
        new_cache = {"k": k, "v": v}
    else:
        # decode: cache["k"]: (B, Skv, Kh, hd); cache["pos"]: (B,) per-slot
        # positions (continuous batching: every row may be at a different
        # sequence offset).  Writes are a vmapped dynamic_update_slice.
        # A cache carrying ``block_table`` is PAGED: k/v are (n_pages,
        # page_size, Kh, hd) pools shared by every slot, the block table
        # (B, n_pp) maps each slot's page index to a pool page (-1 =
        # unallocated), and attention runs over the gathered per-slot
        # view — same reduction shape as dense, so greedy tokens are
        # bit-identical to the page_size=0 oracle.
        pos = cache["pos"]
        if "block_table" in cache:
            assert not cfg.sliding_window, \
                "paged KV caches need absolute positions (no ring buffers)"
            bt = cache["block_table"]
            n_pages, page_size = cache["k"].shape[0], cache["k"].shape[1]
            skv = bt.shape[1] * page_size
            pg_idx, off = jnp.divmod(pos, page_size)
            pg = jnp.take_along_axis(
                bt, jnp.minimum(pg_idx, bt.shape[1] - 1)[:, None], 1)[:, 0]
            # unallocated entries are -1, which numpy-style indexing would
            # WRAP onto the last pool page — remap to the out-of-bounds
            # index n_pages so mode="drop" discards the write instead of
            # corrupting a live page
            pg = jnp.where(pg < 0, n_pages, pg)
            ck = cache["k"].at[pg, off].set(
                k[:, 0].astype(cache["k"].dtype), mode="drop")
            cv = cache["v"].at[pg, off].set(
                v[:, 0].astype(cache["v"].dtype), mode="drop")
            ak, av = _gather_pages(ck, bt), _gather_pages(cv, bt)
            valid = jnp.arange(skv)[None, :] <= pos[:, None]
            new_cache = {"k": ck, "v": cv, "block_table": bt, "pos": pos + 1}
        else:
            skv = cache["k"].shape[1]
            if cfg.sliding_window:
                slot = jnp.mod(pos, skv)                   # ring buffer
            else:
                slot = pos
            write = jax.vmap(
                lambda c, new, i: jax.lax.dynamic_update_slice(
                    c, new, (i, 0, 0)))
            ck = write(cache["k"], k.astype(cache["k"].dtype), slot)
            cv = write(cache["v"], v.astype(cache["v"].dtype), slot)
            kpos = jnp.arange(skv)
            if cfg.sliding_window:
                valid = (kpos[None, :] <= slot[:, None]) \
                    | (pos[:, None] >= skv)
            else:
                valid = kpos[None, :] <= pos[:, None]      # (B, Skv)
            ak, av = ck, cv
            new_cache = {"k": ck, "v": cv, "pos": pos + 1}
        # grouped-query attention without materializing the head repeat:
        # q -> (B, 1, KV, rep, hd) and contract against the raw cache.
        rep = cfg.n_heads // cfg.n_kv_heads
        qg = q.reshape(b, q.shape[1], cfg.n_kv_heads, rep, cfg.hd)
        s_ = jnp.einsum("bqgrd,bkgd->bgrqk", qg, ak).astype(jnp.float32) \
            * cfg.hd ** -0.5
        s_ = jnp.where(valid[:, None, None, None, :], s_, -1e30)
        w = jax.nn.softmax(s_, axis=-1).astype(av.dtype)
        o = jnp.einsum("bgrqk,bkgd->bqgrd", w, av)
        o = o.reshape(b, q.shape[1], cfg.n_heads, cfg.hd)

    o = o.reshape(b, o.shape[1], cfg.n_heads * cfg.hd)
    return jnp.dot(o, p["wo"].astype(o.dtype)), new_cache


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                    page_size: int = 0, n_pages: int = 0):
    """Decode KV cache; sliding-window archs get a ring buffer of window
    size.  ``pos`` is per-slot (continuous batching).

    ``page_size > 0`` builds the PAGED layout instead: k/v become a fixed
    pool of ``(n_pages, page_size, Kh, hd)`` blocks shared by every slot,
    plus a ``block_table`` (batch, max_len // page_size) int32 mapping
    each slot's page index to a pool page (-1 = unallocated — writes to a
    hole are dropped, never clamped).  ``page_size`` must divide
    ``max_len`` so the gathered per-slot view keeps the dense reduction
    shape (bit-exactness against the ``page_size=0`` oracle)."""
    if page_size:
        assert not cfg.sliding_window, \
            "paged KV caches need absolute positions (no ring buffers)"
        assert max_len % page_size == 0, (
            f"page_size={page_size} must divide max_len={max_len}")
        assert n_pages >= 1, f"paged cache needs n_pages >= 1, got {n_pages}"
        n_pp = max_len // page_size
        shape = (n_pages, page_size, cfg.n_kv_heads, cfg.hd)
        return {"k": jnp.zeros(shape, cfg.adtype),
                "v": jnp.zeros(shape, cfg.adtype),
                "block_table": jnp.full((batch, n_pp), -1, jnp.int32),
                "pos": jnp.zeros((batch,), jnp.int32)}
    length = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    shape = (batch, length, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, cfg.adtype), "v": jnp.zeros(shape, cfg.adtype),
            "pos": jnp.zeros((batch,), jnp.int32)}
