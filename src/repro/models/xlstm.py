"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, sequential scan with block-diagonal recurrence).

mLSTM recurrence (per head, stabilized in f32):
    C_t = f_t * C_{t-1} + i_t * v_t k_t^T        C: (hd_v, hd_qk)
    n_t = f_t * n_{t-1} + i_t * k_t              n: (hd_qk,)
    h_t = (C_t q_t) / max(|n_t . q_t|, 1)
with f_t = sigmoid(f~_t) (log-space cumulative products) and
i_t = exp(min(i~_t, CLAMP)).  Training/prefill uses the chunkwise algorithm
(mamba2-style: within-chunk quadratic term + cross-chunk state scan) so the
sequential depth is S / chunk; decode is the O(1) update.

sLSTM cannot be parallelized over time (recurrent h_{t-1} feeds the gates) —
one lax.scan over the sequence, exactly as the xLSTM paper states.  Heads
use block-diagonal recurrent matrices.

Deviations from the official xLSTM code (noted in DESIGN.md): no causal
conv1d front, qk dim = d_in/2 (parameter budget), sigmoid forget gate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

I_CLAMP = 8.0  # clamp on the exponential input gate pre-activation


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_dims(cfg: ModelConfig):
    d = cfg.d_model
    d_in = cfg.ssm.expand * d          # value / gate width
    d_qk = d_in // 2                   # query/key width
    h = cfg.n_heads
    return d, d_in, d_qk, h, d_in // h, d_qk // h


def init_mlstm(key, cfg: ModelConfig):
    d, d_in, d_qk, h, hd_v, hd_qk = mlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    s = d ** -0.5
    return {
        "w_q": jax.random.normal(ks[0], (d, d_qk), cfg.pdtype) * s,
        "w_k": jax.random.normal(ks[1], (d, d_qk), cfg.pdtype) * s,
        "w_v": jax.random.normal(ks[2], (d, d_in), cfg.pdtype) * s,
        "w_z": jax.random.normal(ks[3], (d, d_in), cfg.pdtype) * s,   # output gate branch
        "w_if": jax.random.normal(ks[4], (d, 2 * h), cfg.pdtype) * s, # i~, f~ per head
        # forget-gate bias >0 (remember by default), input-gate bias <0
        "b_if": jnp.concatenate([jnp.full((h,), -2.0), jnp.full((h,), 3.0)]).astype(cfg.pdtype),
        "w_out": jax.random.normal(ks[5], (d_in, d), cfg.pdtype) * d_in ** -0.5,
        "norm_scale": jnp.ones((d_in,), cfg.pdtype),
    }


def _mlstm_gates(cfg: ModelConfig, p, x):
    """Returns q, k, v (headed), log_f, log_i — all f32 except qkv."""
    d, d_in, d_qk, h, hd_v, hd_qk = mlstm_dims(cfg)
    b, s, _ = x.shape
    q = jnp.dot(x, p["w_q"].astype(x.dtype)).reshape(b, s, h, hd_qk)
    k = jnp.dot(x, p["w_k"].astype(x.dtype)).reshape(b, s, h, hd_qk) * hd_qk ** -0.5
    v = jnp.dot(x, p["w_v"].astype(x.dtype)).reshape(b, s, h, hd_v)
    gates = jnp.dot(x, p["w_if"].astype(x.dtype)).astype(jnp.float32) \
        + p["b_if"].astype(jnp.float32)
    i_pre, f_pre = gates[..., :h], gates[..., h:]
    log_i = jnp.minimum(i_pre, I_CLAMP)                  # (B, S, H)
    log_f = jax.nn.log_sigmoid(f_pre)                    # (B, S, H), <= 0
    return q, k, v, log_f, log_i


def _gated_rmsnorm(x, z, scale):
    xf = (x * jax.nn.silu(z)).astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
    return (xf * r).astype(x.dtype) * scale.astype(x.dtype)


def mlstm_fwd(cfg: ModelConfig, p, x: jax.Array, state: dict | None = None):
    """x: (B, S, d) -> (y, new_state).  state: {"c": (B,H,hdv,hdqk),
    "n": (B,H,hdqk)}; decode path when S == 1 and state is given."""
    b, s, d = x.shape
    _, d_in, d_qk, h, hd_v, hd_qk = mlstm_dims(cfg)
    q, k, v, log_f, log_i = _mlstm_gates(cfg, p, x)
    z = jnp.dot(x, p["w_z"].astype(x.dtype))

    if state is not None and s == 1:
        c, n = state["c"], state["n"]
        f = jnp.exp(log_f[:, 0]).astype(jnp.float32)     # (B, H)
        i = jnp.exp(log_i[:, 0]).astype(jnp.float32)
        vk = jnp.einsum("bhv,bhk->bhvk", v[:, 0].astype(jnp.float32),
                        k[:, 0].astype(jnp.float32))
        c = c * f[..., None, None] + vk * i[..., None, None]
        n = n * f[..., None] + k[:, 0].astype(jnp.float32) * i[..., None]
        qf = q[:, 0].astype(jnp.float32)
        num = jnp.einsum("bhvk,bhk->bhv", c, qf)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf))
        y = num / jnp.maximum(den, 1.0)[..., None]
        y = y.reshape(b, 1, d_in).astype(x.dtype)
        y = _gated_rmsnorm(y, z, p["norm_scale"])
        return jnp.dot(y, p["w_out"].astype(x.dtype)), {"c": c, "n": n}

    # ----- chunkwise parallel (train / prefill) ------------------------------
    ck = min(cfg.ssm.chunk, s)
    assert s % ck == 0, (s, ck)
    nc = s // ck
    rs = lambda t: t.reshape(b, nc, ck, *t.shape[2:]).swapaxes(0, 1)
    q_c, k_c, v_c, lf_c, li_c = map(rs, (q, k, v, log_f, log_i))

    def chunk_step(carry, inp):
        c_st, n_st = carry                                # (B,H,hdv,hdqk), (B,H,hdqk)
        qc, kc, vc, lfc, lic = inp
        cum = jnp.cumsum(lfc, axis=1)                     # inclusive, (B, ck, H)
        total = cum[:, -1]
        qf = qc.astype(jnp.float32)
        kf = kc.astype(jnp.float32)
        vf = vc.astype(jnp.float32)
        # incoming-state contribution: decay_in[t] = exp(cum_t)
        decay_in = jnp.exp(cum)                           # (B, ck, H)
        num_st = jnp.einsum("blhk,bhvk->blhv", qf, c_st) * decay_in[..., None]
        den_st = jnp.einsum("blhk,bhk->blh", qf, n_st) * decay_in
        # within-chunk "attention": D[t,u] = exp(cum_t - cum_u + log_i_u), u <= t
        rel = cum[:, :, None, :] - cum[:, None, :, :] + lic[:, None, :, :]
        mask = jnp.tril(jnp.ones((ck, ck), bool))
        # mask BEFORE exp (0 * inf cotangent trap — see mamba2.py)
        rel = jnp.where(mask[None, :, :, None], rel, -1e30)
        dmat = jnp.exp(rel)                               # (B, l, u, H)
        scores = jnp.einsum("blhk,buhk->blhu", qf, kf)
        w = scores * dmat.swapaxes(2, 3)                  # (B, l, H, u)
        num_in = jnp.einsum("blhu,buhv->blhv", w, vf)
        # den_in[t] = sum_u D[t,u] (q_t . k_u) = row-sum of the weighted scores
        den_in = jnp.sum(w, axis=-1)
        num = num_st + num_in
        den = jnp.abs(den_st + den_in)
        y = num / jnp.maximum(den, 1.0)[..., None]        # (B, ck, H, hdv)
        # state update: c' = exp(total) c + sum_u exp(total - cum_u + li_u) v_u k_u^T
        # Fold the decay into v and contract u DIRECTLY — materializing the
        # (B, ck, H, hd_v, hd_qk) outer product first costs ~GBs of HBM
        # traffic per chunk (§Perf A.1); the fused form writes only the
        # (B, H, hd_v, hd_qk) result.
        carry_decay = jnp.exp(total[:, None] - cum + lic) # (B, ck, H)
        vz = vf * carry_decay[..., None]
        c_new = c_st * jnp.exp(total)[..., None, None] + jnp.einsum(
            "buhv,buhk->bhvk", vz, kf)
        n_new = n_st * jnp.exp(total)[..., None] + jnp.einsum(
            "buh,buhk->bhk", carry_decay, kf)
        return (c_new, n_new), y

    c0 = (state["c"] if state is not None
          else jnp.zeros((b, h, hd_v, hd_qk), jnp.float32))
    n0 = (state["n"] if state is not None
          else jnp.zeros((b, h, hd_qk), jnp.float32))
    step_fn = jax.checkpoint(chunk_step) if cfg.remat else chunk_step
    (c_f, n_f), y_c = jax.lax.scan(step_fn, (c0, n0), (q_c, k_c, v_c, lf_c, li_c))
    y = y_c.swapaxes(0, 1).reshape(b, s, d_in).astype(x.dtype)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    return jnp.dot(y, p["w_out"].astype(x.dtype)), {"c": c_f, "n": n_f}


def init_mlstm_state(cfg: ModelConfig, batch: int):
    _, d_in, d_qk, h, hd_v, hd_qk = mlstm_dims(cfg)
    return {"c": jnp.zeros((batch, h, hd_v, hd_qk), jnp.float32),
            "n": jnp.zeros((batch, h, hd_qk), jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_dims(cfg: ModelConfig):
    d, h = cfg.d_model, cfg.n_heads
    return d, h, d // h


def init_slstm(key, cfg: ModelConfig):
    d, h, hd = slstm_dims(cfg)
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    d_up = (4 * d // 3 + 127) // 128 * 128              # post-FFN at ratio 4/3
    return {
        # input projections for gates z, i, f, o (fused)
        "w_x": jax.random.normal(ks[0], (d, 4 * d), cfg.pdtype) * s,
        # block-diagonal recurrent weights, per head: (H, hd, 4*hd)
        "w_h": jax.random.normal(ks[1], (h, hd, 4 * hd), cfg.pdtype) * hd ** -0.5,
        "b": jnp.concatenate([jnp.zeros((d,)), jnp.full((d,), -2.0),
                              jnp.full((d,), 3.0), jnp.zeros((d,))]).astype(cfg.pdtype),
        "w_up": jax.random.normal(ks[2], (d, 2 * d_up), cfg.pdtype) * s,
        "w_down": jax.random.normal(ks[3], (d_up, d), cfg.pdtype) * d_up ** -0.5,
    }


def _slstm_cell(cfg: ModelConfig, p, xg, hprev, cprev, nprev, mprev):
    """One sLSTM step.  xg: (B, H, 4, hd) precomputed input-gate
    contributions (pre-transposed OUTSIDE the scan — §Perf A.2: per-step
    transposes were ~1/3 of the sequential loop's HBM traffic);
    hprev: (B, H, hd).  Returns (h, c, n, m) all (B, H, hd)."""
    d, h, hd = slstm_dims(cfg)
    b = xg.shape[0]
    rec = jnp.einsum("bhi,hio->bho", hprev, p["w_h"].astype(hprev.dtype))
    rec = rec.reshape(b, h, 4, hd)                       # (B, H, 4, hd)
    g = (xg + rec).astype(jnp.float32)
    zt = jnp.tanh(g[:, :, 0])
    i_pre = jnp.minimum(g[:, :, 1], I_CLAMP)
    f_pre = g[:, :, 2]
    ot = jax.nn.sigmoid(g[:, :, 3])
    log_f = jax.nn.log_sigmoid(f_pre)
    m = jnp.maximum(log_f + mprev, i_pre)                # stabilizer, (B,H,hd)
    i_s = jnp.exp(i_pre - m)
    f_s = jnp.exp(log_f + mprev - m)
    c = f_s * cprev + i_s * zt
    n = f_s * nprev + i_s
    hnew = ot * c / jnp.maximum(n, 1e-6)
    return hnew, c, n, m


def slstm_fwd(cfg: ModelConfig, p, x: jax.Array, state: dict | None = None):
    """x: (B, S, d) -> (y, new_state); sequential lax.scan over time."""
    b, s, d = x.shape
    _, h, hd = slstm_dims(cfg)
    xg = jnp.dot(x, p["w_x"].astype(x.dtype)) + p["b"].astype(x.dtype)  # (B,S,4d)
    # pre-transpose to the cell's (B, H, 4, hd) layout once, outside the
    # sequential scan (§Perf A.2)
    xg = xg.reshape(b, s, 4, h, hd).transpose(0, 1, 3, 2, 4)  # (B,S,H,4,hd)

    if state is None:
        z = jnp.zeros((b, h, hd), jnp.float32)
        st = (z, z, z, jnp.full((b, h, hd), -1e30, jnp.float32))
    else:
        st = (state["h"], state["c"], state["n"], state["m"])

    def step(carry, xg_t):
        hp, cp, np_, mp = carry
        hn, cn, nn, mn = _slstm_cell(cfg, p, xg_t, hp.astype(x.dtype), cp, np_, mp)
        return (hn.astype(jnp.float32), cn, nn, mn), hn.astype(x.dtype)

    # checkpoint per-step: backward keeps only the (h, c, n, m) carries,
    # not the gate pre-activations (the truly-sequential minimal state)
    step_fn = jax.checkpoint(step) if cfg.remat else step
    (hf, cf, nf, mf), ys = jax.lax.scan(step_fn, st, xg.swapaxes(0, 1))
    y = ys.swapaxes(0, 1).reshape(b, s, d)
    # post up/down FFN (GeGLU at ratio ~4/3, per the sLSTM block design)
    up = jnp.dot(y, p["w_up"].astype(x.dtype))
    u, g = jnp.split(up, 2, axis=-1)
    y = jnp.dot(u * jax.nn.gelu(g), p["w_down"].astype(x.dtype))
    return y, {"h": hf, "c": cf, "n": nf, "m": mf}


def init_slstm_state(cfg: ModelConfig, batch: int):
    d, h, hd = slstm_dims(cfg)
    z = jnp.zeros((batch, h, hd), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": jnp.full((batch, h, hd), -1e30, jnp.float32)}
