"""Model assembly: every assigned architecture family as one functional LM.

Families and their block layouts:
  dense / audio / vlm : N x (attn + FFN)            [scan over layers]
  moe                 : N x (attn + MoE-FFN)        [scan over layers]
  ssm (xLSTM)         : G x ((k-1) mLSTM + 1 sLSTM) [scan over groups, inner
                        scan over the mLSTM run]    (k = ssm.slstm_every)
  hybrid (zamba2)     : G x (k mamba2 + SHARED attn/FFN block)  (k =
                        attn_every; the shared block's params are one set
                        applied at every group boundary — Zamba2's design)

All stacking uses lax.scan over stacked param pytrees so compile time is
O(1) in depth; ``cfg.remat`` wraps block bodies in jax.checkpoint.

Steps (the units the dry-run lowers):
  train_step   (state, batch)  -> (state, metrics)  — fwd + bwd + optimizer
  prefill_step (params, batch) -> (last_logits, cache)
  decode_step  (params, cache, tokens) -> (logits, cache)

Inputs are tokens (B, S) int32, or precomputed embeddings (B, S, d) for
``input_mode="embeddings"`` (audio/vlm stubs).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2, moe, xlstm
from repro.models.approx_ffn import approx_ffn_fwd, init_approx_ffn
from repro.sharding.activations import constrain


# ---------------------------------------------------------------------------
# Per-family block init/apply
# ---------------------------------------------------------------------------

def _init_dense_block(key, cfg: ModelConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"ln1": L.init_norm(cfg, cfg.d_model), "attn": L.init_attn(k1, cfg),
         "ln2": L.init_norm(cfg, cfg.d_model)}
    if cfg.moe.n_experts:
        p["moe"] = moe.init_moe(k2, cfg)
    elif cfg.approx.enable:
        p["approx"] = init_approx_ffn(k3, cfg)
    else:
        p["ffn"] = L.init_ffn(k4, cfg)
    return p


def _dense_block(cfg: ModelConfig, p, x, positions, cache, *, serve=False,
                 row_mask=None, dispatch_plan=None, tier=None,
                 tier_margins=None, residency=None):
    """One transformer block.  Returns (x, new_cache, aux_loss, aux_metrics).

    ``dispatch_plan`` (serve + route_scope="tick"): the per-tick
    DispatchPlan built above the layer scan — this block's ApproxFFN
    executes against it instead of routing its own tokens.  ``tier``/
    ``tier_margins`` (serve, layer scope): per-slot QoS tiers for this
    block's own routing decision (a tick plan already embeds them).
    ``residency`` (serve, library): the traced (n_resident,) library
    residency map selecting the executed approximator rows."""
    h, new_cache = L.attention_fwd(cfg, p["attn"], L.norm_fwd(cfg, p["ln1"], x),
                                   positions, cache)
    aux = jnp.zeros((), jnp.float32)
    metrics = {}
    if cfg.parallel_block:
        # stablelm-2 style: FFN in parallel with attention, one residual
        f = _ffn_part(cfg, p, L.norm_fwd(cfg, p["ln1"], x), serve, row_mask,
                      dispatch_plan, tier, tier_margins, residency)
        f, aux, metrics = f
        x = x + h + f
    else:
        x = x + h
        f, aux, metrics = _ffn_part(cfg, p, L.norm_fwd(cfg, p["ln2"], x),
                                    serve, row_mask, dispatch_plan, tier,
                                    tier_margins, residency)
        x = x + f
    return x, new_cache, aux, metrics


def _ffn_part(cfg: ModelConfig, p, xn, serve, row_mask=None,
              dispatch_plan=None, tier=None, tier_margins=None,
              residency=None):
    if cfg.moe.n_experts:
        y, aux = moe.moe_fwd(cfg, p["moe"], xn)
        return y, aux, {}
    if cfg.approx.enable:
        y, a = approx_ffn_fwd(cfg, p["approx"], xn, serve=serve,
                              row_mask=row_mask, plan=dispatch_plan,
                              tier=tier, tier_margins=tier_margins,
                              residency=residency)
        m = {"invocation": a["invocation"], "router_acc": a["router_acc"]}
        if "label_votes" in a:  # train path: per-token competitive labels,
            # summed over the layer scan to supervise the tick-router head
            m["_label_votes"] = a["label_votes"]
        st = a.get("invoke_stats")
        if st is not None:  # serve-mode dispatch engine reports these
            total = jnp.maximum(jnp.sum(st["class_counts"]), 1) \
                .astype(jnp.float32)
            m["exact_frac"] = st["exact_frac"]
            m["dropped_frac"] = st["dropped"].astype(jnp.float32) / total
            m["padding_rows"] = st["padding_rows"].astype(jnp.float32)
            # the capacity autotuner's raw signal (global under a mesh):
            # routed + post-capacity per-class counts, dropped rows
            m["class_counts"] = st["class_counts"].astype(jnp.float32)
            m["dispatched"] = st["dispatched"].astype(jnp.float32)
            m["dropped_rows"] = st["dropped"].astype(jnp.float32)
            # per-tier QoS split: (n_tiers, n+1) routed / post-capacity
            # counts — the server attributes served invocation and drops
            # to each request's error-bound tier from these
            m["tier_counts"] = st["tier_counts"].astype(jnp.float32)
            m["tier_dispatched"] = st["tier_dispatched"] \
                .astype(jnp.float32)
            # library residency: full-library demand (the
            # ResidencyController's promotion signal) + off-set rows
            # folded onto the exact path (lib_counts == class_counts and
            # 0 off-set rows on library-less deployments)
            m["lib_counts"] = st["lib_counts"].astype(jnp.float32)
            m["off_set_exact_rows"] = st["off_set_exact_rows"] \
                .astype(jnp.float32)
        return y, a["loss"], m
    return L.ffn_fwd(cfg, p["ffn"], xn), jnp.zeros((), jnp.float32), {}


# ---- xLSTM ---------------------------------------------------------------

def _init_mlstm_block(key, cfg):
    return {"ln": L.init_norm(cfg, cfg.d_model), "core": xlstm.init_mlstm(key, cfg)}


def _init_slstm_block(key, cfg):
    return {"ln": L.init_norm(cfg, cfg.d_model), "core": xlstm.init_slstm(key, cfg)}


def _mlstm_block(cfg, p, x, state):
    y, st = xlstm.mlstm_fwd(cfg, p["core"], L.norm_fwd(cfg, p["ln"], x), state)
    return x + y, st


def _slstm_block(cfg, p, x, state):
    y, st = xlstm.slstm_fwd(cfg, p["core"], L.norm_fwd(cfg, p["ln"], x), state)
    return x + y, st


# ---- zamba2 hybrid ---------------------------------------------------------

def _init_mamba_block(key, cfg):
    return {"ln": L.init_norm(cfg, cfg.d_model), "core": mamba2.init_mamba(key, cfg)}


def _mamba_block(cfg, p, x, state):
    y, st = mamba2.mamba_fwd(cfg, p["core"], L.norm_fwd(cfg, p["ln"], x), state)
    return x + y, st


# ---------------------------------------------------------------------------
# Model topology descriptors
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Topology:
    """How layers group into scan stacks for a family."""

    kind: str            # "uniform" | "xlstm" | "hybrid"
    n_groups: int = 0
    per_group: int = 0   # inner homogeneous run length


def topology(cfg: ModelConfig) -> Topology:
    if cfg.family == "ssm":
        k = cfg.ssm.slstm_every
        assert cfg.n_layers % k == 0, (cfg.n_layers, k)
        return Topology("xlstm", cfg.n_layers // k, k - 1)
    if cfg.family == "hybrid":
        k = cfg.attn_every or 6
        assert cfg.n_layers % k == 0, (cfg.n_layers, k)
        return Topology("hybrid", cfg.n_layers // k, k)
    return Topology("uniform", cfg.n_layers, 1)


def _stack_init(key, n, init_fn):
    """Init n copies of a block with stacked leaves (leading dim n)."""
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_model(key: jax.Array, cfg: ModelConfig):
    topo = topology(cfg)
    ke, kb, ks = jax.random.split(key, 3)
    params: dict[str, Any] = {"embed": L.init_embed(ke, cfg),
                              "ln_f": L.init_norm(cfg, cfg.d_model)}
    if topo.kind == "uniform":
        params["blocks"] = _stack_init(kb, topo.n_groups,
                                       lambda k: _init_dense_block(k, cfg))
    elif topo.kind == "xlstm":
        km, ksl = jax.random.split(kb)
        params["mlstm"] = _stack_init(
            km, topo.n_groups * topo.per_group,
            lambda k: _init_mlstm_block(k, cfg))
        params["mlstm"] = jax.tree.map(
            lambda a: a.reshape(topo.n_groups, topo.per_group, *a.shape[1:]),
            params["mlstm"])
        params["slstm"] = _stack_init(ksl, topo.n_groups,
                                      lambda k: _init_slstm_block(k, cfg))
    else:  # hybrid
        km, ka = jax.random.split(kb)
        params["mamba"] = _stack_init(
            km, topo.n_groups * topo.per_group,
            lambda k: _init_mamba_block(k, cfg))
        params["mamba"] = jax.tree.map(
            lambda a: a.reshape(topo.n_groups, topo.per_group, *a.shape[1:]),
            params["mamba"])
        # ONE shared attention+FFN block (Zamba2), applied per group
        params["shared"] = _init_dense_block(ks, dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, n_experts=0)))
    if cfg.approx.enable and not cfg.moe.n_experts \
            and topo.kind in ("uniform", "hybrid"):
        # tick-router head (route_scope="tick"): ONE (d, n+1) classifier on
        # the pre-layer hidden state, co-trained on the competitive labels
        # aggregated across layers — the paper's one decision per input,
        # made once per decode tick and reused by every layer of the scan
        params["tick_router"] = jax.random.normal(
            jax.random.fold_in(ke, 1),
            (cfg.d_model, cfg.approx.n_live + 1),
            cfg.pdtype) * cfg.d_model ** -0.5
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill): scan over stacked blocks
# ---------------------------------------------------------------------------

def _maybe_remat(cfg, fn):
    return jax.checkpoint(fn) if cfg.remat else fn


def forward(cfg: ModelConfig, params, inputs: jax.Array, *,
            collect_cache: bool = False, serve: bool = False):
    """Full-sequence forward.  inputs: tokens (B, S) or embeds (B, S, d).

    Returns (logits (B, S, V), cache-or-None, aux_loss, metrics).
    """
    topo = topology(cfg)
    x = constrain(L.embed_fwd(cfg, params["embed"], inputs))
    b, s = x.shape[0], x.shape[1]
    positions = jnp.arange(s)[None, :]
    aux_total = jnp.zeros((), jnp.float32)
    metrics: dict[str, jax.Array] = {}
    cache = None
    # tick-router co-training: accumulate every layer's competitive labels
    # (one-hot votes) through the scan carry; after the scan the TICK
    # router head trains on the across-layer modal label per token
    train_tick = ("tick_router" in params and not serve
                  and cfg.approx.enable and not cfg.moe.n_experts)
    x0 = x
    votes0 = jnp.zeros((b * s, cfg.approx.n_live + 1), jnp.float32)

    if topo.kind == "uniform":
        def body(carry, blk):
            x, votes = carry
            x, kv, aux, m = _dense_block(cfg, blk, x, positions, None, serve=serve)
            if "_label_votes" in m:
                votes = votes + m.pop("_label_votes")
            # K/V are scan outputs ONLY when prefill needs them — XLA does
            # not reliably DCE unused (L, B, S, KV, hd) while-loop outputs
            kvs = (kv["k"], kv["v"]) if collect_cache else ()
            return (constrain(x), votes), (aux, m, kvs)
        (x, votes), (auxs, ms, kvs) = jax.lax.scan(
            _maybe_remat(cfg, body), (x, votes0), params["blocks"])
        aux_total = jnp.sum(auxs)
        # layer mean over the scan axis only: scalar metrics stay scalar,
        # per-class vectors (class_counts/dispatched) stay (n+1,)
        metrics = {k: jnp.mean(v, axis=0) for k, v in ms.items()}
        if collect_cache:
            ks, vs = kvs
            if cfg.sliding_window:
                w = min(s, cfg.sliding_window)
                assert s % w == 0, "ring-buffer alignment needs S % window == 0"
                ks, vs = ks[:, :, -w:], vs[:, :, -w:]
            cache = {"k": ks, "v": vs, "pos": jnp.full((b,), s, jnp.int32)}

    elif topo.kind == "xlstm":
        def group(x, grp):
            mblks, sblk = grp

            def inner(x, blk):
                x, st = _mlstm_block(cfg, blk, x, None)
                return constrain(x), st
            x, msts = jax.lax.scan(_maybe_remat(cfg, inner), x, mblks)
            x, sst = _slstm_block(cfg, sblk, x, None)
            return constrain(x), (msts, sst)
        x, (mstates, sstates) = jax.lax.scan(
            _maybe_remat(cfg, group), x, (params["mlstm"], params["slstm"]))
        if collect_cache:
            cache = {"mlstm": mstates, "slstm": sstates,
                     "pos": jnp.full((b,), s, jnp.int32)}

    else:  # hybrid
        shared = params["shared"]

        def group(carry, mblks):
            x, votes = carry

            def inner(x, blk):
                x, st = _mamba_block(cfg, blk, x, None)
                return constrain(x), st
            x, msts = jax.lax.scan(_maybe_remat(cfg, inner), x, mblks)
            x, kv, aux, m = _dense_block(cfg, shared, x, positions, None,
                                         serve=serve)
            if "_label_votes" in m:
                votes = votes + m.pop("_label_votes")
            kvs = (kv["k"], kv["v"]) if collect_cache else ()
            return (constrain(x), votes), (msts, aux, m, kvs)
        (x, votes), (mstates, auxs, ms, kvs) = jax.lax.scan(
            _maybe_remat(cfg, group), (x, votes0), params["mamba"])
        aux_total = jnp.sum(auxs)
        # group mean of the shared block's metrics (the uniform-family
        # convention) — the dispatch/invocation signal was previously
        # dropped here, leaving the autotuner blind for this family
        metrics = {k: jnp.mean(v, axis=0) for k, v in ms.items()}
        if collect_cache:
            ks, vs = kvs
            cache = {"mamba": mstates, "k": ks, "v": vs,
                     "pos": jnp.full((b,), s, jnp.int32)}

    if train_tick and topo.kind in ("uniform", "hybrid"):
        tick_labels = jnp.argmax(votes, -1)
        t_logits = jnp.dot(x0.reshape(b * s, -1),
                           params["tick_router"].astype(x0.dtype)) \
            .astype(jnp.float32)
        logp = jax.nn.log_softmax(t_logits, -1)
        tick_loss = -jnp.mean(jnp.take_along_axis(logp,
                                                  tick_labels[:, None], 1))
        aux_total = aux_total + cfg.approx.router_weight * tick_loss
        metrics = dict(metrics, tick_router_loss=tick_loss,
                       tick_router_acc=jnp.mean(
                           (jnp.argmax(t_logits, -1) == tick_labels)
                           .astype(jnp.float32)))

    x = L.norm_fwd(cfg, params["ln_f"], x)
    logits = L.unembed_fwd(cfg, params["embed"], x)
    return logits, cache, aux_total, metrics


# ---------------------------------------------------------------------------
# Decode (single token, cache update)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               page_size: int = 0, kv_pages: int = 0):
    """Empty decode cache sized for ``max_len`` context.

    ``page_size > 0`` builds the PAGED layout (uniform family only): k/v
    are per-layer pools ``(L, kv_pages, page_size, Kh, hd)`` plus ONE
    ``block_table`` (batch, max_len // page_size) shared by every layer —
    all layers write the same positions, so one table serves the stack.
    ``page_size=0`` keeps the dense ``(L, batch, max_len, Kh, hd)``
    layout, the bit-exact oracle."""
    topo = topology(cfg)
    if page_size:
        assert topo.kind == "uniform" and not cfg.sliding_window, (
            "paged KV caches need the uniform dense-attention family "
            f"(got family={cfg.family!r}, "
            f"sliding_window={cfg.sliding_window})")
        c = L.init_attn_cache(cfg, batch, max_len, page_size=page_size,
                              n_pages=kv_pages)
        stack = lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape))
        return {"k": stack(c["k"]), "v": stack(c["v"]),
                "block_table": c["block_table"],
                "pos": jnp.zeros((batch,), jnp.int32)}
    if topo.kind == "uniform":
        c = L.init_attn_cache(cfg, batch, max_len)
        stack = lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape))
        return {"k": stack(c["k"]), "v": stack(c["v"]),
                "pos": jnp.zeros((batch,), jnp.int32)}
    if topo.kind == "xlstm":
        m = xlstm.init_mlstm_state(cfg, batch)
        sl = xlstm.init_slstm_state(cfg, batch)
        st = lambda a, n: jnp.broadcast_to(a, n + a.shape)
        return {"mlstm": jax.tree.map(
                    lambda a: st(a, (topo.n_groups, topo.per_group)), m),
                "slstm": jax.tree.map(lambda a: st(a, (topo.n_groups,)), sl),
                "pos": jnp.zeros((batch,), jnp.int32)}
    # hybrid
    ms = mamba2.init_mamba_state(cfg, batch)
    ac = L.init_attn_cache(cfg, batch, max_len)
    st = lambda a, n: jnp.broadcast_to(a, n + a.shape)
    return {"mamba": jax.tree.map(
                lambda a: st(a, (topo.n_groups, topo.per_group)), ms),
            "k": st(ac["k"], (topo.n_groups,)), "v": st(ac["v"], (topo.n_groups,)),
            "pos": jnp.zeros((batch,), jnp.int32)}


def reset_slot(cfg: ModelConfig, cache, fresh, slot: int):
    """Reset one batch slot of a decode cache to ``fresh`` (a cache from
    init_cache): continuous batching admits a new request into a freed
    slot.  Batch-dim position per leaf: k/v (L, B, ...) -> 1; mlstm/mamba
    states (G, P, B, ...) -> 2; slstm states (G, B, ...) -> 1; pos -> 0.

    Paged caches (``block_table`` present): the k/v pools are SHARED by
    every slot — freeing pages is the server allocator's job, so the
    pools pass through untouched and only the slot's block-table row
    (back to -1) and ``pos`` (back to 0) reset."""
    paged = isinstance(cache, dict) and "block_table" in cache

    def bdim(path):
        head = path[0]
        if head in ("k", "v"):
            return None if paged else 1
        if head in ("mlstm", "mamba"):
            return 2
        if head == "slstm":
            return 1
        return 0  # pos, block_table

    def walk(path, c, f):
        if isinstance(c, dict):
            return {k: walk(path + (k,), c[k], f[k]) for k in c}
        d = bdim(path)
        if d is None:
            return c                       # shared page pool: not per-slot
        idx = tuple([slice(None)] * d + [slot])
        return c.at[idx].set(f[idx])
    return walk((), cache, fresh)


def pad_cache(cfg: ModelConfig, cache, max_len: int):
    """Grow a prefill-built cache's KV length to ``max_len`` (decode room).
    No-op for pure-SSM caches, ring buffers (fixed window), and paged
    caches (a fixed pool — capacity is kv_pages, not per-slot length)."""
    if "k" not in cache or cfg.sliding_window or "block_table" in cache:
        return cache
    pad = max_len - cache["k"].shape[2]
    if pad <= 0:
        return cache
    grow = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    return dict(cache, k=grow(cache["k"]), v=grow(cache["v"]))


def decode(cfg: ModelConfig, params, cache, inputs: jax.Array, *,
           serve: bool = True, collect_metrics: bool = False,
           row_mask: jax.Array | None = None,
           tier: jax.Array | None = None,
           tier_margins: jax.Array | None = None,
           residency: jax.Array | None = None):
    """One decode step.  inputs: tokens (B, 1) or embeds (B, 1, d).
    Returns (logits (B, V), new_cache), or (logits, new_cache, metrics)
    when ``collect_metrics`` — layer-meaned per-step block metrics (e.g.
    the ApproxFFN dispatch invocation rate; uniform family only).

    ``row_mask`` (optional, (B,) bool) marks the ACTIVE batch slots of a
    continuous-batching server.  Idle slots (fed dummy token 0) are
    excluded from the serve-mode dispatch and its invoke stats, so the
    reported invocation/exact_frac are exact on partially-full tables.

    ``tier`` (optional, (B,) int32) + ``tier_margins`` ((n_tiers,)
    float32, TRACED — margin changes never retrace): per-slot QoS tiers.
    Each slot's row routes at its own error-bound tier via the
    exact-logit margin (runtime/dispatch.route), one batch mixing tiers
    freely, and the metrics gain the per-tier invoke-stat split.  With
    ``tier=None`` the step traces the margin-free program unchanged.

    ``cfg.approx.route_scope="tick"``: the MCMA routing decision is made
    ONCE per tick — a DispatchPlan built from the tick-router head on the
    pre-layer hidden state (approx_ffn.make_tick_plan), hoisted above the
    layer scan and reused by every layer, so each layer's dispatch is one
    weight-switch launch on already-sorted rows (no per-layer argsort/
    bincount/rank), and the reported invoke stats are the ONE tick-level
    observation (every layer sees the same plan).

    ``residency`` (optional, (n_resident,) int32 library ids, TRACED):
    approximator-library serving (``cfg.approx.library_size > 0``) —
    routing covers the full library, the residency map folds classes onto
    resident slots, and every layer executes against the residency-
    gathered weight rows.  A hot-set swap between ticks is a new vector
    through this same compiled step — zero retraces."""
    topo = topology(cfg)
    x = L.embed_fwd(cfg, params["embed"], inputs)
    pos = cache["pos"]                                   # (B,) per-slot
    positions = pos[:, None]
    step_metrics: dict[str, jax.Array] = {}
    plan = None
    if serve and cfg.approx.enable:
        if cfg.approx.route_scope not in ("layer", "tick"):
            # a typo would otherwise silently fall back to per-layer routing
            raise ValueError(f"unknown route_scope: "
                             f"{cfg.approx.route_scope!r} "
                             "(expected 'layer' or 'tick')")
        if (cfg.approx.route_scope == "tick" and not cfg.moe.n_experts
                and topo.kind in ("uniform", "hybrid")):
            from repro.models.approx_ffn import make_tick_plan
            plan = make_tick_plan(cfg, params, x, row_mask, tier=tier,
                                  tier_margins=tier_margins,
                                  residency=residency)
            tier = tier_margins = None   # the plan embeds the tiers

    if topo.kind == "uniform":
        # The cache is CARRIED and updated in place (dynamic-update-slice
        # inside the while loop aliases the donated input buffer) — passing
        # it as scan xs/ys would materialize two extra (L, B, S, KV, hd)
        # temporaries, which at 32k context is the whole HBM budget.
        bt = cache.get("block_table")     # paged: ONE table for all layers

        def body(carry, blk_i):
            x, ck, cv = carry
            blk, i = blk_i
            lc = {"k": ck[i], "v": cv[i], "pos": pos}
            if bt is not None:
                lc["block_table"] = bt
            x, nc, _, m = _dense_block(cfg, blk, x, positions, lc, serve=serve,
                                       row_mask=row_mask, dispatch_plan=plan,
                                       tier=tier, tier_margins=tier_margins,
                                       residency=residency)
            m.pop("_label_votes", None)   # train-only co-training signal
            ck = jax.lax.dynamic_update_index_in_dim(ck, nc["k"], i, 0)
            cv = jax.lax.dynamic_update_index_in_dim(cv, nc["v"], i, 0)
            return (x, ck, cv), (m if collect_metrics else None)
        (x, ks, vs), ms = jax.lax.scan(
            body, (x, cache["k"], cache["v"]),
            (params["blocks"], jnp.arange(cfg.n_layers)))
        # ``row_mask`` also gates the position advance: a masked slot HOLDS
        # its sequence offset (its dummy-token KV write at ``pos`` is
        # overwritten by the slot's next real token), so a chunked-prefill
        # scheduler can run decode ticks while other slots sit mid-prompt
        # without corrupting them.  Unmasked rows see pos + 1 exactly.
        adv = 1 if row_mask is None else row_mask.astype(jnp.int32)
        new_cache = {"k": ks, "v": vs, "pos": pos + adv}
        if bt is not None:
            new_cache["block_table"] = bt
        if collect_metrics and ms is not None:
            step_metrics = {k: jnp.mean(v, axis=0) for k, v in ms.items()}

    elif topo.kind == "xlstm":
        def group(x, grp):
            mblks, msts, sblk, sst = grp

            def inner(x, bs):
                blk, st = bs
                x, ns = _mlstm_block(cfg, blk, x, st)
                return x, ns
            x, nmsts = jax.lax.scan(inner, x, (mblks, msts))
            x, nsst = _slstm_block(cfg, sblk, x, sst)
            return x, (nmsts, nsst)
        x, (nm, nsl) = jax.lax.scan(
            group, x, (params["mlstm"], cache["mlstm"], params["slstm"],
                       cache["slstm"]))
        new_cache = {"mlstm": nm, "slstm": nsl, "pos": pos + 1}

    else:  # hybrid
        shared = params["shared"]
        topo_g = topo.n_groups

        def group(carry, grp):
            x, ck, cv = carry
            mblks, msts, gi = grp

            def inner(x, bs):
                blk, st = bs
                x, ns = _mamba_block(cfg, blk, x, st)
                return x, ns
            x, nmsts = jax.lax.scan(inner, x, (mblks, msts))
            lc = {"k": ck[gi], "v": cv[gi], "pos": pos}
            x, nc, _, m = _dense_block(cfg, shared, x, positions, lc,
                                       serve=serve, row_mask=row_mask,
                                       dispatch_plan=plan, tier=tier,
                                       tier_margins=tier_margins,
                                       residency=residency)
            m.pop("_label_votes", None)   # train-only co-training signal
            ck = jax.lax.dynamic_update_index_in_dim(ck, nc["k"], gi, 0)
            cv = jax.lax.dynamic_update_index_in_dim(cv, nc["v"], gi, 0)
            return (x, ck, cv), (nmsts, m if collect_metrics else None)
        (x, ks, vs), (nm, ms) = jax.lax.scan(
            group, (x, cache["k"], cache["v"]),
            (params["mamba"], cache["mamba"], jnp.arange(topo_g)))
        new_cache = {"mamba": nm, "k": ks, "v": vs, "pos": pos + 1}
        if collect_metrics and ms is not None:
            # group mean of the shared block's dispatch metrics — this used
            # to be dropped, leaving the autotuner blind for this family
            step_metrics = {k: jnp.mean(v, axis=0) for k, v in ms.items()}

    x = L.norm_fwd(cfg, params["ln_f"], x)
    logits = L.unembed_fwd(cfg, params["embed"], x)
    if collect_metrics:
        return logits[:, 0], new_cache, step_metrics
    return logits[:, 0], new_cache


def decode_chunk(cfg: ModelConfig, params, cache, tokens: jax.Array,
                 n_valid: jax.Array, *, serve: bool = True,
                 collect_metrics: bool = False,
                 row_mask: jax.Array | None = None,
                 tier: jax.Array | None = None,
                 tier_margins: jax.Array | None = None,
                 residency: jax.Array | None = None):
    """One chunked-PREFILL step against the decode cache layout.

    tokens: (B, S) int32 — up to S prompt tokens per slot, appended to each
    slot's cache at its own offset ``cache["pos"]``; ``n_valid`` (B,) int32
    counts the real tokens per slot this chunk (0 = slot sits this step
    out entirely; the tail of its row is padding).  Returns
    ``(new_cache, metrics)`` with ``pos`` advanced by ``n_valid`` per slot.

    No logits: prefill chunks never sample — the next token after the
    prompt comes from feeding the FINAL prompt token through the regular
    decode step (bit-identical to token-by-token serving), so the unembed
    matmul over all S positions is skipped entirely.

    Uniform (dense-attention) family with a dense KV cache only: SSM and
    hybrid recurrences, and sliding-window ring buffers, consume their
    prompts token-by-token (the server's scheduler falls back for them).
    The serve-mode FFN dispatch (and the tick-scope plan) runs on the
    B*S chunk rows under a TOKEN-level mask — per-slot activity AND the
    per-token n_valid bound — so padded rows never touch the router,
    the capacity dispatch, or any invoke stat.
    """
    topo = topology(cfg)
    assert topo.kind == "uniform" and not cfg.sliding_window, \
        "decode_chunk needs the uniform family with a dense KV cache " \
        f"(got family={cfg.family!r}, sliding_window={cfg.sliding_window})"
    b, s = tokens.shape[0], tokens.shape[1]
    x = L.embed_fwd(cfg, params["embed"], tokens)
    pos = cache["pos"]                                   # (B,) per-slot
    positions = pos[:, None] + jnp.arange(s)[None, :]    # (B, S)
    tok_mask = jnp.arange(s)[None, :] < n_valid[:, None]
    if row_mask is not None:
        tok_mask = tok_mask & row_mask.astype(bool)[:, None]
    plan = None
    if serve and cfg.approx.enable:
        if cfg.approx.route_scope == "tick" and not cfg.moe.n_experts:
            from repro.models.approx_ffn import make_tick_plan
            plan = make_tick_plan(cfg, params, x, tok_mask, tier=tier,
                                  tier_margins=tier_margins,
                                  residency=residency)
            tier = tier_margins = None   # the plan embeds the tiers

    bt = cache.get("block_table")         # paged: ONE table for all layers

    def body(carry, blk_i):
        x, ck, cv = carry
        blk, i = blk_i
        lc = {"k": ck[i], "v": cv[i], "pos": pos, "n_valid": n_valid}
        if bt is not None:
            lc["block_table"] = bt
        x, nc, _, m = _dense_block(cfg, blk, x, positions, lc, serve=serve,
                                   row_mask=tok_mask, dispatch_plan=plan,
                                   tier=tier, tier_margins=tier_margins,
                                   residency=residency)
        m.pop("_label_votes", None)   # train-only co-training signal
        ck = jax.lax.dynamic_update_index_in_dim(ck, nc["k"], i, 0)
        cv = jax.lax.dynamic_update_index_in_dim(cv, nc["v"], i, 0)
        return (x, ck, cv), (m if collect_metrics else None)
    (_, ks, vs), ms = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["blocks"], jnp.arange(cfg.n_layers)))
    new_cache = {"k": ks, "v": vs, "pos": pos + n_valid.astype(jnp.int32)}
    if bt is not None:
        new_cache["block_table"] = bt
    metrics: dict[str, jax.Array] = {}
    if collect_metrics and ms is not None:
        metrics = {k: jnp.mean(v, axis=0) for k, v in ms.items()}
    return new_cache, metrics


# ---------------------------------------------------------------------------
# Losses / steps
# ---------------------------------------------------------------------------

def lm_loss(cfg: ModelConfig, params, inputs, labels):
    """Next-token cross-entropy (+ family aux losses).  labels: (B, S).

    CE is computed as a one-hot contraction, not take_along_axis: a gather
    over a vocab-sharded logits tensor forces the SPMD partitioner into
    token replication ("involuntary full rematerialization"); the one-hot
    einsum shards cleanly (tokens over data, vocab over model).
    """
    from repro.sharding.activations import constrain_logits, constrain_tokens
    logits, _, aux, metrics = forward(cfg, params, inputs)
    logits = constrain_logits(logits).astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, -1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), -1))
    onehot = jax.nn.one_hot(labels, cfg.vocab, dtype=shifted.dtype)
    picked = jnp.einsum("bsv,bsv->bs", shifted, onehot)
    nll = constrain_tokens(lse - picked)
    loss = jnp.mean(nll)
    metrics = dict(metrics, lm_loss=loss, aux_loss=aux)
    return loss + aux, metrics
