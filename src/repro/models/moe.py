"""Mixture-of-Experts FFN with top-k routing, capacity, and SORT-BASED
dispatch (MegaBlocks-style on XLA).

The classic GShard one-hot dispatch materializes a (T, E, cap) tensor —
at LM scale (1M tokens x 64 experts) that is petabytes.  Instead we:

  1. argsort the (token, choice) pairs by expert id;
  2. compute each pair's rank within its expert (static-shape cumsum math);
  3. scatter tokens into a (E*cap, d) buffer (over-capacity pairs drop to a
     trash slot — the GShard convention, residual carries dropped tokens);
  4. run the experts as one batched (E, cap, d) x (E, d, f) matmul — this
     shards as EP (experts over "model") or TP-in-expert (sharding/rules);
  5. gather back and combine with the (renormalized) gate weights.

Memory is O(E*cap*d + T*d); FLOPs scale with top_k * capacity_factor.
Aux load-balancing loss follows Switch/GShard: E * sum_e(f_e * p_e).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    ks = jax.random.split(key, 4)
    s_in, s_out = d ** -0.5, f ** -0.5
    p = {"router": jax.random.normal(ks[0], (d, e), cfg.pdtype) * s_in,
         "w_in": jax.random.normal(ks[1], (e, d, f), cfg.pdtype) * s_in,
         "w_out": jax.random.normal(ks[2], (e, f, d), cfg.pdtype) * s_out}
    if cfg.gated_ffn:
        p["w_gate"] = jax.random.normal(ks[3], (e, d, f), cfg.pdtype) * s_in
    return p


def moe_fwd(cfg: ModelConfig, p, x: jax.Array):
    """x: (B, S, d) -> (out, aux_loss).

    Distributed path (under a mesh + activation-sharding context): the
    dispatch is shard_mapped over the DATA axes — each data shard sorts and
    routes only its LOCAL tokens (GShard groups == data shards), while the
    expert matmuls stay in GSPMD auto mode over the model axis (EP or
    TP-in-expert per sharding/rules).  Without this, the global argsort
    forces the partitioner to replicate the (T*k, d) gather/scatter and
    all-reduce it — measured at ~250 s/step of wire time on the 64-expert
    train cell (§Perf B.1).  Expert weights are FSDP-stored over data and
    all-gathered here (classic FSDP unshard-on-use).

    Local path (tests, single host): GShard-style token groups of
    ``moe.scan_chunk`` via lax.scan, checkpointed per group.
    """
    from repro.sharding.activations import manual_dp_context
    mesh, dp = manual_dp_context()
    if mesh is not None and "model" in mesh.axis_names:
        md = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
        if cfg.moe.n_experts % md == 0:
            return _moe_fwd_manual(cfg, p, x, mesh, dp, md)
        # TP-in-expert archs (E < |model|) stay on the auto path
    return _moe_chunked(cfg, p, x)


def _moe_fwd_manual(cfg: ModelConfig, p, x, mesh, dp, md):
    """Fully-manual expert parallelism (shard_map over ALL mesh axes).

    Each model shard owns E/|model| experts; x is replicated across the
    model axis (standard for the residual stream), so dispatch needs NO
    token movement: every model shard routes its data-shard's tokens to its
    own experts, computes them, and the partial outputs are psum'd over
    "model" — exactly one all-reduce of the token block per layer, the same
    as a dense TP FFN.  FSDP weight storage is unsharded on use with one
    all_gather over the data axes.  Capacity is per (data shard, expert):
    GShard groups == data shards.
    """
    from repro.sharding.rules import moe_manual_specs
    e = cfg.moe.n_experts
    specs = moe_manual_specs(mesh, gated="w_gate" in p)
    axes = tuple(dp) + ("model",)

    def local(p_loc, x_loc):
        # unshard: router fully, expert weights over the FSDP (data) dim
        router = jax.lax.all_gather(
            jax.lax.all_gather(p_loc["router"], "model", axis=1, tiled=True),
            dp, axis=0, tiled=True)
        w = {k: jax.lax.all_gather(p_loc[k], dp, axis=1, tiled=True)
             for k in ("w_in", "w_out", "w_gate") if k in p_loc}
        e_loc = e // md
        e_off = jax.lax.axis_index("model") * e_loc
        y_part, aux = _moe_local_experts(cfg, router, w, x_loc, e_loc, e_off)
        y = jax.lax.psum(y_part, "model")
        return y, jax.lax.pmean(aux, axes)

    from repro.sharding.compat import shard_map_compat
    fn = shard_map_compat(local, mesh=mesh, in_specs=specs["in"],
                          out_specs=specs["out"],
                          axis_names=frozenset(axes), check=False)
    return fn(p, x)


def _moe_local_experts(cfg: ModelConfig, router, w, x, e_loc: int, e_off):
    """Route local tokens to THIS shard's experts (global top-k routing,
    local compute).  x: (B, S, d) local tokens; returns the partial output
    (zeros for tokens whose experts live elsewhere) and the aux loss.

    Dispatch plumbing (sort-based ranks, capacity slots, trash-slot
    scatter/gather) is the shared machinery of the serving dispatch engine
    (runtime/dispatch.py) — one implementation for MoE and MCMA."""
    from repro.runtime import dispatch as D
    b, s, d = x.shape
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    t = b * s
    cap = min(int(cfg.moe.capacity_factor * t * k / e) + 1, t)
    xt = x.reshape(t, d)

    logits = jnp.dot(xt, router.astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # global-expert rank math (capacity consistent across model shards);
    # only classes [e_off, e_off + e_loc) land in this shard's buffer
    e_flat = gate_idx.reshape(t * k)
    tok_flat = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k)).reshape(t * k)
    order, e_sorted, rank, _ = D.class_sort_ranks(e_flat, e)
    keep, slot = D.capacity_slots(e_sorted, rank, cap, n_local=e_loc,
                                  offset=e_off)

    xe = D.scatter_rows(xt[tok_flat[order]], slot, keep,
                        e_loc * cap).reshape(e_loc, cap, d)

    h = jnp.einsum("ecd,edf->ecf", xe, w["w_in"].astype(x.dtype))
    if cfg.gated_ffn:
        g = jnp.einsum("ecd,edf->ecf", xe, w["w_gate"].astype(x.dtype))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.silu(h)
    ye = jnp.einsum("ecf,efd->ecd", h, w["w_out"].astype(x.dtype))

    contrib = D.gather_rows(ye.reshape(e_loc * cap, d), slot, keep) \
        * gate_vals.reshape(t * k)[order][:, None].astype(ye.dtype)
    out = jnp.zeros((t, d), x.dtype).at[tok_flat[order]].add(contrib)

    frac_tokens = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], e,
                                          dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs) * cfg.moe.aux_weight
    return out.reshape(b, s, d), aux


def _moe_chunked(cfg: ModelConfig, p, x: jax.Array):
    """Token groups of ``moe.scan_chunk`` via lax.scan (bounds buffers)."""
    b, s, d = x.shape
    t = b * s
    ck = cfg.moe.scan_chunk
    if ck and t > ck and t % ck == 0:
        xg = x.reshape(t // ck, 1, ck, d)

        def one(_, xc):
            y, aux = _moe_group(cfg, p, xc)
            return None, (y, aux)
        # checkpoint per group: backward re-dispatches a group instead of
        # saving every group's (E, cap, d/f) buffers
        _, (yg, auxg) = jax.lax.scan(jax.checkpoint(one), None, xg)
        return yg.reshape(b, s, d), jnp.mean(auxg)
    return _moe_group(cfg, p, x)


def _moe_group(cfg: ModelConfig, p, x: jax.Array):
    """One token group.  x: (B, S, d) -> (out, aux_loss)."""
    from repro.runtime import dispatch as D
    b, s, d = x.shape
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    t = b * s
    cap = min(int(cfg.moe.capacity_factor * t * k / e) + 1, t)
    xt = x.reshape(t, d)

    logits = jnp.dot(xt, p["router"].astype(x.dtype)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, -1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                          # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch (shared engine plumbing) -----------------------
    e_flat = gate_idx.reshape(t * k)                                       # (T*k,)
    tok_flat = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k)).reshape(t * k)
    order, e_sorted, rank, _ = D.class_sort_ranks(e_flat, e)
    keep, slot = D.capacity_slots(e_sorted, rank, cap, n_local=e)

    # scatter tokens into the expert buffer
    xe = D.scatter_rows(xt[tok_flat[order]], slot, keep,
                        e * cap).reshape(e, cap, d)

    h = jnp.einsum("ecd,edf->ecf", xe, p["w_in"].astype(x.dtype))
    if cfg.gated_ffn:
        g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(x.dtype))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.silu(h)
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(x.dtype))

    # gather back + weighted combine (scatter-add over the k choices)
    contrib = D.gather_rows(ye.reshape(e * cap, d), slot, keep) \
        * gate_vals.reshape(t * k)[order][:, None].astype(ye.dtype)
    out = jnp.zeros((t, d), x.dtype).at[tok_flat[order]].add(contrib)

    # Switch-style load-balancing aux loss
    frac_tokens = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32),
                           axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs) * cfg.moe.aux_weight
    return out.reshape(b, s, d), aux
