"""Atomic, mesh-elastic checkpointing.

* Arrays are host-gathered (fully addressable) and written as one .npz per
  step plus a JSON manifest of the pytree structure — checkpoints carry NO
  mesh/sharding information, so a run can restore onto a different device
  count or mesh shape (elastic scaling; asserted in tests).
* Writes are atomic: write to ``<dir>/tmp.<step>``, fsync, rename to
  ``<dir>/step_<k>`` — a preempted writer never corrupts the latest
  checkpoint (restart-safe).
* keep_k garbage collection retains the newest k checkpoints.
* Restore: load host arrays, then ``jax.device_put`` against the target
  shardings (or plain arrays when no mesh is given).

At real multi-pod scale the same layout extends to per-host shard files +
a distributed barrier; on one host the gather is a no-op.  Bitwise resume
is tested (tests/test_checkpoint.py): save@k -> restore -> train to n must
equal uninterrupted train to n.
"""
from __future__ import annotations

import json
import os
import re
import shutil

import jax
import numpy as np


_EMPTY = "__empty_dict__"  # sentinel: empty subtree (e.g. non-param LN {})


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        if not tree:
            out[prefix[:-1]] = _EMPTY
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        if not tree:
            out[prefix[:-1]] = _EMPTY
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        is_sentinel = (isinstance(v, str) or
                       (hasattr(v, "dtype") and v.dtype.kind == "U"))
        node[parts[-1]] = {} if is_sentinel and str(v) == _EMPTY else v

    def fix(node):
        if not isinstance(node, dict):
            return node
        keys = list(node.keys())
        if keys and all(re.fullmatch(r"\d+", k) for k in keys):
            return [fix(node[str(i)]) for i in range(len(keys))]
        return {k: fix(v) for k, v in node.items()}
    return fix(root)


def save(ckpt_dir: str, step: int, state, *, keep_k: int = 3) -> str:
    flat = _flatten(state)
    host = {k: (np.asarray(v) if isinstance(v, str)
                else np.asarray(jax.device_get(v)))
            for k, v in flat.items()}
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    # npz with sanitized names + manifest mapping
    names = {k: f"a{i}" for i, k in enumerate(host)}
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{names[k]: v for k, v in host.items()})
    manifest = {"step": step,
                "paths": {k: {"name": names[k], "dtype": str(v.dtype),
                              "shape": list(v.shape)}
                          for k, v in host.items()}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep_k)
    return final


def _gc(ckpt_dir: str, keep_k: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep_k] if keep_k else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:09d}"), ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(m.group(1)))
    return out


def latest_step(ckpt_dir: str):
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int | None = None, *, shardings=None):
    """Returns (state, step).  ``shardings``: optional pytree of
    NamedShardings to place leaves onto (elastic restore)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        return None, None
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(path, "arrays.npz"))
    flat = {k: arrays[meta["name"]]
            for k, meta in manifest["paths"].items()}
    state = _unflatten(flat)
    if shardings is not None:
        state = jax.tree.map(lambda a, s: jax.device_put(a, s), state, shardings)
    return state, step
