"""Pallas TPU kernels for the MCMA hot paths.

mcma_mlp:     fused 2-layer approximator MLP (VMEM-resident weights).
switched_mlp: multi-approximator weight switch via scalar-prefetch grouped
              matmul (the paper's NPU weight-buffer swap, TPU-native).
ops:          jit'd wrappers (padding, class grouping, scatter-back).
ref:          pure-jnp oracles defining kernel semantics.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
