"""Pallas TPU kernel: FUSED dispatch — gather/compute/scatter in one pass.

The unfused engine path (ops.switched_apply) moves every activation row
three times per layer: scatter into class-sorted order, the weight-switch
kernel, gather back to original order — each a standalone XLA op crossing
HBM.  The paper's NPU gets its switch "within a cycle" precisely because
only synapse weights move while activations stay put (§III-D); this kernel
is the TPU-native equivalent of that claim.

Mechanics: the ``DispatchPlan``'s class-sort is folded into a single
(t_pad,) int32 ROW-INDEX vector (``fused_row_index``: padded position p
holds the original row that sorts there, or the trash id T for padding),
scalar-prefetched alongside ``tile_cls``.  The activations ride into the
kernel ONCE as a VMEM-resident block; each grid step reads its tile's row
ids from SMEM, gathers those rows VMEM-locally, computes the tile under
the weight block ``tile_cls[i]`` selects (the same scalar-prefetched
weight switch as switched_mlp.py), and the epilogue scatters the results
straight to their ORIGINAL row of the output block — which is flushed to
HBM once when the grid finishes.  Net: one HBM pass over activations per
layer and zero standalone gather/scatter ops in the surrounding program.

Padding rows (positions with row id T) gather a clamped real row, compute
garbage under that tile's weights, and scatter into the trash row T of the
(T + 1)-row output — sliced off afterwards — so they never touch a real
row.  Exact / over-capacity / masked rows ride the zero-weight
pseudo-class exactly as in the unfused kernel and come out exactly zero.

When fusion is sound: the whole activation block (T, d_in) and the
(T + 1, d_out) output must fit VMEM simultaneously with one weight block
— decode-tick batches do comfortably (a 1024×512 f32 block is 2 MiB
against ~16 MiB VMEM on v5e); past that, fall back to the unfused
``backend="pallas"`` path whose tiles stream.  The kernel keeps two
I/O strategies behind the static ``vector_io`` flag:

  * ``vector_io=True`` (default under ``interpret``): value-level
    vectorized gather/scatter inside the kernel body.  In interpret mode
    these lower to plain XLA gathers on the VMEM-resident block values —
    the CI-measurable form — and XLA keeps the revisited full-array
    blocks in place across grid steps.
  * ``vector_io=False`` (default compiled): per-row dynamic-slice copies
    (``fori_loop`` over SMEM row ids) — the Mosaic-friendly DMA form for
    real TPU runs.  Both branches are bit-identical (pinned in
    tests/test_fused_dispatch.py); the compute between them is shared
    and shape-identical to _switched_kernel, so results match the
    unfused kernel bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def fused_row_index(order: jax.Array, pos: jax.Array, t: int,
                    t_pad: int) -> jax.Array:
    """Fold a class-sort permutation into the kernel's row-index vector.

    ``(order, pos)`` come from ops.class_sort_plan (original row
    ``order[k]`` lands at padded position ``pos[k]``).  Returns a (t_pad,)
    int32 vector mapping each padded position to its ORIGINAL row — both
    the gather source on load and the scatter destination on store —
    with padding positions holding the trash id ``t``.
    """
    return jnp.full((t_pad,), t, jnp.int32).at[pos].set(
        order.astype(jnp.int32))


def _fused_kernel(t, block_t, d_in, vector_io,
                  rows_ref, tile_cls_ref, x_ref, w1_ref, b1_ref, w2_ref,
                  b2_ref, o_ref, xs_ref):
    del tile_cls_ref  # consumed by the weight index_maps only
    i = pl.program_id(0)
    base = i * block_t
    d_in_p = w1_ref.shape[1]

    # ---- gather-on-load: this tile's rows, VMEM-locally ------------------
    if vector_io:
        idx = jax.lax.dynamic_slice(rows_ref[...], (base,), (block_t,))
        src = jnp.minimum(idx, t - 1)          # padding rows read a real row
        xs = x_ref[...][src]
        if d_in_p > d_in:
            xs = jnp.pad(xs, ((0, 0), (0, d_in_p - d_in)))
    else:
        if d_in_p > d_in:
            @pl.when(i == 0)
            def _zero_lane_pad():
                xs_ref[:, d_in:] = jnp.zeros((block_t, d_in_p - d_in),
                                             xs_ref.dtype)

        def gather_body(k, carry):
            r = jnp.minimum(rows_ref[base + k], t - 1)
            xs_ref[k, :d_in] = x_ref[r, :]
            return carry
        jax.lax.fori_loop(0, block_t, gather_body, 0)
        xs = xs_ref[...]

    # ---- compute: identical shapes/ops to _switched_kernel ---------------
    h = jnp.dot(xs, w1_ref[0], preferred_element_type=jnp.float32)
    h = jnp.tanh(h + b1_ref[0].astype(jnp.float32))
    y = jnp.dot(h.astype(xs.dtype), w2_ref[0],
                preferred_element_type=jnp.float32)
    y = (y + b2_ref[0].astype(jnp.float32)).astype(o_ref.dtype)

    # ---- scatter-on-store: straight to original rows (trash row t for
    # padding positions; every real row is written exactly once) ----------
    if vector_io:
        o_ref[...] = o_ref[...].at[idx].set(y)
    else:
        def scatter_body(k, carry):
            o_ref[rows_ref[base + k], :] = y[k, :]
            return carry
        jax.lax.fori_loop(0, block_t, scatter_body, 0)


@functools.partial(jax.jit,
                   static_argnames=("block_t", "interpret", "vector_io"))
def switched_mlp_fused(x: jax.Array, rows: jax.Array, tile_cls: jax.Array,
                       w1: jax.Array, b1: jax.Array, w2: jax.Array,
                       b2: jax.Array, *, block_t: int = 256,
                       interpret: bool = False,
                       vector_io: bool | None = None) -> jax.Array:
    """Fused grouped MLP over UNSORTED rows via a prefetched row index.

    x: (T, d_in) in ORIGINAL row order; rows: (t_pad,) int32 row index
    from ``fused_row_index`` (t_pad % block_t == 0, every block_t tile
    single-class); tile_cls: (t_pad // block_t,) int32 per-tile class;
    w1: (n, d_in_p, d_h_p); b1: (n, 1, d_h_p); w2: (n, d_h_p, d_out_p);
    b2: (n, 1, d_out_p) — feature dims may exceed x's (lane padding).

    Returns (T + 1, d_out_p): row r of the input's result at row r, the
    trash row last — callers slice ``[:T, :d_out]``.
    """
    t, d_in = x.shape
    assert t >= 1, "fused dispatch needs at least one row"
    d_in_p, d_h_p = w1.shape[1], w1.shape[2]
    d_out_p = w2.shape[2]
    assert d_in <= d_in_p, (d_in, d_in_p)
    t_pad = rows.shape[0]
    assert t_pad % block_t == 0, (t_pad, block_t)
    num_tiles = t_pad // block_t
    if vector_io is None:
        vector_io = bool(interpret)

    # Named index maps (arity = grid rank 1 + num_scalar_prefetch 2): the
    # activation/output blocks are whole-array VMEM residents (constant
    # block index -> fetched once, flushed once); only the weight blocks
    # switch per tile, driven by the prefetched tile_cls exactly as in the
    # unfused kernel.
    def _resident(i, rows_s, tile_cls_s):
        return (0, 0)

    def _weight(i, rows_s, tile_cls_s):
        return (tile_cls_s[i], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((t, d_in), _resident),
            pl.BlockSpec((1, d_in_p, d_h_p), _weight),
            pl.BlockSpec((1, 1, d_h_p), _weight),
            pl.BlockSpec((1, d_h_p, d_out_p), _weight),
            pl.BlockSpec((1, 1, d_out_p), _weight),
        ],
        out_specs=pl.BlockSpec((t + 1, d_out_p), _resident),
        scratch_shapes=[pltpu.VMEM((block_t, d_in_p), x.dtype)],
    )
    kernel = functools.partial(_fused_kernel, t, block_t, d_in, vector_io)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t + 1, d_out_p), x.dtype),
        interpret=interpret,
    )(rows, tile_cls, x, w1, b1, w2, b2)
