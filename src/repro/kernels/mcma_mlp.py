"""Pallas TPU kernel: fused 2-layer MLP forward (the approximator hot path).

The approximator is a 2-layer tanh MLP — at LM scale (ApproxFFN) this is
``(T, d_model) @ (d_model, d_h) -> tanh -> @ (d_h, d_model)``.  Fusing both
matmuls keeps the (T, d_h) intermediate in VMEM: HBM traffic drops from
``2*T*d_h`` extra bytes (XLA materializes h) to zero, which matters because
d_h is small (low arithmetic intensity — the layer is memory-bound).

Tiling: grid over rows of x; both weight matrices stay resident in VMEM
across the whole grid (index_map returns block (0, 0) each step, so the
pipeline loads them once) — the TPU analog of the paper's per-PE weight
buffer.  VMEM budget per step:
  block_t*d_in + d_in*d_h + d_h*d_out + block_t*d_h + block_t*d_out  floats,
with the default block_t=256, d_in=d_out=2048, d_h=256: ~2.4 MB in bf16 —
comfortably inside the ~16 MB/core VMEM of a v5e.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...]
    # First matmul + bias + tanh, f32 accumulation on the MXU.
    h = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    h = jnp.tanh(h + b1_ref[...].astype(jnp.float32))
    # Second matmul stays in VMEM; cast h to the input dtype for the MXU.
    y = jnp.dot(h.astype(x.dtype), w2_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = (y + b2_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def mlp_forward(x: jax.Array, w1: jax.Array, b1: jax.Array, w2: jax.Array,
                b2: jax.Array, *, block_t: int = 256,
                interpret: bool = False) -> jax.Array:
    """Fused MLP forward.  All dims must already be tile-aligned
    (T % block_t == 0; feature dims % 128 == 0) — see ops.py for padding.
    """
    t, d_in = x.shape
    d_h = w1.shape[1]
    d_out = w2.shape[1]
    assert t % block_t == 0, (t, block_t)
    grid = (t // block_t,)
    return pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d_in), lambda i: (i, 0)),
            pl.BlockSpec((d_in, d_h), lambda i: (0, 0)),   # resident
            pl.BlockSpec((1, d_h), lambda i: (0, 0)),
            pl.BlockSpec((d_h, d_out), lambda i: (0, 0)),  # resident
            pl.BlockSpec((1, d_out), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d_out), x.dtype),
        interpret=interpret,
    )(x, w1, b1.reshape(1, -1), w2, b2.reshape(1, -1))
