"""Pallas TPU kernel: the sLSTM recurrence with VMEM-resident state.

The sLSTM is truly sequential (h_{t-1} feeds the gates through a
block-diagonal recurrent matmul), so XLA lowers it to a 4096-iteration
while loop whose tiny (B, H, hd) carries and ~30 per-step fusions bounce
through HBM every step — measured as the dominant HBM term of the
xlstm-1.3b train cell (EXPERIMENTS.md §Perf A).

This kernel runs the WHOLE time loop as a sequential Pallas grid:
  * grid = (S,); TPU executes grid steps in order on one core, so VMEM
    scratch persists across steps — the recurrent state (h, c, n, m) lives
    in VMEM for the entire sequence;
  * per step the kernel streams one xg block (the input-side gate
    pre-activations, precomputed as one big matmul OUTSIDE the kernel) and
    writes one output block — HBM traffic collapses to one read + one
    write of the sequence;
  * the recurrent weights (H, hd, 4*hd) stay resident (index_map -> 0).

VMEM budget (xlstm-1.3b: B_tile=8, H=4, hd=512, bf16 weights):
  wh 8.4 MiB + xg block 0.26 MiB + 4 state scratches 0.26 MiB + out block
  ~0.07 MiB  ==  ~9 MiB  (< 16 MiB/core v5e VMEM).

Stabilized cell (matches models/xlstm.py::_slstm_cell):
  z = tanh(gz)   o = sigmoid(go)
  m' = max(log_sigmoid(gf) + m, min(gi, CLAMP))
  c' = exp(log_sigmoid(gf) + m - m') c + exp(min(gi, CLAMP) - m') z
  n' = exp(log_sigmoid(gf) + m - m') n + exp(min(gi, CLAMP) - m')
  h' = o * c' / max(n', 1e-6)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

I_CLAMP = 8.0


def _slstm_kernel(xg_ref, wh_ref, h0_ref, c0_ref, n0_ref, m0_ref,
                  y_ref, hf_ref, cf_ref, nf_ref, mf_ref,
                  h_scr, c_scr, n_scr, m_scr):
    t = pl.program_id(0)
    s = pl.num_programs(0)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = h0_ref[...]
        c_scr[...] = c0_ref[...]
        n_scr[...] = n0_ref[...]
        m_scr[...] = m0_ref[...]

    h = h_scr[...]                                   # (B, H, hd) f32
    hd = h.shape[-1]
    # block-diagonal recurrent matmul, f32 accumulation on the MXU
    rec = jax.lax.dot_general(
        h.astype(wh_ref.dtype), wh_ref[...],
        dimension_numbers=(((2,), (1,)), ((1,), (0,))),
        preferred_element_type=jnp.float32)          # (H, B, 4*hd)
    rec = rec.transpose(1, 0, 2)                     # (B, H, 4*hd)
    g = xg_ref[0] + rec                              # (B, H, 4*hd) f32

    gz, gi, gf, go = (g[..., :hd], g[..., hd:2 * hd],
                      g[..., 2 * hd:3 * hd], g[..., 3 * hd:])
    z = jnp.tanh(gz)
    o = jax.nn.sigmoid(go)
    log_f = jax.nn.log_sigmoid(gf)
    i_pre = jnp.minimum(gi, I_CLAMP)
    m = m_scr[...]
    m_new = jnp.maximum(log_f + m, i_pre)
    i_s = jnp.exp(i_pre - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c = f_s * c_scr[...] + i_s * z
    n = f_s * n_scr[...] + i_s
    h_new = o * c / jnp.maximum(n, 1e-6)

    y_ref[0] = h_new.astype(y_ref.dtype)
    h_scr[...] = h_new
    c_scr[...] = c
    n_scr[...] = n
    m_scr[...] = m_new

    @pl.when(t == s - 1)
    def _final():
        hf_ref[...] = h_new
        cf_ref[...] = c
        nf_ref[...] = n
        mf_ref[...] = m_new


@functools.partial(jax.jit, static_argnames=("interpret",))
def slstm_scan(xg: jax.Array, wh: jax.Array, h0, c0, n0, m0, *,
               interpret: bool = False):
    """Run the sLSTM over a sequence.

    xg: (S, B, H, 4*hd) f32 — input-side gate pre-activations (incl. bias);
    wh: (H, hd, 4*hd) recurrent weights; h0/c0/n0/m0: (B, H, hd) f32.
    Returns (ys (S, B, H, hd) f32, (hf, cf, nf, mf)).
    """
    s, b, h, hd4 = xg.shape
    assert hd4 % 4 == 0, (
        f"xg last dim must stack the 4 gate pre-activations, got {hd4}")
    hd = hd4 // 4
    state_shape = jax.ShapeDtypeStruct((b, h, hd), jnp.float32)
    out_shape = (jax.ShapeDtypeStruct((s, b, h, hd), jnp.float32),
                 state_shape, state_shape, state_shape, state_shape)
    grid = (s,)
    res = pl.pallas_call(
        _slstm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, b, h, hd4), lambda t: (t, 0, 0, 0)),
            pl.BlockSpec((h, hd, hd4), lambda t: (0, 0, 0)),   # resident
            pl.BlockSpec((b, h, hd), lambda t: (0, 0, 0)),
            pl.BlockSpec((b, h, hd), lambda t: (0, 0, 0)),
            pl.BlockSpec((b, h, hd), lambda t: (0, 0, 0)),
            pl.BlockSpec((b, h, hd), lambda t: (0, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, b, h, hd), lambda t: (t, 0, 0, 0)),
            pl.BlockSpec((b, h, hd), lambda t: (0, 0, 0)),
            pl.BlockSpec((b, h, hd), lambda t: (0, 0, 0)),
            pl.BlockSpec((b, h, hd), lambda t: (0, 0, 0)),
            pl.BlockSpec((b, h, hd), lambda t: (0, 0, 0)),
        ),
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((b, h, hd), jnp.float32)
                        for _ in range(4)],
        interpret=interpret,
    )(xg, wh, h0, c0, n0, m0)
    ys, hf, cf, nf, mf = res
    return ys, (hf, cf, nf, mf)


# ---------------------------------------------------------------------------
# Trainable wrapper: Pallas forward, reference-recompute backward.
# Pallas kernels carry no autodiff rules; the standard recipe is a
# custom_vjp whose backward re-runs the (differentiable) reference scan and
# pulls cotangents through it — forward gets the VMEM win, backward costs
# what the XLA path always cost (recompute included, like remat).
# ---------------------------------------------------------------------------

import functools as _functools

from repro.kernels import ref as _ref


@_functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def slstm_scan_trainable(xg, wh, h0, c0, n0, m0, interpret=False):
    return slstm_scan(xg, wh, h0, c0, n0, m0, interpret=interpret)


def _fwd(xg, wh, h0, c0, n0, m0, interpret):
    out = slstm_scan(xg, wh, h0, c0, n0, m0, interpret=interpret)
    return out, (xg, wh, h0, c0, n0, m0)


def _bwd(interpret, res, cot):
    _, vjp = jax.vjp(lambda *a: _ref.slstm_scan_ref(*a), *res)
    return vjp(cot)


slstm_scan_trainable.defvjp(_fwd, _bwd)
