"""Pallas TPU kernel: multi-approximator "weight switch" (paper §III-D).

The NPU switches approximators by shipping a weight set from on-chip cache
to the PE weight buffers.  The TPU-native equivalent: rows are pre-sorted by
the classifier's class (ops.py), every grid tile is single-class, and a
SCALAR-PREFETCHED per-tile class index drives the weight BlockSpec index_map
— so the correct approximator's weights are DMA'd HBM->VMEM while the
previous tile computes.  Switching cost is therefore hidden behind compute
(the paper's "within a cycle" claim, Case 3), and when all approximators fit
VMEM the pipeline degenerates to Case 1 (no reload: consecutive tiles with
the same class reuse the same block).

Grid: one step per row-tile.  tile_cls (num_tiles,) int32 is the scalar
prefetch operand; weight index_maps select block ``tile_cls[i]`` of the
stacked (n_approx, ...) weight tensors.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _switched_kernel(tile_cls_ref, x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    del tile_cls_ref  # consumed by the index_maps only
    x = x_ref[...]
    h = jnp.dot(x, w1_ref[0], preferred_element_type=jnp.float32)
    h = jnp.tanh(h + b1_ref[0].astype(jnp.float32))
    y = jnp.dot(h.astype(x.dtype), w2_ref[0], preferred_element_type=jnp.float32)
    o_ref[...] = (y + b2_ref[0].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def switched_mlp(x: jax.Array, tile_cls: jax.Array, w1: jax.Array,
                 b1: jax.Array, w2: jax.Array, b2: jax.Array, *,
                 block_t: int = 256, interpret: bool = False) -> jax.Array:
    """Grouped MLP forward over class-sorted rows.

    x: (T, d_in) with T % block_t == 0 and every tile single-class;
    tile_cls: (T // block_t,) int32 — class of each tile;
    w1: (n, d_in, d_h); b1: (n, 1, d_h); w2: (n, d_h, d_out); b2: (n, 1, d_out).
    """
    t, d_in = x.shape
    n, _, d_h = w1.shape
    d_out = w2.shape[2]
    assert t % block_t == 0, (t, block_t)
    num_tiles = t // block_t

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((block_t, d_in), lambda i, tc: (i, 0)),
            pl.BlockSpec((1, d_in, d_h), lambda i, tc: (tc[i], 0, 0)),
            pl.BlockSpec((1, 1, d_h), lambda i, tc: (tc[i], 0, 0)),
            pl.BlockSpec((1, d_h, d_out), lambda i, tc: (tc[i], 0, 0)),
            pl.BlockSpec((1, 1, d_out), lambda i, tc: (tc[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, d_out), lambda i, tc: (i, 0)),
    )
    return pl.pallas_call(
        _switched_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, d_out), x.dtype),
        interpret=interpret,
    )(tile_cls, x, w1, b1, w2, b2)
