"""Pure-jnp oracles for the Pallas kernels.

These define the semantics the kernels must match (assert_allclose in
tests/test_kernels.py).  Shapes are the UNPADDED logical shapes; the ops.py
wrappers are responsible for padding/alignment before calling the kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mlp_forward_ref(x: jax.Array, w1: jax.Array, b1: jax.Array,
                    w2: jax.Array, b2: jax.Array) -> jax.Array:
    """Fused 2-layer MLP: tanh(x @ w1 + b1) @ w2 + b2.

    x: (T, d_in); w1: (d_in, d_h); w2: (d_h, d_out).
    Accumulation is f32 regardless of input dtype (MXU semantics).
    """
    h = jnp.tanh(jnp.dot(x.astype(jnp.float32), w1.astype(jnp.float32))
                 + b1.astype(jnp.float32))
    y = jnp.dot(h, w2.astype(jnp.float32)) + b2.astype(jnp.float32)
    return y.astype(x.dtype)


def switched_mlp_ref(x: jax.Array, cls: jax.Array, w1: jax.Array, b1: jax.Array,
                     w2: jax.Array, b2: jax.Array) -> jax.Array:
    """Per-row approximator selection (the MCMA weight switch).

    x: (T, d_in); cls: (T,) int32 in [0, n_approx);
    w1: (n, d_in, d_h); b1: (n, d_h); w2: (n, d_h, d_out); b2: (n, d_out).
    Row t is evaluated under approximator cls[t]'s weights.
    """
    w1t = w1[cls]                      # (T, d_in, d_h) gather
    b1t = b1[cls]
    w2t = w2[cls]
    b2t = b2[cls]
    h = jnp.tanh(jnp.einsum("ti,tih->th", x.astype(jnp.float32),
                            w1t.astype(jnp.float32)) + b1t.astype(jnp.float32))
    y = jnp.einsum("th,tho->to", h, w2t.astype(jnp.float32)) + b2t.astype(jnp.float32)
    return y.astype(x.dtype)


def slstm_scan_ref(xg, wh, h0, c0, n0, m0, clamp=8.0):
    """Oracle for the sLSTM recurrence kernel (kernels/slstm_scan.py).

    xg: (S, B, H, 4*hd) f32 gate pre-activations (order [z|i|f|o] per head);
    wh: (H, hd, 4*hd); states: (B, H, hd) f32.
    """
    s, b, h, hd4 = xg.shape
    assert hd4 % 4 == 0, (
        f"xg last dim must stack the 4 gate pre-activations, got {hd4}")
    hd = hd4 // 4

    def cell(carry, xg_t):
        hp, cp, np_, mp = carry
        rec = jnp.einsum("bhi,hio->bho", hp, wh.astype(jnp.float32))
        g = xg_t + rec
        gz, gi, gf, go = (g[..., :hd], g[..., hd:2 * hd],
                          g[..., 2 * hd:3 * hd], g[..., 3 * hd:])
        z = jnp.tanh(gz)
        o = jax.nn.sigmoid(go)
        log_f = jax.nn.log_sigmoid(gf)
        i_pre = jnp.minimum(gi, clamp)
        m = jnp.maximum(log_f + mp, i_pre)
        i_s = jnp.exp(i_pre - m)
        f_s = jnp.exp(log_f + mp - m)
        c = f_s * cp + i_s * z
        n = f_s * np_ + i_s
        hn = o * c / jnp.maximum(n, 1e-6)
        return (hn, c, n, m), hn

    (hf, cf, nf, mf), ys = jax.lax.scan(cell, (h0, c0, n0, m0), xg)
    return ys, (hf, cf, nf, mf)
