"""Public jit'd wrappers around the Pallas kernels.

Responsibilities kept OUT of the kernels:
  * padding feature dims to lane multiples (128) and rows to tile multiples;
  * sorting rows by classifier class and building the per-tile class index
    (every tile must be single-class for the weight switch);
  * scattering results back to the original row order.

Zero-padding is semantics-preserving for a tanh MLP (tanh(0) = 0 contributes
nothing through zero weight columns).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import fused_dispatch, mcma_mlp, switched_mlp

LANE = 128


def _pad_to(n: int, m: int) -> int:
    return (n + m - 1) // m * m


def _pad2(a: jax.Array, rows: int, cols: int) -> jax.Array:
    return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def mlp_apply(x: jax.Array, w1: jax.Array, b1: jax.Array, w2: jax.Array,
              b2: jax.Array, *, block_t: int = 256,
              interpret: bool = False) -> jax.Array:
    """Fused approximator MLP on arbitrary (T, d_in) inputs."""
    t, d_in = x.shape
    d_h, d_out = w1.shape[1], w2.shape[1]
    tp, d_in_p = _pad_to(max(t, 1), block_t), _pad_to(d_in, LANE)
    d_h_p, d_out_p = _pad_to(d_h, LANE), _pad_to(d_out, LANE)
    y = mcma_mlp.mlp_forward(
        _pad2(x, tp, d_in_p), _pad2(w1, d_in_p, d_h_p),
        jnp.pad(b1, (0, d_h_p - d_h)), _pad2(w2, d_h_p, d_out_p),
        jnp.pad(b2, (0, d_out_p - d_out)), block_t=block_t, interpret=interpret)
    return y[:t, :d_out]


def worst_case_rows(t: int, n: int, block_t: int) -> int:
    """Static padded row count class_sort_plan produces for T rows and n
    classes — i.e. the rows the switched kernel actually launches."""
    return _pad_to(t + n * block_t, block_t)


def prepad_switched_weights(w1: jax.Array, b1: jax.Array, w2: jax.Array,
                            b2: jax.Array, *, pseudo_classes: int = 1):
    """One-time serving form of an approximator weight stack.

    Appends ``pseudo_classes`` all-zero approximators (the nC/over-capacity
    rows ride through the switched kernel under them with exactly-zero
    contribution) and lane-pads every feature dim to a multiple of LANE, so
    ``switched_apply(..., prepadded=True)`` ships the stacks straight into
    the kernel with no per-call copies.  Padding regions are exact zeros —
    semantics-preserving for the tanh MLP (see module docstring).

    w1: (n, d_in, d_h); b1: (n, d_h); w2: (n, d_h, d_out); b2: (n, d_out)
    -> same order with leading dim n + pseudo_classes and padded features.
    """
    n, d_in, d_h = w1.shape
    d_out = w2.shape[2]
    d_in_p, d_h_p, d_out_p = (_pad_to(d_in, LANE), _pad_to(d_h, LANE),
                              _pad_to(d_out, LANE))
    z = pseudo_classes
    return (jnp.pad(w1, ((0, z), (0, d_in_p - d_in), (0, d_h_p - d_h))),
            jnp.pad(b1, ((0, z), (0, d_h_p - d_h))),
            jnp.pad(w2, ((0, z), (0, d_h_p - d_h), (0, d_out_p - d_out))),
            jnp.pad(b2, ((0, z), (0, d_out_p - d_out))))


def gather_resident_stacks(w1: jax.Array, b1: jax.Array, w2: jax.Array,
                           b2: jax.Array, residency: jax.Array):
    """Resident view of a LIBRARY weight stack — the runtime hot-set swap.

    The full library lives prepadded (prepad_switched_weights: leading dim
    ``library_size + 1``, the zero pseudo-class last).  ``residency`` is a
    TRACED (n_resident,) int32 vector of library ids; this gathers those
    rows plus the pseudo-class row into ``(n_resident + 1, ...)`` stacks —
    exactly the serving form ``switched_apply(prepadded=True)`` and the
    XLA oracle consume.  Resident slot ``i`` serves library class
    ``residency[i]``; the trailing row stays the zero pseudo-class.

    Because ``residency`` is traced data (never a shape), a promotion/
    demotion is a new int vector through the SAME compiled program — the
    shapes-are-static invariant the capacity-autotune ladder exploits,
    applied to weight residency.  Cost per call: an ``n_resident + 1``-row
    gather per stack (tiny next to one layer's matmuls).

    Degenerate residency ids are pinned, not undefined: an id outside
    ``[0, library_size)`` resolves to the zero pseudo-class row (the slot
    serves exact zeros — identical to an empty slot — instead of
    whatever row jax's gather clamping would pick), and duplicate ids
    simply duplicate the weight row (each slot still serves its class's
    rows deterministically).
    """
    lib = w1.shape[0] - 1                       # library_size (pseudo last)
    r = residency.astype(jnp.int32)
    r = jnp.where((r >= 0) & (r < lib), r, lib)
    idx = jnp.concatenate([r, jnp.asarray([lib], jnp.int32)])
    return w1[idx], b1[idx], w2[idx], b2[idx]


def class_sort_plan(cls: jax.Array, n: int, block_t: int):
    """Static-shape plan grouping rows by class into single-class row-tiles.

    cls: (T,) int32 in [0, n).  Returns ``(order, pos, tile_cls,
    padded_sizes, t_pad)``: original row ``order[i]`` lands at padded
    position ``pos[i]`` of a (t_pad, ...) buffer in which every
    ``block_t``-row tile holds rows of exactly one class
    (``tile_cls[tile]``); worst-case padding is one partial tile per class,
    so ``t_pad`` is static.  This is the invocation-side machinery the
    weight-switch kernel (switched_mlp.py) and the serving dispatch
    runtime (runtime/dispatch.py) share.
    """
    t = cls.shape[0]
    t_pad = worst_case_rows(t, n, block_t)     # static worst case
    assert t_pad % block_t == 0, (
        f"worst_case_rows must return a block_t multiple, got {t_pad}")

    # --- group rows by class (stable sort keeps cache-friendly order) ------
    order = jnp.argsort(cls, stable=True)
    cls_sorted = cls[order]
    sizes = jnp.bincount(cls, length=n)                       # (n,)
    padded_sizes = (sizes + block_t - 1) // block_t * block_t
    padded_off = jnp.concatenate([jnp.zeros(1, sizes.dtype),
                                  jnp.cumsum(padded_sizes)])  # (n+1,)
    start = jnp.concatenate([jnp.zeros(1, sizes.dtype), jnp.cumsum(sizes)])
    rank = jnp.arange(t) - start[cls_sorted]                  # rank within class
    pos = padded_off[cls_sorted] + rank                       # padded position

    # --- per-tile class ------------------------------------------------------
    tile_starts = jnp.arange(t_pad // block_t) * block_t
    tile_cls = jnp.clip(
        jnp.searchsorted(padded_off[1:], tile_starts, side="right"), 0, n - 1
    ).astype(jnp.int32)
    return order, pos, tile_cls, padded_sizes, t_pad


@functools.partial(jax.jit,
                   static_argnames=("block_t", "interpret", "prepadded",
                                    "d_out"))
def switched_apply(x: jax.Array, cls: jax.Array, w1: jax.Array, b1: jax.Array,
                   w2: jax.Array, b2: jax.Array, *, block_t: int = 256,
                   interpret: bool = False, prepadded: bool = False,
                   d_out: int | None = None,
                   sort_plan=None) -> jax.Array:
    """MCMA dispatch: row t is evaluated under approximator cls[t].

    x: (T, d_in); cls: (T,) int32 in [0, n).  Rows are grouped by class into
    single-class tiles (worst-case padding: one partial tile per class), the
    switched kernel runs over the padded buffer, and results scatter back.

    ``prepadded=True`` declares the weight stacks already in serving form
    (prepad_switched_weights: lane-padded feature dims, pseudo-classes
    appended) so no per-call weight copies happen on the hot path;
    ``d_out`` then gives the LOGICAL output width to slice back to (the
    padded stacks cannot tell it apart from its padding).

    ``sort_plan`` is an optional precomputed ``(order, pos, tile_cls)``
    triple from ``class_sort_plan(cls, n, block_t)`` — a caller that
    reuses one routing decision across many weight stacks (the tick-scope
    DispatchPlan, runtime/dispatch.py) pays the argsort/bincount once and
    every call here is just scatter -> kernel -> gather.  ``cls`` is
    ignored when it is given; it MUST have been built with the same
    ``block_t`` and class count.
    """
    t, d_in = x.shape
    n = w1.shape[0]
    if prepadded:
        assert d_out is not None, "prepadded stacks need an explicit d_out"
        d_in_p, d_h_p = w1.shape[1], w1.shape[2]
        assert d_in <= d_in_p, (d_in, d_in_p)
        w1p, w2p = w1, w2
        b1p, b2p = b1[:, None, :], b2[:, None, :]
    else:
        d_h, d_out = w1.shape[2], w2.shape[2]
        d_in_p, d_h_p, d_out_p = (_pad_to(d_in, LANE), _pad_to(d_h, LANE),
                                  _pad_to(d_out, LANE))
        w1p = jnp.pad(w1, ((0, 0), (0, d_in_p - d_in), (0, d_h_p - d_h)))
        b1p = jnp.pad(b1, ((0, 0), (0, d_h_p - d_h)))[:, None, :]
        w2p = jnp.pad(w2, ((0, 0), (0, d_h_p - d_h), (0, d_out_p - d_out)))
        b2p = jnp.pad(b2, ((0, 0), (0, d_out_p - d_out)))[:, None, :]
    if sort_plan is None:
        order, pos, tile_cls, _, t_pad = class_sort_plan(cls, n, block_t)
    else:
        order, pos, tile_cls = sort_plan
        t_pad = tile_cls.shape[0] * block_t

    xp = jnp.zeros((t_pad, d_in_p), x.dtype).at[pos, :d_in].set(x[order])

    yp = switched_mlp.switched_mlp(xp, tile_cls, w1p, b1p, w2p, b2p,
                                   block_t=block_t, interpret=interpret)
    # --- scatter back to original order -------------------------------------
    y_sorted = yp[pos, :d_out]
    return jnp.zeros((t, d_out), x.dtype).at[order].set(y_sorted)


@functools.partial(jax.jit,
                   static_argnames=("block_t", "interpret", "prepadded",
                                    "d_out", "vector_io"))
def switched_apply_fused(x: jax.Array, cls: jax.Array, w1: jax.Array,
                         b1: jax.Array, w2: jax.Array, b2: jax.Array, *,
                         block_t: int = 256, interpret: bool = False,
                         prepadded: bool = False, d_out: int | None = None,
                         sort_plan=None,
                         vector_io: bool | None = None) -> jax.Array:
    """``switched_apply`` with the gather/scatter fused into the kernel.

    Same contract and bit-identical results (the fused kernel's compute
    is shape-identical to the unfused one; see kernels/fused_dispatch.py),
    but the class-sort permutation rides into the kernel as a
    scalar-prefetched row-index vector instead of standalone XLA
    gather/scatter ops — activations cross HBM once per call.
    ``vector_io`` picks the kernel's I/O strategy (None = vectorized
    under interpret, per-row DMA loops compiled).
    """
    t, d_in = x.shape
    n = w1.shape[0]
    if prepadded:
        assert d_out is not None, "prepadded stacks need an explicit d_out"
        w1p, w2p = w1, w2
        b1p, b2p = b1[:, None, :], b2[:, None, :]
    else:
        d_h, d_out = w1.shape[2], w2.shape[2]
        d_in_p, d_h_p, d_out_p = (_pad_to(d_in, LANE), _pad_to(d_h, LANE),
                                  _pad_to(d_out, LANE))
        w1p = jnp.pad(w1, ((0, 0), (0, d_in_p - d_in), (0, d_h_p - d_h)))
        b1p = jnp.pad(b1, ((0, 0), (0, d_h_p - d_h)))[:, None, :]
        w2p = jnp.pad(w2, ((0, 0), (0, d_h_p - d_h), (0, d_out_p - d_out)))
        b2p = jnp.pad(b2, ((0, 0), (0, d_out_p - d_out)))[:, None, :]
    if sort_plan is None:
        order, pos, tile_cls, _, t_pad = class_sort_plan(cls, n, block_t)
    else:
        order, pos, tile_cls = sort_plan
        t_pad = tile_cls.shape[0] * block_t

    rows = fused_dispatch.fused_row_index(order, pos, t, t_pad)
    y = fused_dispatch.switched_mlp_fused(
        x, rows, tile_cls, w1p, b1p, w2p, b2p, block_t=block_t,
        interpret=interpret, vector_io=vector_io)
    return y[:t, :d_out]
