"""One-pass baseline [Mahajan et al., ISCA'16]:

Train the approximator once on ALL data; derive safe/unsafe labels from its
errors; train a binary classifier on those labels.  No iteration — the A<->C
correlation is ignored (paper §II-B).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # avoid circular import (apps imports core.mlp)
    from repro.apps.registry import App
from repro.core import quality
from repro.core.mlp import (MLPSpec, Params, apply_mlp, balanced_weights,
                            init_mlp, mlp_logits, train_mlp)


@dataclasses.dataclass
class BinaryPair:
    """A trained (approximator, binary classifier) pair."""

    app: "App"
    a_params: Params
    c_params: Params

    def dispatch(self, x: jax.Array) -> jax.Array:
        """True where the classifier accepts the input (class 1 = safe)."""
        logits = mlp_logits(self.c_params, x, self.app.cls_spec(2))
        return jnp.argmax(logits, -1) == 1

    def evaluate(self, x: jax.Array, y: jax.Array) -> quality.Metrics:
        err = quality.approx_errors(self.app, self.a_params, self.app.approx_spec, x, y)
        return quality.confusion_metrics(self.app, self.dispatch(x), err, err, 1)


def train_one_pass(app: "App", key: jax.Array, x, y, *, epochs: int = 1500,
                   lr: float = 1e-2) -> BinaryPair:
    ka, kc = jax.random.split(key)
    a0 = init_mlp(ka, app.approx_spec)
    a = train_mlp(a0, x, y, app.approx_spec, epochs=epochs, lr=lr)
    err = quality.approx_errors(app, a, app.approx_spec, x, y)
    labels = (err <= app.error_bound).astype(jnp.int32)
    c0 = init_mlp(kc, app.cls_spec(2))
    c = train_mlp(c0, x, labels, app.cls_spec(2), loss="xent", epochs=epochs,
                  lr=lr, weights=balanced_weights(labels, 2))
    return BinaryPair(app, a, c)
