"""Iterative baseline [Xu et al., DAC'17]:

Alternate: retrain the approximator on the data the classifier currently
accepts (and that is truly under the bound — the "AC" agreement set of
paper §III-A), then regenerate labels from the approximator and retrain the
classifier.  Error shrinks, but so does the accepted set — motivating MCMA.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # avoid circular import (apps imports core.mlp)
    from repro.apps.registry import App
from repro.core import quality
from repro.core.mlp import balanced_weights, init_mlp, mlp_logits, train_mlp
from repro.core.onepass import BinaryPair


def train_iterative(app: "App", key: jax.Array, x, y, *, iters: int = 5,
                    epochs: int = 1500, lr: float = 1e-2,
                    selection: str = "AC") -> BinaryPair:
    """``selection``: "AC" (paper default), "C" (classifier-only, clusters —
    used inside MCCA), or "A" (error-only, scatters; Fig. 2b)."""
    ka, kc = jax.random.split(key)
    aspec, cspec = app.approx_spec, app.cls_spec(2)
    a = init_mlp(ka, aspec)
    c = init_mlp(kc, cspec)
    w = jnp.ones(x.shape[0], jnp.float32)  # territory mask for the approximator
    for it in range(iters):
        a = train_mlp(a, x, y, aspec, weights=w, epochs=epochs, lr=lr)
        err = quality.approx_errors(app, a, aspec, x, y)
        labels = (err <= app.error_bound).astype(jnp.int32)
        c = train_mlp(c, x, labels, cspec, loss="xent", epochs=epochs, lr=lr,
                      weights=balanced_weights(labels, 2))
        accept = jnp.argmax(mlp_logits(c, x, cspec), -1) == 1
        if selection == "AC":
            w = (accept & (err <= app.error_bound)).astype(jnp.float32)
        elif selection == "C":
            w = accept.astype(jnp.float32)
        else:  # "A"
            w = (err <= app.error_bound).astype(jnp.float32)
        # Never let the territory collapse to nothing (keeps training defined).
        w = jnp.where(jnp.sum(w) < 8, jnp.ones_like(w), w)
    return BinaryPair(app, a, c)
