"""Analytical NPU performance/energy model (paper Fig. 8, DESIGN.md §6).

The paper estimates MCMA performance "by scaling the performance of NPU [10]
based on the invocation of NPU"; we do the same with an explicit model:

  T(method) = T_cls + inv * T_npu(A) + (1 - inv) * T_cpu
  E(method) = E_cls + inv * E_npu(A) + (1 - inv) * E_cpu

* NPU: 8 PEs per tile, 1 MAC/cycle/PE -> T_npu = MACs/8 + FIFO latency.
* CPU cost per call = per-app dynamic-instruction constants (registry).
* Energy: CPU ~ 1.0 nJ per cycle-op at nominal; NPU MAC ~ 0.03 nJ
  (order-of-magnitude from the NPU paper's ~3x energy gains at ~10x
  invocation cost gap).
* MCMA weight switch: Case 1/3 of paper §III-D — swap overlaps compute, so
  switching cost is 0 when all approximators fit the weight buffer and one
  reload otherwise; we charge ``switch_penalty`` cycles on a class change.

MCCA pays one classifier inference per consulted pair (its serial weakness);
MCMA pays exactly one (multiclass) classifier inference.
"""
from __future__ import annotations

import dataclasses

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # avoid circular import (apps imports core.mlp)
    from repro.apps.registry import App
from repro.core.mlp import MLPSpec

N_PES = 8.0
FIFO_LATENCY = 8.0            # cycles per NN inference, bus/FIFO overhead
CPU_ENERGY_PER_CYCLE = 1.0    # nJ
NPU_ENERGY_PER_MAC = 0.03     # nJ
NPU_ENERGY_STATIC = 2.0       # nJ per inference (FIFO/bus/controller)
WEIGHT_BUFFER_MACS = 4096     # capacity (weights) of the per-PE buffers x tile


def nn_cycles(spec: MLPSpec) -> float:
    return spec.n_macs / N_PES + FIFO_LATENCY


def nn_energy(spec: MLPSpec) -> float:
    return spec.n_macs * NPU_ENERGY_PER_MAC + NPU_ENERGY_STATIC


@dataclasses.dataclass(frozen=True)
class CostReport:
    time_per_call: float
    energy_per_call: float

    def speedup_vs(self, other: "CostReport") -> float:
        return other.time_per_call / self.time_per_call

    def energy_reduction_vs(self, other: "CostReport") -> float:
        return other.energy_per_call / self.energy_per_call


def cost(app: "App", invocation: float, *, n_approx: int = 1,
         n_classifier_calls: float = 1.0, multiclass: bool = False,
         switch_rate: float = 0.0) -> CostReport:
    """Expected per-call time (cycles) and energy (nJ) for a method.

    ``switch_rate``: probability consecutive inputs use different
    approximators (charges a weight reload when the buffer cannot hold all
    approximators — paper §III-D Case 3).
    """
    aspec = app.approx_spec
    cspec = app.cls_spec(n_approx + 1 if multiclass else 2)
    t_cls = n_classifier_calls * nn_cycles(cspec)
    e_cls = n_classifier_calls * nn_energy(cspec)
    all_fit = n_approx * aspec.n_macs <= WEIGHT_BUFFER_MACS
    switch_penalty = 0.0 if all_fit else aspec.n_macs / N_PES  # reload from cache
    t_approx = nn_cycles(aspec) + switch_rate * switch_penalty
    t = t_cls + invocation * t_approx + (1.0 - invocation) * app.cpu_cycles
    e = (e_cls + invocation * nn_energy(aspec)
         + (1.0 - invocation) * app.cpu_cycles * CPU_ENERGY_PER_CYCLE)
    return CostReport(t, e)


def cpu_only(app: "App") -> CostReport:
    return CostReport(app.cpu_cycles, app.cpu_cycles * CPU_ENERGY_PER_CYCLE)
