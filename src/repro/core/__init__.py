"""Core: the paper's contribution — approximators, classifiers, co-training
methods (one-pass / iterative / MCCA / MCMA), quality control, NPU cost
model, and the ApproxFFN LM-scale generalization.
"""
from repro.core.mlp import MLPSpec, apply_mlp, init_mlp, mlp_logits, train_mlp
from repro.core.onepass import BinaryPair, train_one_pass
from repro.core.iterative import train_iterative
from repro.core.mcca import MCCA, train_mcca
from repro.core.mcma import MCMA, train_mcma
from repro.core import npu_model, quality

__all__ = [
    "MLPSpec", "apply_mlp", "init_mlp", "mlp_logits", "train_mlp",
    "BinaryPair", "train_one_pass", "train_iterative",
    "MCCA", "train_mcca", "MCMA", "train_mcma", "npu_model", "quality",
]
