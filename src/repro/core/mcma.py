"""MCMA — Multiclass-Classifier and Multiple Approximators (paper §III-C).

One (n+1)-way classifier dispatches each input either to the approximator
predicted safe (classes 0..n-1) or to the CPU (class n = "nC").  Two
co-training data-allocation mechanisms:

* complementary — approximators are initialized SERIALLY on residual data
  (AdaBoost-flavored); iteration labels are produced by the FIRST
  approximator that fits each sample under the bound.
* competitive — all approximators train on ALL data from diversified
  inits/hyper-params; the label is the argmin-error approximator (if under
  the bound, else nC).

After initialization both schemes iterate: train the multiclass classifier
on the labels, re-partition the input space by the classifier's prediction
(each approximator's "territory"), retrain each approximator on its
territory, regenerate labels.  Invocation history per iteration reproduces
Fig. 9.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # avoid circular import (apps imports core.mlp)
    from repro.apps.registry import App
from repro.core import quality
from repro.core.mlp import init_mlp, mlp_logits, train_mlp


@dataclasses.dataclass
class MCMA:
    app: "App"
    a_params: list          # n approximator param pytrees (identical topology)
    c_params: object        # multiclass classifier params
    history: list           # per-iteration invocation on the training set
    scheme: str

    @property
    def n_approx(self) -> int:
        return len(self.a_params)

    def classify(self, x: jax.Array) -> jax.Array:
        """(n,) int class per input; == n_approx means nC (CPU)."""
        cspec = self.app.cls_spec(self.n_approx + 1)
        return jnp.argmax(mlp_logits(self.c_params, x, cspec), -1)

    def approximator_errors(self, x: jax.Array, y: jax.Array) -> jax.Array:
        aspec = self.app.approx_spec
        return jnp.stack([quality.approx_errors(self.app, a, aspec, x, y)
                          for a in self.a_params])  # (n_approx, n)

    def evaluate(self, x: jax.Array, y: jax.Array) -> quality.Metrics:
        errs = self.approximator_errors(x, y)
        cls = self.classify(x)
        dispatched = cls < self.n_approx
        err_chosen = errs[jnp.minimum(cls, self.n_approx - 1), jnp.arange(x.shape[0])]
        return quality.confusion_metrics(self.app, dispatched, err_chosen,
                                         errs.min(0), self.n_approx, cls)


def _labels_complementary(errs: jax.Array, bound: float,
                          prev: jax.Array | None = None) -> jax.Array:
    """First approximator under the bound wins; else nC (= n_approx)."""
    n_approx = errs.shape[0]
    safe = errs <= bound                                    # (n_approx, n)
    first = jnp.argmax(safe, axis=0)                        # first True (0 if none)
    any_safe = jnp.any(safe, axis=0)
    return jnp.where(any_safe, first, n_approx).astype(jnp.int32)


def _labels_competitive(errs: jax.Array, bound: float,
                        prev: jax.Array | None = None) -> jax.Array:
    """Lowest-error approximator wins if under the bound; else nC.

    With ``prev`` labels, ties are sticky (hysteresis): a sample only
    changes owner when the challenger beats the incumbent by 20% of the
    bound.  This is the paper's "bias of each approximator is reinforced" —
    without it, near-ties churn between owners every iteration and the
    classifier chases moving targets.
    """
    n_approx = errs.shape[0]
    if prev is not None:
        owner = jax.nn.one_hot(prev, n_approx + 1, axis=0)[:n_approx]  # (n_approx, n)
        errs = errs - 0.2 * bound * owner
    best = jnp.argmin(errs, axis=0)
    return jnp.where(errs.min(0) <= bound, best, n_approx).astype(jnp.int32)


from repro.core.mlp import balanced_weights as _balanced_weights  # noqa: E402


def train_mcma(app: "App", key: jax.Array, x, y, *, n_approx: int = 3,
               scheme: str = "competitive", iters: int = 5,
               epochs: int = 1500, lr: float = 1e-2) -> MCMA:
    assert scheme in ("competitive", "complementary")
    aspec = app.approx_spec
    cspec = app.cls_spec(n_approx + 1)
    keys = jax.random.split(key, n_approx + 1)
    kc, kas = keys[0], keys[1:]

    # ----- initialization pass ---------------------------------------------
    a_params = []
    if scheme == "complementary":
        residual = jnp.ones(x.shape[0], jnp.float32)
        for i in range(n_approx):
            a = init_mlp(kas[i], aspec)
            a = train_mlp(a, x, y, aspec, weights=residual, epochs=epochs, lr=lr)
            err = quality.approx_errors(app, a, aspec, x, y)
            residual = residual * (err > app.error_bound).astype(jnp.float32)
            residual = jnp.where(jnp.sum(residual) < 8, jnp.ones_like(residual) * 0.05,
                                 residual)
            a_params.append(a)
    else:  # competitive: diversified hyper-params reach different local minima
        for i in range(n_approx):
            a = init_mlp(kas[i], aspec, scale=0.3 * (i + 1))
            a = train_mlp(a, x, y, aspec, epochs=epochs, lr=lr * (0.5 + 0.5 * i))
            a_params.append(a)

    label_fn = _labels_complementary if scheme == "complementary" else _labels_competitive
    c = init_mlp(kc, cspec)
    history = []
    labels = None

    # ----- iterative co-training -------------------------------------------
    for it in range(iters):
        errs = jnp.stack([quality.approx_errors(app, a, aspec, x, y) for a in a_params])
        labels = label_fn(errs, app.error_bound, labels)
        c = train_mlp(c, x, labels, cspec, loss="xent", epochs=epochs, lr=lr,
                      weights=_balanced_weights(labels, n_approx + 1))
        pred = jnp.argmax(mlp_logits(c, x, cspec), -1)
        history.append(float(jnp.mean(pred < n_approx)))
        if it == iters - 1:
            break
        # The classifier partitions the input space into n+1 territories and
        # each approximator retrains on its own territory.  A sample also
        # keeps a small weight with its *current* owner (err under bound) so
        # a noisy classifier round cannot erase an approximator's competence.
        new_params = []
        for i, a in enumerate(a_params):
            w = ((pred == i).astype(jnp.float32)
                 + 0.25 * (errs[i] <= app.error_bound).astype(jnp.float32))
            w = jnp.where(jnp.sum(w) < 8, 0.05 * jnp.ones_like(w), w)
            new_params.append(train_mlp(a, x, y, aspec, weights=w, epochs=epochs, lr=lr))
        a_params = new_params

    return MCMA(app, a_params, c, history, scheme)


def _error_clusters(key: jax.Array, x: jax.Array, err: jax.Array,
                    k: int, iters: int = 10) -> jax.Array:
    """K-means partition over (inputs, probe-error) features.

    Samples a single global fit serves BADLY cluster together (the error
    coordinate dominates exactly where the probe struggles), so the
    specialists a residency deployment needs for rare-but-hard regions
    exist from round 0 instead of hoping hyper-param diversity finds
    them.  Returns the (n,) int32 cluster assignment."""
    xs = (x - x.mean(0)) / jnp.maximum(x.std(0), 1e-6)
    es = (err - err.mean()) / jnp.maximum(err.std(), 1e-6)
    z = jnp.concatenate([xs, 2.0 * es[:, None]], -1)
    mu = z[jax.random.choice(key, z.shape[0], (k,), replace=False)]
    for _ in range(iters):
        d = jnp.sum((z[:, None, :] - mu[None]) ** 2, -1)      # (n, k)
        assign = jnp.argmin(d, -1)
        onehot = jax.nn.one_hot(assign, k)                    # (n, k)
        cnt = onehot.sum(0)
        mu = jnp.where(cnt[:, None] > 0,
                       (onehot.T @ z) / jnp.maximum(cnt, 1.0)[:, None], mu)
    return jnp.argmin(jnp.sum((z[:, None, :] - mu[None]) ** 2, -1), -1)


def train_library(app: "App", key: jax.Array, x, y, *,
                  library_size: int = 8, scheme: str = "competitive",
                  iters: int = 3, epochs: int = 1500, lr: float = 1e-2,
                  cluster_iters: int = 10) -> MCMA:
    """Co-train a LIBRARY of approximators — MCMA at library scale.

    ``train_mcma`` trains the handful of approximators a deployment keeps
    permanently resident; this trains ``library_size`` of them (more than
    the prepadded weight stacks hold at once) for the residency runtime:
    routing happens over the full library and a ResidencyController
    (runtime/autotune.py) hot-swaps which ``n_resident`` occupy the
    stacks (runtime/options.LibrarySpec).

    Initialization is ERROR-CLUSTERED instead of train_mcma's
    hyper-param diversification — with 8-16 members, diversified inits
    collapse onto the same few local minima.  A probe approximator is
    fit on all data, each sample gets a (whitened input, probe residual
    error) feature vector, and k-means over those partitions the input
    space into ``library_size`` territories; each member initializes on
    its own territory.  The usual competitive/complementary co-training
    loop then runs with a ``(library_size + 1)``-way classifier.

    Returns an ``MCMA`` whose ``a_params`` has ``library_size`` entries;
    the serving config carries the same number in
    ``ApproxConfig.library_size`` (so stacks and router heads are sized
    by ``n_live``) while ``n_approx`` stays the resident-slot count."""
    assert scheme in ("competitive", "complementary")
    assert library_size >= 1
    aspec = app.approx_spec
    cspec = app.cls_spec(library_size + 1)
    keys = jax.random.split(key, library_size + 3)
    kc, kp, kk, kas = keys[0], keys[1], keys[2], keys[3:]

    # ----- error-clustered initialization ----------------------------------
    probe = train_mlp(init_mlp(kp, aspec), x, y, aspec, epochs=epochs, lr=lr)
    probe_err = quality.approx_errors(app, probe, aspec, x, y)
    assign = _error_clusters(kk, x, probe_err, library_size,
                             iters=cluster_iters)
    a_params = []
    for i in range(library_size):
        w = (assign == i).astype(jnp.float32)
        # a starved cluster falls back to a faint global fit (same guard
        # as train_mcma territories) rather than training on nothing
        w = jnp.where(jnp.sum(w) < 8, 0.05 * jnp.ones_like(w), w)
        a = init_mlp(kas[i], aspec, scale=0.3 * (1 + i % 3))
        a_params.append(train_mlp(a, x, y, aspec, weights=w,
                                  epochs=epochs, lr=lr))

    label_fn = _labels_complementary if scheme == "complementary" \
        else _labels_competitive
    c = init_mlp(kc, cspec)
    history = []
    labels = None

    # ----- iterative co-training (same loop shape as train_mcma) -----------
    for it in range(iters):
        errs = jnp.stack([quality.approx_errors(app, a, aspec, x, y)
                          for a in a_params])
        labels = label_fn(errs, app.error_bound, labels)
        c = train_mlp(c, x, labels, cspec, loss="xent", epochs=epochs, lr=lr,
                      weights=_balanced_weights(labels, library_size + 1))
        pred = jnp.argmax(mlp_logits(c, x, cspec), -1)
        history.append(float(jnp.mean(pred < library_size)))
        if it == iters - 1:
            break
        new_params = []
        for i, a in enumerate(a_params):
            w = ((pred == i).astype(jnp.float32)
                 + 0.25 * (errs[i] <= app.error_bound).astype(jnp.float32))
            w = jnp.where(jnp.sum(w) < 8, 0.05 * jnp.ones_like(w), w)
            new_params.append(train_mlp(a, x, y, aspec, weights=w,
                                        epochs=epochs, lr=lr))
        a_params = new_params

    return MCMA(app, a_params, c, history, scheme)
