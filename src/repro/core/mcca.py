"""MCCA — Multiple Cascaded Classifiers and Approximators (paper §III-B).

Pair i+1 is trained on the residual inputs rejected by classifiers 1..i
(category "C" selection inside each pair's iterative loop, per the paper).
The cascade stops when a pair "cannot converge" — operationalized as the
residual set dropping below ``min_frac`` of the data or ``max_pairs``.

Runtime is cascaded: the first classifier that accepts wins; inputs rejected
by every classifier go to the CPU.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # avoid circular import (apps imports core.mlp)
    from repro.apps.registry import App
from repro.core import quality
from repro.core.mlp import balanced_weights, init_mlp, mlp_logits, train_mlp


@dataclasses.dataclass
class MCCA:
    app: "App"
    pairs: list  # list of (a_params, c_params)

    def dispatch(self, x: jax.Array):
        """Returns (dispatched mask, chosen pair index; -1 = CPU)."""
        cspec = self.app.cls_spec(2)
        choice = jnp.full(x.shape[0], -1, jnp.int32)
        for i, (_, c) in enumerate(self.pairs):
            accept = jnp.argmax(mlp_logits(c, x, cspec), -1) == 1
            choice = jnp.where((choice < 0) & accept, i, choice)
        return choice >= 0, choice

    def evaluate(self, x: jax.Array, y: jax.Array) -> quality.Metrics:
        aspec = self.app.approx_spec
        errs = jnp.stack([quality.approx_errors(self.app, a, aspec, x, y)
                          for a, _ in self.pairs])        # (n_pairs, n)
        dispatched, choice = self.dispatch(x)
        err_chosen = errs[jnp.maximum(choice, 0), jnp.arange(x.shape[0])]
        return quality.confusion_metrics(self.app, dispatched, err_chosen,
                                         errs.min(0), len(self.pairs), choice)

    def classifiers_consulted(self, x: jax.Array) -> jax.Array:
        """Mean number of classifier inferences per input (MCCA's serial cost)."""
        _, choice = self.dispatch(x)
        n = len(self.pairs)
        return jnp.mean(jnp.where(choice >= 0, choice + 1, n).astype(jnp.float32))


def train_mcca(app: "App", key: jax.Array, x, y, *, max_pairs: int = 3,
               iters: int = 2, epochs: int = 1500, lr: float = 1e-2,
               min_frac: float = 0.05) -> MCCA:
    aspec, cspec = app.approx_spec, app.cls_spec(2)
    pairs = []
    residual = jnp.ones(x.shape[0], jnp.float32)
    for p in range(max_pairs):
        if float(jnp.mean(residual)) < min_frac:
            break  # cascade "cannot converge" on too little data
        kp, key = jax.random.split(key)
        ka, kc = jax.random.split(kp)
        a, c = init_mlp(ka, aspec), init_mlp(kc, cspec)
        w = residual
        for it in range(iters):
            a = train_mlp(a, x, y, aspec, weights=w, epochs=epochs, lr=lr)
            err = quality.approx_errors(app, a, aspec, x, y)
            labels = ((err <= app.error_bound) & (residual > 0)).astype(jnp.int32)
            c = train_mlp(c, x, labels, cspec, loss="xent",
                          weights=residual * balanced_weights(labels, 2),
                          epochs=epochs, lr=lr)
            accept = jnp.argmax(mlp_logits(c, x, cspec), -1) == 1
            # category "C" selection (paper: clusters, easier to separate)
            w = (accept.astype(jnp.float32)) * residual
            w = jnp.where(jnp.sum(w) < 8, residual, w)
        pairs.append((a, c))
        accept = jnp.argmax(mlp_logits(c, x, cspec), -1) == 1
        residual = residual * (~accept).astype(jnp.float32)
    return MCCA(app, pairs)
