"""Small MLP substrate used by approximators and classifiers.

The paper trains multilayer perceptrons with backpropagation + RMSprop for
1500 epochs.  Topologies come from Fig. 6 (e.g. ``6->8->1`` for the
Black-Scholes approximator).  Everything here is pure-functional JAX:
``init`` returns a parameter pytree, ``apply`` maps ``(params, x) -> y``.

Training whole runs are a single ``jax.lax.scan`` over epochs so a 1500-epoch
paper-faithful run costs one XLA dispatch.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

Params = list  # list of {"w": (in, out), "b": (out,)}


@dataclasses.dataclass(frozen=True)
class MLPSpec:
    """Topology spec: ``sizes=(6, 8, 1)`` means 6->8->1."""

    sizes: tuple
    # The NPU's activation unit is sigmoid-family; we default to tanh (a
    # rescaled sigmoid) which trains markedly better on normalized inputs.
    hidden_act: str = "tanh"
    out_act: str = "linear"      # regression output by default

    @staticmethod
    def parse(topo: str, **kw) -> "MLPSpec":
        """Parse a paper-style topology string like ``"6->8->1"``."""
        sizes = tuple(int(t) for t in topo.replace(" ", "").split("->"))
        return MLPSpec(sizes=sizes, **kw)

    @property
    def n_layers(self) -> int:
        return len(self.sizes) - 1

    @property
    def n_macs(self) -> int:
        """Multiply-accumulates per forward pass (used by the NPU cost model)."""
        return int(sum(a * b for a, b in zip(self.sizes[:-1], self.sizes[1:])))

    @property
    def n_params(self) -> int:
        return int(sum(a * b + b for a, b in zip(self.sizes[:-1], self.sizes[1:])))


_ACTS: dict = {
    "sigmoid": jax.nn.sigmoid,
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
    "linear": lambda x: x,
    "gelu": jax.nn.gelu,
}


def init_mlp(key: jax.Array, spec: MLPSpec, dtype=jnp.float32, scale: float | None = None) -> Params:
    """Glorot-uniform init; ``scale`` overrides the per-layer fan-based scale
    (used by competitive co-training to diversify local minima)."""
    params = []
    keys = jax.random.split(key, spec.n_layers)
    for k, (fan_in, fan_out) in zip(keys, zip(spec.sizes[:-1], spec.sizes[1:])):
        s = scale if scale is not None else (6.0 / (fan_in + fan_out)) ** 0.5
        w = jax.random.uniform(k, (fan_in, fan_out), dtype, -s, s)
        params.append({"w": w, "b": jnp.zeros((fan_out,), dtype)})
    return params


def apply_mlp(params: Params, x: jax.Array, spec: MLPSpec) -> jax.Array:
    """Forward pass. ``x``: (..., in_features) -> (..., out_features)."""
    h = x
    hidden = _ACTS[spec.hidden_act]
    for i, layer in enumerate(params):
        h = h @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            h = hidden(h)
    return _ACTS[spec.out_act](h)


def mlp_logits(params: Params, x: jax.Array, spec: MLPSpec) -> jax.Array:
    """Forward pass returning pre-output-activation logits (for classifiers)."""
    h = x
    hidden = _ACTS[spec.hidden_act]
    for i, layer in enumerate(params):
        h = h @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            h = hidden(h)
    return h


# ---------------------------------------------------------------------------
# RMSprop training (paper setup), full-run scan.
# ---------------------------------------------------------------------------

def _rmsprop_update(params, grads, ms, lr, decay=0.9, eps=1e-8):
    new_ms = jax.tree.map(lambda m, g: decay * m + (1 - decay) * g * g, ms, grads)
    new_p = jax.tree.map(lambda p, g, m: p - lr * g / (jnp.sqrt(m) + eps), params, grads, new_ms)
    return new_p, new_ms


def mse_loss(params, x, y, spec, weights=None):
    pred = apply_mlp(params, x, spec)
    err = jnp.sum((pred - y) ** 2, axis=-1)
    if weights is None:
        return jnp.mean(err)
    # Weighted mean: lets callers mask out samples outside a territory while
    # keeping shapes static (crucial for jit).
    return jnp.sum(err * weights) / jnp.maximum(jnp.sum(weights), 1.0)


def xent_loss(params, x, labels, spec, weights=None):
    logits = mlp_logits(params, x, spec)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if weights is None:
        return jnp.mean(nll)
    return jnp.sum(nll * weights) / jnp.maximum(jnp.sum(weights), 1.0)


def balanced_weights(labels: jax.Array, n_classes: int) -> jax.Array:
    """Inverse-frequency sample weights (mean 1) so minority classes train."""
    counts = jnp.bincount(labels, length=n_classes).astype(jnp.float32)
    w = 1.0 / jnp.maximum(counts, 1.0)
    w = w / jnp.sum(w * counts) * labels.shape[0]
    return w[labels]


@partial(jax.jit, static_argnames=("spec", "loss", "epochs", "lr"))
def train_mlp(params: Params, x: jax.Array, y: jax.Array, spec: MLPSpec, *,
              weights: jax.Array | None = None, loss: str = "mse",
              epochs: int = 1500, lr: float = 1e-2) -> Params:
    """Full-batch RMSprop for ``epochs`` steps (paper: RMSprop, epoch=1500).

    ``weights`` is an optional per-sample mask/weight vector; masked-out
    samples contribute zero gradient, which is how territories are selected
    without dynamic shapes.
    """
    loss_fn = mse_loss if loss == "mse" else xent_loss
    ms = jax.tree.map(jnp.zeros_like, params)

    def step(carry, _):
        p, m = carry
        g = jax.grad(loss_fn)(p, x, y, spec, weights)
        p, m = _rmsprop_update(p, g, m, lr)
        return (p, m), None

    (params, _), _ = jax.lax.scan(step, (params, ms), None, length=epochs)
    return params
