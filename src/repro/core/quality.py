"""Quality control: per-sample approximation error, safe-to-approximate
labels, and the invocation/error/confusion metrics of Fig. 7 and Fig. 11.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # avoid circular import (apps imports core.mlp)
    from repro.apps.registry import App
from repro.core.mlp import MLPSpec, Params, apply_mlp


def per_sample_error(app: "App", y_pred: jax.Array, y_true: jax.Array) -> jax.Array:
    """Per-sample error, comparable against ``app.error_bound``.

    * ``rmse_rel``: per-sample RMSE over output dims, normalized by the
      GLOBAL output RMS of the batch.  A per-sample denominator would make
      near-zero outputs unapproximable by definition; the paper's benchmarks
      (Fig. 10b "relative error") are scale-relative, not pointwise-relative.
    * ``class``: 0/1 misclassification (jmeint).
    """
    if app.err_kind == "class":
        return (jnp.argmax(y_pred, -1) != jnp.argmax(y_true, -1)).astype(jnp.float32)
    se = jnp.mean((y_pred - y_true) ** 2, axis=-1)
    denom = jnp.sqrt(jnp.mean(y_true ** 2))
    return jnp.sqrt(se) / jnp.maximum(denom, 1e-6)


def approx_errors(app: "App", params: Params, spec: MLPSpec, x, y) -> jax.Array:
    return per_sample_error(app, apply_mlp(params, x, spec), y)


@dataclasses.dataclass
class Metrics:
    """Runtime metrics for one method on one app (test set)."""

    invocation: float        # fraction of inputs dispatched to an approximator
    err_norm: float          # mean error of dispatched samples / error bound
    true_invocation: float   # AC fraction (dispatched AND truly safe)
    recall: float            # AC / (AC + AnC) — how much safe data we salvage
    false_neg: float         # AnC: safe data abandoned to the CPU
    false_pos: float         # nAC: unsafe data wrongly dispatched
    dispatch_frac: list      # per-approximator share of dispatched inputs

    def row(self) -> str:
        return (f"inv={self.invocation:.3f} err/bound={self.err_norm:.3f} "
                f"AC={self.true_invocation:.3f} recall={self.recall:.3f} "
                f"AnC={self.false_neg:.3f} nAC={self.false_pos:.3f}")


def confusion_metrics(app: "App", dispatched: jax.Array, err_dispatched: jax.Array,
                      err_best: jax.Array, n_approx: int,
                      choice: jax.Array | None = None) -> Metrics:
    """Build Metrics from runtime decisions.

    ``dispatched``: bool (n,) — classifier sent the input to an approximator.
    ``err_dispatched``: error of the *chosen* approximator per sample.
    ``err_best``: error of the best available approximator per sample (defines
    ground-truth "safe" = any approximator could have fit it).
    """
    bound = app.error_bound
    safe = err_best <= bound
    inv = jnp.mean(dispatched)
    ac = jnp.mean(dispatched & (err_dispatched <= bound))
    anc = jnp.mean(~dispatched & safe)
    nac = jnp.mean(dispatched & (err_dispatched > bound))
    denom = jnp.maximum(ac + anc, 1e-9)
    err_n = jnp.sum(jnp.where(dispatched, err_dispatched, 0.0)) / jnp.maximum(
        jnp.sum(dispatched), 1.0) / bound
    if choice is None:
        frac = [float(inv)]
    else:
        tot = jnp.maximum(jnp.sum(dispatched), 1.0)
        frac = [float(jnp.sum(dispatched & (choice == i)) / tot) for i in range(n_approx)]
    return Metrics(float(inv), float(err_n), float(ac), float(ac / denom),
                   float(anc), float(nac), frac)
