"""Deterministic, seekable, shardable synthetic data pipeline.

Every batch is a pure function of (seed, step) — ``batch_at(step)`` —
so restarts replay NO data and need NO pipeline checkpointing: after
restoring model state at step k, training resumes with batch_at(k) and the
run is bitwise identical to an uninterrupted one (asserted in
tests/test_checkpoint.py).  Per-host slicing for multi-host clusters takes
``host_id``/``n_hosts`` and generates only the local rows from the same
global key stream (no cross-host coordination).

The stream is a Zipf-ish token mixture with a Markov backbone — enough
statistical structure for a ~100M model's loss to drop visibly in a few
hundred steps (examples/train_lm_mcma.py), while remaining fully
synthetic/offline.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts

    def batch_at(self, step: int) -> dict:
        return batch_at(self, step)


def _markov_tokens(key, batch, seq_len, vocab):
    """Zipf marginals + first-order Markov structure (learnable bigrams)."""
    k1, k2, k3 = jax.random.split(key, 3)
    # Zipf-ish marginal via exponential transform of uniforms
    u = jax.random.uniform(k1, (batch, seq_len), minval=1e-6)
    ranks = jnp.floor(jnp.exp(u * jnp.log(float(vocab)))) - 1.0
    base = ranks.astype(jnp.int32) % vocab
    # Markov backbone: with p=0.5, token t+1 = f(token t) (a fixed affine
    # map over the vocab), else the Zipf draw — gives the model bigram
    # structure worth ~1 nat of loss to learn.
    follow = jax.random.bernoulli(k2, 0.5, (batch, seq_len))
    shift = jax.random.randint(k3, (), 1, 977)

    def step(prev, xs):
        tok, fol = xs
        nxt = jnp.where(fol, (prev * 31 + shift) % vocab, tok)
        return nxt, nxt
    _, toks = jax.lax.scan(step, base[:, 0], (base.T, follow.T))
    return toks.T


def batch_at(ds: SyntheticLM, step: int) -> dict:
    """{"inputs": (local_B, S) int32, "labels": (local_B, S) int32}."""
    key = jax.random.fold_in(jax.random.PRNGKey(ds.seed), step)
    key = jax.random.fold_in(key, ds.host_id)
    toks = _markov_tokens(key, ds.local_batch, ds.seq_len + 1, ds.vocab)
    return {"inputs": toks[:, :-1], "labels": toks[:, 1:]}
