from repro.data.pipeline import SyntheticLM, batch_at

__all__ = ["SyntheticLM", "batch_at"]
