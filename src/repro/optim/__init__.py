from repro.optim.optimizers import (adamw_init, adamw_update, clip_by_global_norm,
                                    cosine_schedule, rmsprop_init, rmsprop_update)

__all__ = ["adamw_init", "adamw_update", "rmsprop_init", "rmsprop_update",
           "clip_by_global_norm", "cosine_schedule"]
