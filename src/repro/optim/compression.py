"""int8 error-feedback gradient compression for the cross-pod axis.

The multi-pod mesh's "pod" axis carries ONLY the data-parallel gradient
all-reduce, over the slow data-center interconnect.  Ring all-reduce in
f32 moves ~2 x 4 bytes/param across DCI; with 2 pods, an int8
all-gather + local mean moves 1 byte/param gathered once — an 8x wire-byte
reduction measured in the dry-run (§Perf, collective-bound cell).

Scheme (error feedback a la 1-bit SGD / EF-SGD):
    e     <- residual carried from last step (f32, grad-shaped)
    g'    = g + e
    q     = round(g' / scale) clipped to int8, scale = max|g'| / 127
    e'    = g' - q * scale                      (new residual)
    g_out = mean over pods of dequantized q     (via all_gather on int8)

Implemented with shard_map over the "pod" axis only — inside the mapped
function every other axis is still visible to GSPMD, so the model's TP/DP
sharding is untouched.  Convergence: error feedback keeps the quantization
noise unbiased over steps; tests assert compressed-SGD reaches the
uncompressed loss on a quadratic within 1%.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _quantize(g: jax.Array):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_int8_allreduce_tree(grads, err, axis_name: str = "pod"):
    """Per-leaf int8 error-feedback mean over ``axis_name``.

    Must be called INSIDE a shard_map over ``axis_name``.  Returns
    (mean_grads, new_err) with the same pytree structure.
    """
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantize(g32)
        new_e = g32 - q.astype(jnp.float32) * scale
        # all_gather int8 (1 byte/param on the wire) + local mean
        qs = jax.lax.all_gather(q, axis_name)                  # (n_pods, ...)
        ss = jax.lax.all_gather(scale, axis_name)
        mean = jnp.mean(qs.astype(jnp.float32)
                        * ss.reshape((-1,) + (1,) * g.ndim), axis=0)
        return mean.astype(g.dtype), new_e
    out = jax.tree.map(one, grads, err)
    mean = jax.tree.map(lambda o: o[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda o: o[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return mean, new_err


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
