"""Optimizers (no optax in this environment): AdamW for the LM framework,
RMSprop for the paper-faithful pipeline, plus grad clipping and schedules.

Optimizer moments are f32 regardless of param dtype (bf16 training keeps
master statistics in f32; params themselves stay bf16 with f32 update
math — standard mixed-precision practice).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _f32_like(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params):
    return {"m": _f32_like(params), "v": _f32_like(params)}


def adamw_update(params, grads, opt, step, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mhat = m / bc1
        vhat = v / bc2
        step_ = mhat / (jnp.sqrt(vhat) + eps)
        if p.ndim >= 2:  # decay matrices only (norms/biases exempt)
            step_ = step_ + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, opt["m"], opt["v"])
    params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return params, {"m": m, "v": v}


# ---------------------------------------------------------------------------
# RMSprop (paper setup)
# ---------------------------------------------------------------------------

def rmsprop_init(params):
    return {"ms": _f32_like(params)}


def rmsprop_update(params, grads, opt, *, lr, decay=0.9, eps=1e-8):
    ms = jax.tree.map(lambda m, g: decay * m + (1 - decay)
                      * g.astype(jnp.float32) ** 2, opt["ms"], grads)
    params = jax.tree.map(
        lambda p, g, m: (p.astype(jnp.float32)
                         - lr * g.astype(jnp.float32) / (jnp.sqrt(m) + eps)
                         ).astype(p.dtype), params, grads, ms)
    return params, {"ms": ms}


# ---------------------------------------------------------------------------
# Utilities
# ---------------------------------------------------------------------------

def clip_by_global_norm(grads, max_norm: float):
    norm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def cosine_schedule(step, *, base_lr, warmup, total):
    step = step.astype(jnp.float32)
    warm = base_lr * step / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, cos)
