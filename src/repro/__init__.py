"""repro: invocation-driven neural approximate computing (MCMA, ICCAD'18)
as a production-grade multi-pod JAX framework."""
__version__ = "1.0.0"
