"""Trainer: the orchestration loop a real cluster job runs.

Responsibilities:
  * jit the train step with mesh shardings (or run unsharded on one device);
  * deterministic data via data.pipeline.batch_at(step) — restart replays
    nothing;
  * checkpoint every ``ckpt_every`` steps (atomic, keep-k) and AUTO-RESTORE
    the latest checkpoint at startup — a preempted/failed job needs no
    external coordination to resume;
  * fault injection hook (``fail_at``) to exercise the restart path in
    tests exactly as a preemption would;
  * straggler monitor: EWMA of step wall-time, flags outliers (on real
    clusters this feeds the controller that respawns slow hosts; here it
    is recorded in metrics).

Synchronous SPMD fault model (DESIGN.md §5): node loss = job restart from
the newest checkpoint; elasticity = checkpoints are mesh-agnostic so the
restarted job may use a different device count.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import checkpoint as ckpt_lib
from repro.configs.base import ModelConfig
from repro.data.pipeline import SyntheticLM
from repro.runtime import steps as steps_lib
from repro.sharding import rules as R


class PreemptionError(RuntimeError):
    """Injected fault (simulated SIGTERM mid-run)."""


@dataclasses.dataclass
class StragglerMonitor:
    alpha: float = 0.2
    threshold: float = 2.5
    ewma: float = 0.0
    slow_steps: int = 0

    def observe(self, dt: float) -> bool:
        if self.ewma == 0.0:
            self.ewma = dt
            return False
        slow = dt > self.threshold * self.ewma
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        self.slow_steps += slow
        return slow


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = ""
    keep_k: int = 3
    base_lr: float = 3e-4
    warmup: int = 20
    grad_accum: int = 1
    log_every: int = 10
    fail_at: int | None = None        # fault injection (tests)


class Trainer:
    def __init__(self, cfg: ModelConfig, tc: TrainerConfig, ds: SyntheticLM,
                 mesh=None, seed: int = 0):
        self.cfg, self.tc, self.ds, self.mesh = cfg, tc, ds, mesh
        self.monitor = StragglerMonitor()
        self.history: list[dict] = []
        step_fn = steps_lib.make_train_step(
            cfg, grad_accum=tc.grad_accum, base_lr=tc.base_lr,
            warmup=tc.warmup, total_steps=tc.total_steps)

        state = steps_lib.init_train_state(jax.random.PRNGKey(seed), cfg)
        if mesh is not None:
            specs, _ = R.state_pspecs(mesh, state)
            ns = jax.tree.map(lambda p: NamedSharding(mesh, p), specs,
                              is_leaf=lambda x: isinstance(x, P))
            self.state_shardings = ns
            state = jax.tree.map(lambda a, s: jax.device_put(a, s), state, ns)
            self.step_fn = jax.jit(step_fn, in_shardings=(ns, None),
                                   out_shardings=(ns, None),
                                   donate_argnums=(0,))
        else:
            self.state_shardings = None
            self.step_fn = jax.jit(step_fn, donate_argnums=(0,))
        self.state = state
        self.start_step = 0
        # ---- auto-restore ---------------------------------------------------
        if tc.ckpt_dir:
            restored, at = ckpt_lib.restore(tc.ckpt_dir,
                                            shardings=self.state_shardings)
            if restored is not None:
                if self.state_shardings is None:
                    restored = jax.tree.map(jax.numpy.asarray, restored)
                self.state = restored
                self.start_step = int(at)

    def run(self) -> dict:
        t_start = time.time()
        step = self.start_step
        while step < self.tc.total_steps:
            if self.tc.fail_at is not None and step == self.tc.fail_at:
                raise PreemptionError(f"injected preemption at step {step}")
            batch = self.ds.batch_at(step)
            t0 = time.time()
            self.state, metrics = self.step_fn(self.state, batch)
            loss = float(metrics["loss"])           # blocks; honest step time
            dt = time.time() - t0
            slow = self.monitor.observe(dt)
            step += 1
            rec = {"step": step, "loss": loss, "dt": dt, "slow": slow,
                   "grad_norm": float(metrics["grad_norm"])}
            self.history.append(rec)
            if step % self.tc.log_every == 0 or step == self.tc.total_steps:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"({dt:.2f}s{' SLOW' if slow else ''})", flush=True)
            if self.tc.ckpt_dir and (step % self.tc.ckpt_every == 0
                                     or step == self.tc.total_steps):
                ckpt_lib.save(self.tc.ckpt_dir, step, self.state,
                              keep_k=self.tc.keep_k)
        return {"steps": step - self.start_step,
                "final_loss": self.history[-1]["loss"] if self.history else None,
                "wall_s": time.time() - t_start,
                "slow_steps": self.monitor.slow_steps}
