"""Batched decode server loop (the inference-side driver).

Continuous batching over a fixed-size slot table (``batch`` concurrent
sequences): finished sequences (EOS or max_len) free their slot and queued
requests fill freed slots each tick, admitted by a cost model (prompt
length x QoS tier, with aging) rather than FIFO.

Prefill is CHUNKED (``prefill_chunk`` = S > 0): a slot consumes its prompt
S tokens per tick through a compiled (B, S) prefill-chunk step
(steps.make_prefill_chunk_step) that writes the same decode cache layout,
and the scheduler interleaves prefill chunks with decode ticks so in-flight
decodes keep streaming while new prompts load.  Only the FINAL prompt
token goes through the decode step — so the first sampled token, and every
decode-phase computation after it, is bit-identical to token-by-token
serving (``prefill_chunk=0``, the pre-chunking reference mode, still
available and used by the bench as the TTFT baseline).  Chunking needs the
uniform (dense-attention) family with a dense KV cache; SSM/hybrid/
sliding-window models fall back to token-by-token feeding automatically.

The ``max_len`` contract: positions are absolute, never recycled.
``submit()`` enforces ``len(prompt) + max_new <= max_len`` loudly (or
trims the prompt's HEAD under ``overflow="trim"``), and the tick loop
aborts — never clamp-writes — any slot whose prompt cannot fit.

The KV cache is dense ``(batch, max_len)`` by default, or PAGED when
``ServeOptions.kv_page_size > 0``: a fixed pool of ``kv_pages`` blocks
of ``kv_page_size`` tokens plus a per-slot block table (a TRACED leaf of
the cache pytree — page allocation changes never retrace).  The
host-side allocator here hands pages to slots lazily as their ``pos``
crosses page boundaries, and takes every page back the moment a request
finishes, aborts, or strands, so resident KV memory tracks tokens
actually HELD instead of batch x max_len worst case.  Admission then
reserves each request's worst-case page count up front (so in-flight
growth can never deadlock the pool) and the cost model prices pages
instead of raw prompt length.  Paged serving is bit-identical to the
dense oracle (docs/serving.md).

This is deliberately the same decode_step the dry-run lowers — the serving
path at scale IS the lowered cell, just driven by this loop.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.runtime import steps as steps_lib
from repro.runtime.options import LibrarySpec, ServeOptions


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (prompt_len,) int32
    max_new: int = 32
    # per-request QoS: the requested relative-error bound (validated and
    # quantized onto the server's tier table at submit time), or a tier
    # index directly.  None = the deployment's default tier.
    error_bound: float | None = None
    tier: int | None = None
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    # set True when the server gave up on the request instead of finishing
    # it: stranded at run_until_drained(max_ticks) exhaustion, or an
    # unservable prompt that bypassed submit() validation.  ``done`` stays
    # False for stranded requests — aborted is the explicit signal.
    aborted: bool = False
    # latency bookkeeping (bench_serve's raw TTFT signal).  arrival_* are
    # stamped at submit(); first_token_* at the tick that sampled the
    # request's first output token.  Ticks count BOTH phases, so a
    # prefill-heavy schedule shows up in TTFT-in-ticks directly.
    arrival_tick: int | None = None
    first_token_tick: int | None = None
    arrival_s: float | None = None
    first_token_s: float | None = None


@dataclasses.dataclass
class DrainStats:
    """Typed ``run_until_drained`` summary (was an ad-hoc dict).

    Optional fields stay ``None`` when their feature was off for the run
    (no MCMA dispatch -> no ``invocation_rate``; no QoS -> no
    ``per_tier``; ...).  The mapping protocol preserves every historic
    dict-style call site: ``stats["ticks"]``, ``"invocation_rate" in
    stats`` (``None`` counts as absent, exactly like the old dict's
    missing key), ``stats.get(...)``, and ``stats["anything"] = v``
    (unknown keys land in ``extras`` — bench_serve stamps
    ``replay_wall_s`` that way).  ``asdict()`` flattens to the old dict
    shape for CSV/JSON writers, skipping ``None`` fields.
    """

    ticks: int = 0
    wall_s: float = 0.0
    undrained_queued: int = 0
    undrained_inflight: int = 0
    prefill_ticks: int = 0
    prefill_tokens: int = 0
    invocation_rate: Optional[float] = None
    prefill_invocation_rate: Optional[float] = None
    dropped_rows: Optional[float] = None
    routed_per_class: Optional[list] = None
    dispatched_per_class: Optional[list] = None
    dropped_frac: Optional[float] = None
    served_invocation_rate: Optional[float] = None
    per_tier: Optional[list] = None
    autotune: Optional[dict] = None
    # approximator-library residency (LibrarySpec deployments only)
    lib_routed_per_class: Optional[list] = None   # (library_size + 1,)
    off_set_exact_rows: Optional[float] = None    # routed off-set, served exact
    residency: Optional[dict] = None              # ResidencyController.summary()
    # paged KV cache (kv_page_size > 0 deployments only)
    pages_in_use: Optional[int] = None            # pages held at drain end
    page_hwm: Optional[int] = None                # peak pages held
    alloc_failures: Optional[int] = None          # admission deferrals (pool
                                                  # pressure) + pool-exhaust
                                                  # aborts
    page_util: Optional[float] = None             # held tokens / (held pages
                                                  # x page_size), tick-meaned
    # peak resident KV bytes: dense reports its (constant) worst case, a
    # paged run reports page_hwm pages' worth — the bench's memory column
    kv_bytes_resident: Optional[int] = None
    extras: dict = dataclasses.field(default_factory=dict)

    def __getitem__(self, k):
        if k in self.extras:
            return self.extras[k]
        if k in _DRAIN_FIELDS:
            v = getattr(self, k)
            if v is not None:
                return v
        raise KeyError(k)

    def __setitem__(self, k, v):
        if k in _DRAIN_FIELDS and k != "extras":
            setattr(self, k, v)
        else:
            self.extras[k] = v

    def __contains__(self, k):
        return k in self.extras or (
            k in _DRAIN_FIELDS and getattr(self, k) is not None)

    def __iter__(self):
        # iterate present keys like the dict this replaced (without
        # this, the legacy __getitem__ iteration protocol probes s[0])
        return iter(self.asdict())

    def get(self, k, default=None):
        try:
            return self[k]
        except KeyError:
            return default

    def asdict(self) -> dict:
        d = {f: getattr(self, f) for f in _DRAIN_FIELDS
             if f != "extras" and getattr(self, f) is not None}
        d.update(self.extras)
        return d

    def keys(self):
        return self.asdict().keys()

    def items(self):
        return self.asdict().items()


_DRAIN_FIELDS = tuple(f.name for f in dataclasses.fields(DrainStats))

# the historic DecodeServer.__init__ keyword surface (PRs 1-6) — every
# name is also a ServeOptions field, so the shim is a dataclasses.replace
_LEGACY_SERVE_KWARGS = tuple(
    f.name for f in dataclasses.fields(ServeOptions) if f.name != "library")


class DecodeServer:
    def __init__(self, cfg: ModelConfig, params, *,
                 options: ServeOptions | None = None, **legacy):
        """``DecodeServer(cfg, params, options=ServeOptions(...))`` is the
        canonical constructor — ``ServeOptions`` (runtime/options.py) is
        the only way new serve-time state enters the server.

        The historic kwarg form (``DecodeServer(cfg, params, batch=8,
        use_mcma_dispatch=True, ...)``, PRs 1-6) still works: the kwargs
        fold into the ServeOptions via ``dataclasses.replace`` under ONE
        ``DeprecationWarning``, so legacy and options-style construction
        are bit-identical (tests/test_serve_options.py pins it)."""
        if legacy:
            unknown = sorted(set(legacy) - set(_LEGACY_SERVE_KWARGS))
            if unknown:
                raise TypeError(
                    f"DecodeServer: unknown kwargs {unknown} — serve-time "
                    "state enters via options=ServeOptions(...) "
                    f"(legacy kwargs: {sorted(_LEGACY_SERVE_KWARGS)})")
            warnings.warn(
                "DecodeServer(cfg, params, **kwargs) is deprecated — pass "
                "options=ServeOptions(...) (runtime/options.py); the "
                "kwargs were folded into one for you",
                DeprecationWarning, stacklevel=2)
            options = dataclasses.replace(options or ServeOptions(),
                                          **legacy)
        o = self.options = options if options is not None else ServeOptions()
        batch, max_len, eos = o.batch, o.max_len, o.eos
        greedy, seed = o.greedy, o.seed
        use_mcma_dispatch, mesh = o.use_mcma_dispatch, o.mesh
        autotune, drop_budget = o.autotune, o.drop_budget
        autotune_kwargs, route_scope = o.autotune_kwargs, o.route_scope
        qos_tiers, qos_app = o.qos_tiers, o.qos_app
        qos_margin_scale = o.qos_margin_scale
        prefill_chunk, admission = o.prefill_chunk, o.admission
        overflow, aging, backend = o.overflow, o.aging, o.backend
        self.cfg, self.params = cfg, params
        self.batch, self.max_len, self.eos = batch, max_len, eos
        # qos_tiers: per-request error-bound tiers.  True -> the default
        # (tight, trained, loose) table bracketing the config's (or the
        # registry app's) error bound; a tuple of ascending bounds -> that
        # table.  Each tier maps to an exact-logit router margin
        # (autotune.margins_from_bounds) that is a TRACED input of the one
        # compiled decode step — mixing tiers in a batch, or recalibrating
        # margins, never retraces.  ``qos_app`` names an apps/registry.py
        # app whose quality.py bound anchors the table and the submit-time
        # validation.
        self.tier_bounds = None
        self.qos_app = None
        if qos_app is not None:
            from repro.apps.registry import get_app
            self.qos_app = get_app(qos_app)
            if qos_tiers is None:
                qos_tiers = True
        if qos_tiers:
            from repro.runtime import autotune as at
            assert use_mcma_dispatch, \
                "per-request QoS tiers route through the dispatch engine; " \
                "needs use_mcma_dispatch"
            base = self.qos_app.error_bound if self.qos_app is not None \
                else cfg.approx.error_bound
            if qos_tiers is True:
                qos_tiers = cfg.approx.tier_bounds \
                    or at.default_tier_bounds(base)
            self.tier_bounds = tuple(sorted(float(b) for b in qos_tiers))
            assert self.tier_bounds[0] > 0, self.tier_bounds
            self.tier_margins = np.asarray(
                at.margins_from_bounds(self.tier_bounds, base,
                                       scale=qos_margin_scale), np.float32)
            # requests without a bound serve at the tier closest to the
            # bound the router was trained at
            self.default_tier = int(np.argmin(
                [abs(b - base) for b in self.tier_bounds]))
            cfg = dataclasses.replace(cfg, approx=dataclasses.replace(
                cfg.approx, n_tiers=len(self.tier_bounds),
                tier_bounds=self.tier_bounds,
                tier_margins=tuple(float(m) for m in self.tier_margins)))
            self.cfg = cfg
        # library: approximator-library residency (runtime/options.
        # LibrarySpec).  The checkpoint's full library stays in params
        # (stacks sized by cfg.approx.n_live); the SERVING n_approx
        # becomes the spec's resident-slot count, so capacities, the
        # dispatch plan, and the autotune ladder are all per-slot.  Each
        # tick feeds the current residency vector — a TRACED input — into
        # the compiled step (kernels/ops.gather_resident_stacks picks the
        # resident rows), and the ResidencyController promotes/demotes
        # library classes from the served lib_counts EMA: a swap is a new
        # vector through the same compiled program, zero retraces.
        self.library = o.library
        self.residency_controller = None
        self.residency = None
        if self.library is not None:
            from repro.runtime import autotune as at
            spec = self.library
            assert use_mcma_dispatch, \
                "library residency routes through the dispatch engine; " \
                "needs use_mcma_dispatch"
            assert cfg.approx.n_live == spec.library_size, (
                f"LibrarySpec.library_size={spec.library_size} must equal "
                f"the checkpoint's trained approximator count "
                f"(cfg.approx.n_live={cfg.approx.n_live})")
            assert not cfg.approx.invoke_fracs \
                or len(cfg.approx.invoke_fracs) == spec.n_resident, (
                    "per-class invoke_fracs are per resident SLOT "
                    f"(need {spec.n_resident}, got "
                    f"{len(cfg.approx.invoke_fracs)})")
            cfg = dataclasses.replace(cfg, approx=dataclasses.replace(
                cfg.approx, n_approx=spec.n_resident,
                library_size=spec.library_size))
            self.cfg = cfg
            self.residency_controller = at.ResidencyController(spec)
            self.residency = np.asarray(spec.initial_residency(), np.int32)
        # route_scope: "tick" routes once per decode tick (one DispatchPlan
        # from the tick-router head, reused by every layer of the scan) —
        # the per-tick metrics the server (and the autotune controller)
        # observe are then the single tick-level dispatch decision rather
        # than a mean of L per-layer ones.  None honors the config.
        self.route_scope = route_scope
        # use_mcma_dispatch: decode ticks run the ApproxFFN through the
        # MCMA Pallas weight-switch engine (runtime/dispatch.py) and the
        # server accumulates the invocation rate, weighting each tick by
        # its active-slot count.  Every tick passes the active-slot mask
        # into the decode step, so free slots (fed token 0) are excluded
        # from the router, the capacity dispatch, and every invoke stat —
        # the rates are exact even on a mostly-idle slot table.
        # ``backend`` overrides the dispatch engine ("pallas" default,
        # "pallas_fused" = the gather/scatter-fused kernel, "xla" = the
        # oracle the benches gate both kernels against).
        self.use_mcma_dispatch = use_mcma_dispatch
        self.backend = backend
        # mesh: distributed deployment.  Params/cache are sharded by the
        # declarative rules (sharding/rules.py) and every decode step is
        # traced under steps.serve_mesh_context, so the serve-mode FFNs run
        # their shard_map-native dispatch (the MCMA engine per data shard,
        # invoke_stats psum-reduced to global totals).  The mesh's
        # data-axis size must divide ``batch`` for the manual path to
        # engage.
        self.mesh = mesh
        # prefill_chunk: S > 0 turns on chunked prefill — prompts load S
        # tokens per prefill tick through the (B, S) chunk step, leaving
        # the final prompt token for the decode step (bit-exact first
        # sample).  0 = token-by-token reference mode.  Non-uniform
        # families (SSM recurrences, sliding-window ring buffers) cannot
        # address the cache positionally and silently fall back.
        topo = M.topology(cfg)
        self.chunkable = topo.kind == "uniform" and not cfg.sliding_window
        self.prefill_chunk = int(prefill_chunk) if self.chunkable else 0
        assert self.prefill_chunk >= 0, prefill_chunk
        # admission: "cost" (default) admits the cheapest queued request
        # into each freed slot — cost = prompt_len x tier multiplier -
        # aging x queue-age-in-ticks.  Longer prompts and TIGHTER tiers
        # cost more (a tight tier consumes more exact-FFN capacity per
        # token), so short/loose work is not stuck behind a long premium
        # prompt; the aging term guarantees no starvation (any request's
        # cost eventually goes negative).  Ties break FIFO.  "fifo" keeps
        # strict arrival order.
        if admission not in ("cost", "fifo"):
            raise ValueError(f"unknown admission policy: {admission!r} "
                             "(expected 'cost' or 'fifo')")
        self.admission = admission
        self.aging = float(aging)
        # overflow: submit()-time policy for prompts that cannot fit the
        # (batch, max_len) cache next to their max_new budget.  "reject"
        # (default) raises; "trim" keeps the LAST max_len - max_new prompt
        # tokens (the recency-biased truncation every fixed-window server
        # ends up with) and serves the request.
        if overflow not in ("reject", "trim"):
            raise ValueError(f"unknown overflow policy: {overflow!r} "
                             "(expected 'reject' or 'trim')")
        self.overflow = overflow
        # paged KV cache (kv_page_size > 0): k/v become per-layer pools of
        # kv_pages blocks and this host-side allocator owns the per-slot
        # block table.  Pages are acquired lazily as a slot's pos crosses
        # a page boundary and released the moment its request finishes,
        # aborts, or strands; admission reserves each request's worst-case
        # ceil((prompt + max_new) / page_size) pages up front, so the lazy
        # growth below can never run the pool dry mid-flight.
        # kv_page_size=0 keeps the dense layout — the bit-exact oracle
        # every paged deployment is pinned against.
        self.page_size = int(o.kv_page_size)
        self.n_pages = 0
        if self.page_size:
            assert self.chunkable, (
                "paged KV caches need the uniform dense-attention family "
                f"(got family={cfg.family!r}, "
                f"sliding_window={cfg.sliding_window})")
            assert max_len % self.page_size == 0, (
                f"kv_page_size={self.page_size} must divide "
                f"max_len={max_len} — the gathered page view must keep "
                "the dense reduction shape for bit-exactness")
            self.pages_per_slot = max_len // self.page_size
            self.n_pages = int(o.kv_pages) or batch * self.pages_per_slot
            assert self.n_pages >= 1, o.kv_pages
            self._free_pages = list(range(self.n_pages))
            self._slot_pages: list[list[int]] = [[] for _ in range(batch)]
            self._bt = np.full((batch, self.pages_per_slot), -1, np.int32)
            self._reserved = [0] * batch      # worst-case pages per slot
            self._reserved_total = 0
            self._pos_host = np.zeros((batch,), np.int64)
            self._held_token_ticks = 0        # sum over ticks of held tokens
            self._held_page_ticks = 0         # sum over ticks of held pages
        self.pages_in_use = 0
        self.page_hwm = 0
        self.alloc_failures = 0
        # autotune: online capacity adaptation (runtime/autotune.py).
        # True -> the default ladder around cfg's static operating point;
        # a sequence of OperatingPoints -> that ladder.  One decode step
        # per rung is compiled lazily on first use; the controller picks
        # the rung per tick from the served global invoke_stats, targeting
        # ``drop_budget`` dropped-row fraction at minimum capacity.
        self.controller = None
        if autotune:
            from repro.runtime import autotune as at
            assert use_mcma_dispatch, \
                "autotune consumes invoke_stats; needs use_mcma_dispatch"
            ladder = at.default_ladder(cfg) if autotune is True \
                else tuple(autotune)
            shards = self._dp_shards()
            assert batch % shards == 0, (batch, shards)
            n = cfg.approx.n_approx
            caps_fn = lambda pt: at.point_caps(pt, batch // shards, n,
                                               n_shards=shards)
            # cold-start at the configured static operating point when the
            # ladder contains it (the controller then only MOVES once the
            # served stats justify it), else at the cheapest rung
            base = at.OperatingPoint(cfg.approx.exact_frac,
                                     cfg.approx.invoke_frac,
                                     cfg.approx.shard_slack)
            kw = dict(autotune_kwargs or {})
            if "start" not in kw and base in ladder:
                kw["start"] = ladder.index(base)
            self.controller = at.CapacityController(
                ladder, caps_fn, drop_budget=drop_budget, **kw)
        self._steps = {}             # ladder index -> jitted decode step
        self._chunk_steps = {}       # ladder index -> jitted chunk step
        self.decode = self._make_step(None)
        self.chunk = self._make_chunk_step(None) if self.prefill_chunk \
            else None
        self.invocation_sum = 0.0    # active-slot-weighted invocation sum
        self.active_sum = 0          # total active slots over all ticks
        self.dropped_sum = 0.0       # layer-mean dropped rows over ticks
        self.dispatched_sum = None   # (n+1,) layer-mean dispatched rows
        self.routed_sum = None       # (n+1,) layer-mean routed rows
        self.routed_history = []     # per-tick (n+1,) routed counts — the
                                     # ladder_from_counts signal; bounded
                                     # to the most recent window so a
                                     # long-lived server never grows
                                     # memory linearly in ticks
        self.routed_history_cap = 4096
        self.tier_routed_sum = None      # (n_tiers, n+1) per-tier routed
        self.tier_dispatched_sum = None  # (n_tiers, n+1) per-tier served
        self.lib_routed_sum = None       # (library_size+1,) full-library
                                         # routed demand (decode phase)
        self.off_set_sum = 0.0           # rows routed to off-set library
                                         # classes (served on the exact path)
        # prefill-phase dispatch stats accumulate SEPARATELY: the
        # invocation rate, the autotune controller, routed_history and the
        # QoS ledger are decode-phase signals (the paper's metric is the
        # per-invocation decode rate) — mixing prompt-chunk rows in would
        # shift all of them with load shape.
        self.prefill_invocation_sum = 0.0   # token-weighted, chunk ticks
        self.prefill_tokens = 0             # real prompt tokens chunked
        self.prefill_ticks = 0
        # bounded per-tick trace: (phase, tokens processed, invocation or
        # None) — the decode-phase stat-equality tests replay it
        self.tick_log: list[tuple] = []
        self.cache = M.init_cache(cfg, batch, max_len,
                                  page_size=self.page_size,
                                  kv_pages=self.n_pages)
        if mesh is not None:
            self.params = self._shard_params(params)
            self.cache = self._shard_cache(self.cache)
        self.slots: list[Request | None] = [None] * batch
        self.queue: list[Request] = []
        self.remaining_prompt: list[np.ndarray] = [np.zeros((0,), np.int32)] * batch
        self.key = jax.random.PRNGKey(seed)
        self.greedy = greedy
        self.ticks = 0
        self._fresh = None  # lazily-built pristine cache for slot resets
        self._phase_flip = False  # alternates prefill/decode when both ready
        self._submit_seq = 0      # FIFO tiebreak for cost admission

    def _dp_shards(self) -> int:
        if self.mesh is None:
            return 1
        from repro.sharding import rules as R
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        return int(np.prod([sizes[a] for a in R.dp_axes(self.mesh)]))

    def _make_step(self, point):
        return jax.jit(
            steps_lib.make_decode_step(
                self.cfg, use_mcma_dispatch=self.use_mcma_dispatch,
                with_stats=self.use_mcma_dispatch, operating_point=point,
                route_scope=self.route_scope, backend=self.backend),
            donate_argnums=(1,))

    def _make_chunk_step(self, point):
        return jax.jit(
            steps_lib.make_prefill_chunk_step(
                self.cfg, use_mcma_dispatch=self.use_mcma_dispatch,
                with_stats=self.use_mcma_dispatch, operating_point=point,
                route_scope=self.route_scope, backend=self.backend),
            donate_argnums=(1,))

    def _active_step(self):
        """The decode step for this tick: the controller's current ladder
        rung when autotuning (compiled lazily per rung, then cached — a
        switch is a dict lookup, never a retrace), else the static step."""
        if self.controller is None:
            return self.decode
        idx = self.controller.index
        if idx not in self._steps:
            self._steps[idx] = self._make_step(self.controller.ladder[idx])
        return self._steps[idx]

    def _active_chunk_step(self):
        """Chunk-step twin of _active_step: prefill runs at the SAME
        ladder rung as decode (one dispatch configuration per tick pair),
        but its stats never feed the controller."""
        if self.controller is None:
            return self.chunk
        idx = self.controller.index
        if idx not in self._chunk_steps:
            self._chunk_steps[idx] = self._make_chunk_step(
                self.controller.ladder[idx])
        return self._chunk_steps[idx]

    def _named_shardings(self, specs):
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.tree.map(lambda q: NamedSharding(self.mesh, q), specs,
                            is_leaf=lambda q: isinstance(q, P))

    def _shard_params(self, params):
        from repro.sharding import rules as R
        specs, _ = R.param_pspecs(self.mesh, params)
        return jax.device_put(params, self._named_shardings(specs))

    def _shard_cache(self, cache):
        from repro.sharding import rules as R
        return jax.device_put(cache,
                              self._named_shardings(R.cache_pspecs(self.mesh,
                                                                   cache)))

    def _decode(self, *args, **kw):
        with steps_lib.serve_mesh_context(self.mesh):
            return self._active_step()(*args, **kw)

    def _prefill(self, *args, **kw):
        with steps_lib.serve_mesh_context(self.mesh):
            return self._active_chunk_step()(*args, **kw)

    def _residency_kw(self) -> dict:
        """The traced residency vector for this tick's step call (empty
        for non-library deployments — the steps' default ``None``)."""
        if self.residency is None:
            return {}
        return {"residency": jnp.asarray(self.residency)}

    def submit(self, req: Request):
        """Queue a request; per-request limits and QoS are validated HERE,
        loudly — nothing that reaches the tick loop can clamp a cache
        write or wedge a slot.

        Length contract: the prompt must be non-empty and
        ``len(prompt) + max_new <= max_len`` must hold (every prompt token
        and every sampled token occupies one cache position; positions are
        never recycled).  Overlong prompts raise under the default
        ``overflow="reject"`` policy; ``overflow="trim"`` keeps the LAST
        ``max_len - max_new`` prompt tokens instead.

        ``req.error_bound`` is checked against the deployment's tier table
        (anchored on the registry app's quality.py bound when ``qos_app``
        was given): a bound tighter than the tightest tier cannot be
        honored and raises, as does a non-positive/non-finite one; a valid
        bound quantizes onto the largest tier bound <= the request (served
        at-or-tighter than asked, never looser).  ``req.tier`` selects a
        tier index directly and must be in range."""
        req.prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        if req.prompt.size == 0:
            raise ValueError(f"request {req.rid}: empty prompt — a request "
                             "must carry at least one prompt token")
        if req.max_new < 1:
            raise ValueError(f"request {req.rid}: max_new {req.max_new} "
                             "must be >= 1")
        budget = self.max_len - int(req.max_new)
        if req.prompt.size > budget:
            if self.overflow == "reject":
                raise ValueError(
                    f"request {req.rid}: prompt ({req.prompt.size} tokens) "
                    f"+ max_new ({req.max_new}) exceeds max_len "
                    f"({self.max_len}) — the cache is a dense "
                    f"(batch, max_len) table with no position recycling; "
                    f"shorten the prompt/max_new or serve with "
                    f"overflow='trim'")
            if budget < 1:
                raise ValueError(
                    f"request {req.rid}: max_new ({req.max_new}) leaves no "
                    f"room for any prompt token within max_len "
                    f"({self.max_len}) — cannot trim")
            req.prompt = req.prompt[-budget:]   # trim policy: keep the tail
        if self.page_size:
            need = self._pages_needed(req.prompt.size + int(req.max_new))
            if need > self.n_pages:
                raise ValueError(
                    f"request {req.rid}: prompt ({req.prompt.size} tokens) "
                    f"+ max_new ({req.max_new}) needs {need} KV pages but "
                    f"the pool holds only {self.n_pages} "
                    f"(kv_page_size={self.page_size}) — the request could "
                    "never be scheduled; raise kv_pages or shorten it")
        if (req.error_bound is not None or req.tier is not None) \
                and self.tier_bounds is None:
            raise ValueError(
                f"request {req.rid} carries a QoS error_bound/tier but this "
                "server has no tier table — construct DecodeServer("
                "qos_tiers=...) (or qos_app=...) to serve per-request "
                "quality")
        if req.error_bound is not None:
            eb = float(req.error_bound)
            lo = self.tier_bounds[0]
            app = f" (app '{self.qos_app.name}' registry quality bound " \
                  f"{self.qos_app.error_bound})" if self.qos_app else ""
            if not np.isfinite(eb) or eb <= 0.0:
                raise ValueError(f"request {req.rid}: error_bound {eb!r} "
                                 f"is not a positive finite relative "
                                 f"error{app}")
            if eb < lo - 1e-12:
                raise ValueError(
                    f"request {req.rid}: error_bound {eb} is tighter than "
                    f"the tightest served tier {lo} — out of range for "
                    f"tiers {self.tier_bounds}{app}")
            # largest tier bound <= the request: at-or-tighter than asked
            # (a bound looser than every tier clamps to the loosest)
            req.tier = max(i for i, b in enumerate(self.tier_bounds)
                           if b <= eb + 1e-12)
        elif req.tier is not None:
            if not 0 <= int(req.tier) < len(self.tier_bounds):
                raise ValueError(
                    f"request {req.rid}: tier {req.tier} out of range for "
                    f"{len(self.tier_bounds)} tiers {self.tier_bounds}")
            req.tier = int(req.tier)
        req.arrival_tick = self.ticks
        req.arrival_s = time.time()
        req._seq = self._submit_seq          # FIFO tiebreak under "cost"
        self._submit_seq += 1
        self.queue.append(req)

    def _admission_cost(self, req: Request) -> float:
        """Cost-model admission key: the request's appetite for the
        resource that actually constrains the server, scaled by the
        tier's capacity appetite (tight tiers route more rows to the
        exact FFN, so a tight-tier token is more expensive to serve),
        minus an aging credit so queue time eventually dominates any
        length/tier gap.  Dense caches price prompt length; paged caches
        price the worst-case PAGE count (what admission reserves and what
        the pool runs out of)."""
        mult = 1.0
        if self.tier_bounds is not None and len(self.tier_bounds) > 1:
            tier = req.tier if req.tier is not None else self.default_tier
            n = len(self.tier_bounds)
            mult = 1.0 + 0.5 * (n - 1 - tier) / (n - 1)   # tightest x1.5
        age = self.ticks - (req.arrival_tick or 0)
        work = float(self._pages_needed(req.prompt.size + int(req.max_new))) \
            if self.page_size else float(len(req.prompt))
        return work * mult - self.aging * age

    def _pages_needed(self, tokens: int) -> int:
        """Worst-case page count for ``tokens`` cache positions."""
        return (int(tokens) + self.page_size - 1) // self.page_size

    def _ensure_slot_pages(self, i: int, tokens: int):
        """Grow slot ``i``'s block table to cover ``tokens`` positions,
        taking pages from the free pool (lazy acquisition — a slot only
        holds pages for tokens it has actually written or is about to
        write this tick).  Admission reserved the worst case up front, so
        the pool can never actually run dry here; if it does a scheduling
        invariant broke and we fail LOUDLY rather than drop a live
        token's write."""
        need = self._pages_needed(tokens)
        held = self._slot_pages[i]
        while len(held) < need:
            if not self._free_pages:
                self.alloc_failures += 1
                raise RuntimeError(
                    f"KV page pool exhausted growing slot {i} to {tokens} "
                    f"tokens (needs {need} pages; {self.pages_in_use}/"
                    f"{self.n_pages} in use) — admission reservations "
                    "should make this unreachable")
            pg = self._free_pages.pop()
            self._bt[i, len(held)] = pg
            held.append(pg)
            self.pages_in_use += 1
            self.page_hwm = max(self.page_hwm, self.pages_in_use)

    def _release_slot(self, i: int):
        """Return slot ``i``'s pages to the pool and drop its reservation
        — called the moment a request finishes, aborts, or strands
        (free-on-abort: a long-lived server's pool must never leak)."""
        if not self.page_size:
            return
        self._free_pages.extend(self._slot_pages[i])
        self.pages_in_use -= len(self._slot_pages[i])
        self._slot_pages[i] = []
        self._bt[i, :] = -1
        self._reserved_total -= self._reserved[i]
        self._reserved[i] = 0
        self._pos_host[i] = 0

    def _sync_block_table(self):
        """Refresh the cache's TRACED block-table leaf from the host
        allocator's mirror.  Same shape/dtype every tick, so allocation
        changes flow through the compiled steps as data — zero
        retraces."""
        if self.page_size:
            self.cache = dict(self.cache,
                              block_table=jnp.asarray(self._bt))

    def _admit(self):
        for i in range(self.batch):
            while self.slots[i] is None and self.queue:
                if self.admission == "cost":
                    j = min(range(len(self.queue)),
                            key=lambda j: (self._admission_cost(self.queue[j]),
                                           getattr(self.queue[j], "_seq", j)))
                else:
                    j = 0
                req = self.queue[j]
                need = 0
                if self.page_size:
                    need = self._pages_needed(
                        req.prompt.size + int(req.max_new))
                    if need > self.n_pages:
                        # can NEVER fit the pool (injected past submit()
                        # validation): abort instead of wedging the head
                        # of the queue forever
                        self.queue.pop(j)
                        req.aborted = True
                        req.done = True
                        continue            # retry this slot
                    if self._reserved_total + need > self.n_pages:
                        # worst-case reservation doesn't fit right now —
                        # head-of-line block (skipping ahead to a cheaper
                        # request would starve this one under sustained
                        # load); pages free as in-flight requests finish
                        self.alloc_failures += 1
                        return
                self.queue.pop(j)
                self.slots[i] = req
                self.remaining_prompt[i] = np.asarray(req.prompt, np.int32)
                if self.page_size:
                    self._reserved[i] = need
                    self._reserved_total += need
                if self._fresh is None:
                    self._fresh = M.init_cache(self.cfg, self.batch,
                                               self.max_len,
                                               page_size=self.page_size,
                                               kv_pages=self.n_pages)
                    if self.mesh is not None:
                        self._fresh = self._shard_cache(self._fresh)
                self.cache = M.reset_slot(self.cfg, self.cache, self._fresh, i)
                break

    def _abort_unservable(self):
        """Defensive wedge guard: abort (never clamp-write) any slot whose
        remaining prompt cannot fit the cache.  Unreachable through
        submit() validation — this catches requests injected straight into
        ``queue``/``slots`` (and any future scheduling bug) BEFORE a
        single out-of-range KV write happens."""
        pos = None
        for i, req in enumerate(self.slots):
            if req is None or not self.remaining_prompt[i].size:
                continue
            if pos is None:
                pos = np.asarray(self.cache["pos"])
            if int(pos[i]) + self.remaining_prompt[i].size > self.max_len:
                req.aborted = True
                req.done = True
                self.slots[i] = None
                self.remaining_prompt[i] = np.zeros((0,), np.int32)
                self._release_slot(i)       # free-on-abort: pages go back

    def _tiers_arr(self) -> np.ndarray:
        return np.asarray(
            [self.default_tier if s is None or s.tier is None
             else s.tier for s in self.slots], np.int32)

    def _prefill_rows(self) -> list[int]:
        """Slots mid-prompt with more than the final token left — the
        chunk step's work list (the last token always decodes)."""
        return [i for i, s in enumerate(self.slots)
                if s is not None and self.remaining_prompt[i].size > 1]

    def _prefill_tick(self, rows: list[int]):
        """One chunked-prefill tick: up to S prompt tokens per listed slot
        into the decode cache; no logits, no sampling.  Slots not listed
        have n_valid 0 — the chunk step writes nothing for them and their
        ``pos`` holds."""
        S = self.prefill_chunk
        toks = np.zeros((self.batch, S), np.int32)
        nv = np.zeros((self.batch,), np.int32)
        for i in rows:
            n = min(S, self.remaining_prompt[i].size - 1)
            toks[i, :n] = self.remaining_prompt[i][:n]
            self.remaining_prompt[i] = self.remaining_prompt[i][n:]
            nv[i] = n
        if self.page_size:
            for i in rows:
                self._ensure_slot_pages(i, int(self._pos_host[i]) + int(nv[i]))
            self._sync_block_table()
        args = [self.params, self.cache, jnp.asarray(toks), jnp.asarray(nv)]
        if self.use_mcma_dispatch and self.tier_bounds is not None:
            args += [None, jnp.asarray(self._tiers_arr()),
                     jnp.asarray(self.tier_margins)]
        self.cache, m = self._prefill(*args, **self._residency_kw())
        if self.page_size:
            for i in rows:
                self._pos_host[i] += int(nv[i])
        tokens = int(nv.sum())
        inv = None
        if self.use_mcma_dispatch and "invocation" in m:
            inv = float(m["invocation"])
            self.prefill_invocation_sum += inv * tokens
        self.prefill_tokens += tokens
        self.prefill_ticks += 1
        self._log_tick("prefill", tokens, inv)

    def _decode_tick(self, rows: list[int]):
        """One decode tick for the listed slots; every other slot is
        masked out (its ``pos`` holds — the row's dummy write is
        overwritten by its next real token)."""
        toks = np.zeros((self.batch, 1), np.int32)
        fed_prompt = [False] * self.batch
        active = [False] * self.batch
        for i in rows:
            req = self.slots[i]
            active[i] = True
            if self.remaining_prompt[i].size:       # prompt-feeding phase
                toks[i, 0] = self.remaining_prompt[i][0]
                self.remaining_prompt[i] = self.remaining_prompt[i][1:]
                fed_prompt[i] = True
            elif req.out:
                toks[i, 0] = req.out[-1]
            else:
                toks[i, 0] = req.prompt[-1]
        if self.page_size:
            # every listed row writes ONE token at its pos this tick —
            # make sure the page covering it is allocated (lazy
            # acquisition at the boundary crossing)
            for i in rows:
                self._ensure_slot_pages(i, int(self._pos_host[i]) + 1)
            self._sync_block_table()
        mask = jnp.asarray(active)
        if self.use_mcma_dispatch:
            # active-row mask: idle and mid-prefill slots are excluded
            # from the dispatch and its stats inside the step (the
            # free-slot bias fix), so every metric below is exact for the
            # decoding slots only
            if self.tier_bounds is not None:
                # per-slot QoS tier vector, riding next to the mask; the
                # margins vector is a traced input — one compiled step
                # serves every tier mix
                logits, self.cache, m = self._decode(
                    self.params, self.cache, jnp.asarray(toks), mask,
                    jnp.asarray(self._tiers_arr()),
                    jnp.asarray(self.tier_margins),
                    **self._residency_kw())
            else:
                logits, self.cache, m = self._decode(self.params, self.cache,
                                                     jnp.asarray(toks), mask,
                                                     **self._residency_kw())
            n_active = sum(active)
            inv = None
            if "invocation" in m:
                inv = float(m["invocation"])
                self.invocation_sum += inv * n_active
                self.active_sum += n_active
            if "dropped_rows" in m:
                self.dropped_sum += float(m["dropped_rows"])
                disp = np.asarray(m["dispatched"], float)
                routed = np.asarray(m["class_counts"], float)
                self.dispatched_sum = disp if self.dispatched_sum is None \
                    else self.dispatched_sum + disp
                self.routed_sum = routed if self.routed_sum is None \
                    else self.routed_sum + routed
                self.routed_history.append(routed)
                if len(self.routed_history) > self.routed_history_cap:
                    del self.routed_history[0]
                if "tier_counts" in m:
                    tc = np.asarray(m["tier_counts"], float)
                    td = np.asarray(m["tier_dispatched"], float)
                    self.tier_routed_sum = tc \
                        if self.tier_routed_sum is None \
                        else self.tier_routed_sum + tc
                    self.tier_dispatched_sum = td \
                        if self.tier_dispatched_sum is None \
                        else self.tier_dispatched_sum + td
                if self.controller is not None:
                    self.controller.observe(
                        {"class_counts": routed, "dropped": m["dropped_rows"]})
                if self.residency_controller is not None \
                        and "lib_counts" in m:
                    # full-library demand histogram: feed the residency
                    # controller and adopt whatever hot set it returns —
                    # the next tick's step call carries the new vector
                    # through the SAME compiled program (zero retraces)
                    lib = np.asarray(m["lib_counts"], float)
                    self.lib_routed_sum = lib \
                        if self.lib_routed_sum is None \
                        else self.lib_routed_sum + lib
                    self.off_set_sum += float(m["off_set_exact_rows"])
                    self.residency = np.asarray(
                        self.residency_controller.observe(
                            {"lib_counts": lib}), np.int32)
            self._log_tick("decode", n_active, inv)
        else:
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(toks), mask)
            self._log_tick("decode", sum(active), None)
        if self.greedy:
            nxt = np.asarray(jnp.argmax(logits, -1))
        else:
            self.key, k = jax.random.split(self.key)
            nxt = np.asarray(jax.random.categorical(k, logits))
        pos = np.asarray(self.cache["pos"])           # (B,) per-slot
        if self.page_size:
            for i in rows:
                self._pos_host[i] += 1
                # the mirror drives page acquisition — drift would leak
                # or clamp, so pin it against the device truth
                assert int(pos[i]) == int(self._pos_host[i]), \
                    (i, int(pos[i]), int(self._pos_host[i]))
        now = None
        for i in rows:
            req = self.slots[i]
            if fed_prompt[i] and self.remaining_prompt[i].size:
                continue                              # still consuming prompt
            req.out.append(int(nxt[i]))
            if req.first_token_tick is None:
                req.first_token_tick = self.ticks + 1   # tick about to close
                now = time.time() if now is None else now
                req.first_token_s = now
            if (self.eos is not None and req.out[-1] == self.eos) \
                    or len(req.out) >= req.max_new \
                    or int(pos[i]) >= self.max_len - 1:
                req.done = True
                self.slots[i] = None
                self._release_slot(i)   # finished: pages back to the pool

    def _log_tick(self, phase: str, tokens: int, invocation):
        self.tick_log.append((phase, tokens, invocation))
        if len(self.tick_log) > self.routed_history_cap:
            del self.tick_log[0]

    def tick(self):
        """One scheduler tick: admit, then run ONE compiled step — a
        prefill chunk or a decode step.  When both phases have work the
        scheduler alternates them, so queued prompts load S tokens per
        prefill tick while in-flight decodes keep streaming (neither
        phase can starve the other)."""
        self._admit()
        self._abort_unservable()
        if not any(s is not None for s in self.slots):
            return False
        prefill_rows = self._prefill_rows() if self.prefill_chunk else []
        decode_rows = [i for i, s in enumerate(self.slots)
                       if s is not None and i not in prefill_rows]
        if prefill_rows and (not decode_rows or not self._phase_flip):
            self._phase_flip = True
            self._prefill_tick(prefill_rows)
        else:
            self._phase_flip = False
            self._decode_tick(decode_rows)
        self.ticks += 1
        if self.page_size:
            # page_util's raw signal: tokens actually held vs the token
            # capacity of the pages holding them, sampled once per tick
            self._held_token_ticks += int(sum(
                self._pos_host[i] for i in range(self.batch)
                if self._slot_pages[i]))
            self._held_page_ticks += self.pages_in_use
        return True

    def run_until_drained(self, max_ticks: int = 10_000) -> DrainStats:
        """Tick until queue and slots are empty (or ``max_ticks``); returns
        a ``DrainStats`` — dict-style access preserved for old callers."""
        t0 = time.time()
        while (self.queue or any(s is not None for s in self.slots)) \
                and self.ticks < max_ticks:
            self.tick()
        stats = DrainStats(ticks=self.ticks, wall_s=time.time() - t0)
        # tick-budget exhaustion is NOT a quiet success: stranded requests
        # are marked aborted (done stays False) and counted here, so a
        # caller can never mistake a truncated drain for a finished one
        undrained_inflight = sum(s is not None for s in self.slots)
        for i, s in enumerate(self.slots):
            if s is not None:
                s.aborted = True
                # stranded slots release their KV state eagerly — with a
                # paged pool this would otherwise be a real leak (the
                # dense cache merely lingered until slot reuse)
                self.slots[i] = None
                self.remaining_prompt[i] = np.zeros((0,), np.int32)
                self._release_slot(i)
        for r in self.queue:
            r.aborted = True
        stats["undrained_queued"] = len(self.queue)
        stats["undrained_inflight"] = undrained_inflight
        stats["prefill_ticks"] = self.prefill_ticks
        stats["prefill_tokens"] = self.prefill_tokens
        if self.use_mcma_dispatch:
            stats["invocation_rate"] = \
                self.invocation_sum / max(self.active_sum, 1)
            if self.prefill_tokens:
                stats["prefill_invocation_rate"] = \
                    self.prefill_invocation_sum / self.prefill_tokens
            # the autotuner's objective, observable from server stats:
            # global dropped rows and per-class routed/dispatched counts
            # (layer-mean per tick, summed over ticks; mesh runs report
            # psum-reduced global totals).  Decode-phase only — prefill
            # chunks report into the prefill_* accumulators above.
            stats["dropped_rows"] = self.dropped_sum
            if self.routed_sum is not None:
                stats["routed_per_class"] = self.routed_sum.tolist()
                stats["dispatched_per_class"] = self.dispatched_sum.tolist()
                total = max(float(self.routed_sum.sum()), 1.0)
                stats["dropped_frac"] = self.dropped_sum / total
                # invocation actually SERVED (approx rows executed, not
                # just routed) — what capacity autotuning maximizes
                stats["served_invocation_rate"] = \
                    float(self.dispatched_sum[1:].sum()) / total
            if self.tier_bounds is not None \
                    and self.tier_routed_sum is not None:
                # the drain summary's QoS ledger: served invocation and
                # dropped fraction attributed to each error-bound tier
                per = []
                for k, bound in enumerate(self.tier_bounds):
                    routed_k = self.tier_routed_sum[k]
                    disp_k = self.tier_dispatched_sum[k]
                    rows = float(routed_k.sum())
                    per.append({
                        "tier": k,
                        "error_bound": float(bound),
                        "margin": float(self.tier_margins[k]),
                        "rows": rows,
                        "served_invocation_rate":
                            float(disp_k[1:].sum()) / max(rows, 1.0),
                        "routed_invocation_rate":
                            float(routed_k[1:].sum()) / max(rows, 1.0),
                        "dropped_rows": float((routed_k - disp_k).sum()),
                        "dropped_frac":
                            float((routed_k - disp_k).sum()) / max(rows, 1.0),
                    })
                stats["per_tier"] = per
            if self.lib_routed_sum is not None:
                # full-library routed demand vs what the resident set
                # could serve: off_set_exact_rows is the residency
                # opportunity cost (rows a bigger/better-tuned hot set
                # would have approximated)
                stats["lib_routed_per_class"] = self.lib_routed_sum.tolist()
                stats["off_set_exact_rows"] = self.off_set_sum
        if self.controller is not None:
            stats["autotune"] = self.controller.summary()
        if self.residency_controller is not None:
            stats["residency"] = self.residency_controller.summary()
        if self.page_size:
            stats["pages_in_use"] = self.pages_in_use
            stats["page_hwm"] = self.page_hwm
            stats["alloc_failures"] = self.alloc_failures
            stats["page_util"] = self._held_token_ticks / max(
                self._held_page_ticks * self.page_size, 1)
        stats["kv_bytes_resident"] = self._kv_bytes_resident()
        return stats

    def _kv_bytes_resident(self) -> int:
        """Peak resident KV-cache bytes.  Dense caches reserve their
        worst case permanently (batch x max_len whatever is held); a
        paged run pays only for the pages at its high-water mark — the
        bench's paged-vs-dense memory gate compares exactly this."""
        k = self.cache.get("k") if isinstance(self.cache, dict) else None
        if k is None:
            return 0                      # pure-SSM caches: no KV to page
        total = int(k.nbytes) * 2         # the k + v stacks
        if not self.page_size:
            return total
        assert total % self.n_pages == 0, (total, self.n_pages)
        return (total // self.n_pages) * self.page_hwm

    def derived_ladder(self, **kwargs):
        """runtime/autotune.ladder_from_counts over this server's served
        per-tick ``routed_per_class`` history: capacity rungs whose
        per-class budgets track the observed class-count quantiles — the
        asymmetric ladder to deploy for the NEXT run of this mix."""
        from repro.runtime import autotune as at
        assert self.routed_history, \
            "no served invoke stats yet (needs use_mcma_dispatch ticks)"
        return at.ladder_from_counts(
            np.asarray(self.routed_history), self.batch,
            tier_margins=tuple(float(m) for m in self.tier_margins)
            if self.tier_bounds is not None else (), **kwargs)
