"""Batched decode server loop (the inference-side driver).

Continuous-batching-lite: a fixed-size slot table (``batch`` concurrent
sequences); finished sequences (EOS or max_len) free their slot, queued
requests fill freed slots each tick; one jitted decode step advances every
active slot per tick.  Prefill for an incoming request runs through the
same decode step token-by-token when no prefill step is compiled (small
models), or via prefill_step when one is.

This is deliberately the same decode_step the dry-run lowers — the serving
path at scale IS the lowered cell, just driven by this loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.runtime import steps as steps_lib


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (prompt_len,) int32
    max_new: int = 32
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class DecodeServer:
    def __init__(self, cfg: ModelConfig, params, *, batch: int = 8,
                 max_len: int = 512, eos: int | None = None, greedy=True,
                 seed: int = 0, use_mcma_dispatch: bool = False,
                 mesh=None):
        self.cfg, self.params = cfg, params
        self.batch, self.max_len, self.eos = batch, max_len, eos
        # use_mcma_dispatch: decode ticks run the ApproxFFN through the
        # MCMA Pallas weight-switch engine (runtime/dispatch.py) and the
        # server accumulates the invocation rate, weighting each tick by
        # its active-slot count.  Caveat: the decode step classifies all
        # ``batch`` rows, so free slots (fed token 0) still enter the
        # router and can bias the rate on a mostly-idle slot table.
        self.use_mcma_dispatch = use_mcma_dispatch
        # mesh: distributed deployment.  Params/cache are sharded by the
        # declarative rules (sharding/rules.py) and every decode step is
        # traced under steps.serve_mesh_context, so the serve-mode FFNs run
        # their shard_map-native dispatch (the MCMA engine per data shard,
        # invoke_stats psum-reduced to global totals).  The mesh's
        # data-axis size must divide ``batch`` for the manual path to
        # engage.
        self.mesh = mesh
        self.decode = jax.jit(
            steps_lib.make_decode_step(cfg,
                                       use_mcma_dispatch=use_mcma_dispatch,
                                       with_stats=use_mcma_dispatch),
            donate_argnums=(1,))
        self.invocation_sum = 0.0    # active-slot-weighted invocation sum
        self.active_sum = 0          # total active slots over all ticks
        self.cache = M.init_cache(cfg, batch, max_len)
        if mesh is not None:
            self.params = self._shard_params(params)
            self.cache = self._shard_cache(self.cache)
        self.slots: list[Request | None] = [None] * batch
        self.queue: list[Request] = []
        self.remaining_prompt: list[np.ndarray] = [np.zeros((0,), np.int32)] * batch
        self.key = jax.random.PRNGKey(seed)
        self.greedy = greedy
        self.ticks = 0
        self._fresh = None  # lazily-built pristine cache for slot resets

    def _named_shardings(self, specs):
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.tree.map(lambda q: NamedSharding(self.mesh, q), specs,
                            is_leaf=lambda q: isinstance(q, P))

    def _shard_params(self, params):
        from repro.sharding import rules as R
        specs, _ = R.param_pspecs(self.mesh, params)
        return jax.device_put(params, self._named_shardings(specs))

    def _shard_cache(self, cache):
        from repro.sharding import rules as R
        return jax.device_put(cache,
                              self._named_shardings(R.cache_pspecs(self.mesh,
                                                                   cache)))

    def _decode(self, *args):
        with steps_lib.serve_mesh_context(self.mesh):
            return self.decode(*args)

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                self.remaining_prompt[i] = np.asarray(req.prompt, np.int32)
                if self._fresh is None:
                    self._fresh = M.init_cache(self.cfg, self.batch, self.max_len)
                    if self.mesh is not None:
                        self._fresh = self._shard_cache(self._fresh)
                self.cache = M.reset_slot(self.cfg, self.cache, self._fresh, i)

    def _gather_tokens(self) -> np.ndarray:
        toks = np.zeros((self.batch, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if self.remaining_prompt[i].size:       # prompt-feeding phase
                toks[i, 0] = self.remaining_prompt[i][0]
                self.remaining_prompt[i] = self.remaining_prompt[i][1:]
            elif req.out:
                toks[i, 0] = req.out[-1]
            else:
                toks[i, 0] = req.prompt[-1]
        return toks

    def tick(self):
        """One decode step for all active slots."""
        self._admit()
        if not any(s is not None for s in self.slots):
            return False
        toks = self._gather_tokens()
        if self.use_mcma_dispatch:
            logits, self.cache, m = self._decode(self.params, self.cache,
                                                 jnp.asarray(toks))
            if "invocation" in m:
                active = sum(s is not None for s in self.slots)
                self.invocation_sum += float(m["invocation"]) * active
                self.active_sum += active
        else:
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(toks))
        if self.greedy:
            nxt = np.asarray(jnp.argmax(logits, -1))
        else:
            self.key, k = jax.random.split(self.key)
            nxt = np.asarray(jax.random.categorical(k, logits))
        pos = np.asarray(self.cache["pos"])           # (B,) per-slot
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if self.remaining_prompt[i].size:
                continue                              # still consuming prompt
            req.out.append(int(nxt[i]))
            if (self.eos is not None and req.out[-1] == self.eos) \
                    or len(req.out) >= req.max_new \
                    or int(pos[i]) >= self.max_len - 1:
                req.done = True
                self.slots[i] = None
        self.ticks += 1
        return True

    def run_until_drained(self, max_ticks: int = 10_000):
        t0 = time.time()
        while (self.queue or any(s is not None for s in self.slots)) \
                and self.ticks < max_ticks:
            self.tick()
        stats = {"ticks": self.ticks, "wall_s": time.time() - t0}
        if self.use_mcma_dispatch:
            stats["invocation_rate"] = \
                self.invocation_sum / max(self.active_sum, 1)
        return stats
