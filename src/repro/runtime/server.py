"""Batched decode server loop (the inference-side driver).

Continuous-batching-lite: a fixed-size slot table (``batch`` concurrent
sequences); finished sequences (EOS or max_len) free their slot, queued
requests fill freed slots each tick; one jitted decode step advances every
active slot per tick.  Prefill for an incoming request runs through the
same decode step token-by-token when no prefill step is compiled (small
models), or via prefill_step when one is.

This is deliberately the same decode_step the dry-run lowers — the serving
path at scale IS the lowered cell, just driven by this loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.runtime import steps as steps_lib


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (prompt_len,) int32
    max_new: int = 32
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class DecodeServer:
    def __init__(self, cfg: ModelConfig, params, *, batch: int = 8,
                 max_len: int = 512, eos: int | None = None, greedy=True,
                 seed: int = 0, use_mcma_dispatch: bool = False,
                 mesh=None, autotune=None, drop_budget: float = 0.05,
                 autotune_kwargs: dict | None = None,
                 route_scope: str | None = None):
        self.cfg, self.params = cfg, params
        self.batch, self.max_len, self.eos = batch, max_len, eos
        # route_scope: "tick" routes once per decode tick (one DispatchPlan
        # from the tick-router head, reused by every layer of the scan) —
        # the per-tick metrics the server (and the autotune controller)
        # observe are then the single tick-level dispatch decision rather
        # than a mean of L per-layer ones.  None honors the config.
        self.route_scope = route_scope
        # use_mcma_dispatch: decode ticks run the ApproxFFN through the
        # MCMA Pallas weight-switch engine (runtime/dispatch.py) and the
        # server accumulates the invocation rate, weighting each tick by
        # its active-slot count.  Every tick passes the active-slot mask
        # into the decode step, so free slots (fed token 0) are excluded
        # from the router, the capacity dispatch, and every invoke stat —
        # the rates are exact even on a mostly-idle slot table.
        self.use_mcma_dispatch = use_mcma_dispatch
        # mesh: distributed deployment.  Params/cache are sharded by the
        # declarative rules (sharding/rules.py) and every decode step is
        # traced under steps.serve_mesh_context, so the serve-mode FFNs run
        # their shard_map-native dispatch (the MCMA engine per data shard,
        # invoke_stats psum-reduced to global totals).  The mesh's
        # data-axis size must divide ``batch`` for the manual path to
        # engage.
        self.mesh = mesh
        # autotune: online capacity adaptation (runtime/autotune.py).
        # True -> the default ladder around cfg's static operating point;
        # a sequence of OperatingPoints -> that ladder.  One decode step
        # per rung is compiled lazily on first use; the controller picks
        # the rung per tick from the served global invoke_stats, targeting
        # ``drop_budget`` dropped-row fraction at minimum capacity.
        self.controller = None
        if autotune:
            from repro.runtime import autotune as at
            assert use_mcma_dispatch, \
                "autotune consumes invoke_stats; needs use_mcma_dispatch"
            ladder = at.default_ladder(cfg) if autotune is True \
                else tuple(autotune)
            shards = self._dp_shards()
            assert batch % shards == 0, (batch, shards)
            n = cfg.approx.n_approx
            caps_fn = lambda pt: at.point_caps(pt, batch // shards, n,
                                               n_shards=shards)
            # cold-start at the configured static operating point when the
            # ladder contains it (the controller then only MOVES once the
            # served stats justify it), else at the cheapest rung
            base = at.OperatingPoint(cfg.approx.exact_frac,
                                     cfg.approx.invoke_frac,
                                     cfg.approx.shard_slack)
            kw = dict(autotune_kwargs or {})
            if "start" not in kw and base in ladder:
                kw["start"] = ladder.index(base)
            self.controller = at.CapacityController(
                ladder, caps_fn, drop_budget=drop_budget, **kw)
        self._steps = {}             # ladder index -> jitted decode step
        self.decode = self._make_step(None)
        self.invocation_sum = 0.0    # active-slot-weighted invocation sum
        self.active_sum = 0          # total active slots over all ticks
        self.dropped_sum = 0.0       # layer-mean dropped rows over ticks
        self.dispatched_sum = None   # (n+1,) layer-mean dispatched rows
        self.routed_sum = None       # (n+1,) layer-mean routed rows
        self.cache = M.init_cache(cfg, batch, max_len)
        if mesh is not None:
            self.params = self._shard_params(params)
            self.cache = self._shard_cache(self.cache)
        self.slots: list[Request | None] = [None] * batch
        self.queue: list[Request] = []
        self.remaining_prompt: list[np.ndarray] = [np.zeros((0,), np.int32)] * batch
        self.key = jax.random.PRNGKey(seed)
        self.greedy = greedy
        self.ticks = 0
        self._fresh = None  # lazily-built pristine cache for slot resets

    def _dp_shards(self) -> int:
        if self.mesh is None:
            return 1
        from repro.sharding import rules as R
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        return int(np.prod([sizes[a] for a in R.dp_axes(self.mesh)]))

    def _make_step(self, point):
        return jax.jit(
            steps_lib.make_decode_step(
                self.cfg, use_mcma_dispatch=self.use_mcma_dispatch,
                with_stats=self.use_mcma_dispatch, operating_point=point,
                route_scope=self.route_scope),
            donate_argnums=(1,))

    def _active_step(self):
        """The decode step for this tick: the controller's current ladder
        rung when autotuning (compiled lazily per rung, then cached — a
        switch is a dict lookup, never a retrace), else the static step."""
        if self.controller is None:
            return self.decode
        idx = self.controller.index
        if idx not in self._steps:
            self._steps[idx] = self._make_step(self.controller.ladder[idx])
        return self._steps[idx]

    def _named_shardings(self, specs):
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.tree.map(lambda q: NamedSharding(self.mesh, q), specs,
                            is_leaf=lambda q: isinstance(q, P))

    def _shard_params(self, params):
        from repro.sharding import rules as R
        specs, _ = R.param_pspecs(self.mesh, params)
        return jax.device_put(params, self._named_shardings(specs))

    def _shard_cache(self, cache):
        from repro.sharding import rules as R
        return jax.device_put(cache,
                              self._named_shardings(R.cache_pspecs(self.mesh,
                                                                   cache)))

    def _decode(self, *args):
        with steps_lib.serve_mesh_context(self.mesh):
            return self._active_step()(*args)

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                self.remaining_prompt[i] = np.asarray(req.prompt, np.int32)
                if self._fresh is None:
                    self._fresh = M.init_cache(self.cfg, self.batch, self.max_len)
                    if self.mesh is not None:
                        self._fresh = self._shard_cache(self._fresh)
                self.cache = M.reset_slot(self.cfg, self.cache, self._fresh, i)

    def _gather_tokens(self) -> np.ndarray:
        toks = np.zeros((self.batch, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if self.remaining_prompt[i].size:       # prompt-feeding phase
                toks[i, 0] = self.remaining_prompt[i][0]
                self.remaining_prompt[i] = self.remaining_prompt[i][1:]
            elif req.out:
                toks[i, 0] = req.out[-1]
            else:
                toks[i, 0] = req.prompt[-1]
        return toks

    def tick(self):
        """One decode step for all active slots."""
        self._admit()
        if not any(s is not None for s in self.slots):
            return False
        toks = self._gather_tokens()
        if self.use_mcma_dispatch:
            # active-slot mask: idle slots are excluded from the dispatch
            # and its stats inside the step (the free-slot bias fix), so
            # every metric below is exact for the occupied slots only
            mask = jnp.asarray([s is not None for s in self.slots])
            logits, self.cache, m = self._decode(self.params, self.cache,
                                                 jnp.asarray(toks), mask)
            if "invocation" in m:
                active = sum(s is not None for s in self.slots)
                self.invocation_sum += float(m["invocation"]) * active
                self.active_sum += active
            if "dropped_rows" in m:
                self.dropped_sum += float(m["dropped_rows"])
                disp = np.asarray(m["dispatched"], float)
                routed = np.asarray(m["class_counts"], float)
                self.dispatched_sum = disp if self.dispatched_sum is None \
                    else self.dispatched_sum + disp
                self.routed_sum = routed if self.routed_sum is None \
                    else self.routed_sum + routed
                if self.controller is not None:
                    self.controller.observe(
                        {"class_counts": routed, "dropped": m["dropped_rows"]})
        else:
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(toks))
        if self.greedy:
            nxt = np.asarray(jnp.argmax(logits, -1))
        else:
            self.key, k = jax.random.split(self.key)
            nxt = np.asarray(jax.random.categorical(k, logits))
        pos = np.asarray(self.cache["pos"])           # (B,) per-slot
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if self.remaining_prompt[i].size:
                continue                              # still consuming prompt
            req.out.append(int(nxt[i]))
            if (self.eos is not None and req.out[-1] == self.eos) \
                    or len(req.out) >= req.max_new \
                    or int(pos[i]) >= self.max_len - 1:
                req.done = True
                self.slots[i] = None
        self.ticks += 1
        return True

    def run_until_drained(self, max_ticks: int = 10_000):
        t0 = time.time()
        while (self.queue or any(s is not None for s in self.slots)) \
                and self.ticks < max_ticks:
            self.tick()
        stats = {"ticks": self.ticks, "wall_s": time.time() - t0}
        if self.use_mcma_dispatch:
            stats["invocation_rate"] = \
                self.invocation_sum / max(self.active_sum, 1)
            # the autotuner's objective, observable from server stats:
            # global dropped rows and per-class routed/dispatched counts
            # (layer-mean per tick, summed over ticks; mesh runs report
            # psum-reduced global totals)
            stats["dropped_rows"] = self.dropped_sum
            if self.routed_sum is not None:
                stats["routed_per_class"] = self.routed_sum.tolist()
                stats["dispatched_per_class"] = self.dispatched_sum.tolist()
                total = max(float(self.routed_sum.sum()), 1.0)
                stats["dropped_frac"] = self.dropped_sum / total
                # invocation actually SERVED (approx rows executed, not
                # just routed) — what capacity autotuning maximizes
                stats["served_invocation_rate"] = \
                    float(self.dispatched_sum[1:].sum()) / total
        if self.controller is not None:
            stats["autotune"] = self.controller.summary()
        return stats
