"""Batched decode server loop (the inference-side driver).

Continuous-batching-lite: a fixed-size slot table (``batch`` concurrent
sequences); finished sequences (EOS or max_len) free their slot, queued
requests fill freed slots each tick; one jitted decode step advances every
active slot per tick.  Prefill for an incoming request runs through the
same decode step token-by-token when no prefill step is compiled (small
models), or via prefill_step when one is.

This is deliberately the same decode_step the dry-run lowers — the serving
path at scale IS the lowered cell, just driven by this loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.runtime import steps as steps_lib


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (prompt_len,) int32
    max_new: int = 32
    # per-request QoS: the requested relative-error bound (validated and
    # quantized onto the server's tier table at submit time), or a tier
    # index directly.  None = the deployment's default tier.
    error_bound: float | None = None
    tier: int | None = None
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class DecodeServer:
    def __init__(self, cfg: ModelConfig, params, *, batch: int = 8,
                 max_len: int = 512, eos: int | None = None, greedy=True,
                 seed: int = 0, use_mcma_dispatch: bool = False,
                 mesh=None, autotune=None, drop_budget: float = 0.05,
                 autotune_kwargs: dict | None = None,
                 route_scope: str | None = None,
                 qos_tiers=None, qos_app: str | None = None,
                 qos_margin_scale: float = 4.0):
        self.cfg, self.params = cfg, params
        self.batch, self.max_len, self.eos = batch, max_len, eos
        # qos_tiers: per-request error-bound tiers.  True -> the default
        # (tight, trained, loose) table bracketing the config's (or the
        # registry app's) error bound; a tuple of ascending bounds -> that
        # table.  Each tier maps to an exact-logit router margin
        # (autotune.margins_from_bounds) that is a TRACED input of the one
        # compiled decode step — mixing tiers in a batch, or recalibrating
        # margins, never retraces.  ``qos_app`` names an apps/registry.py
        # app whose quality.py bound anchors the table and the submit-time
        # validation.
        self.tier_bounds = None
        self.qos_app = None
        if qos_app is not None:
            from repro.apps.registry import get_app
            self.qos_app = get_app(qos_app)
            if qos_tiers is None:
                qos_tiers = True
        if qos_tiers:
            from repro.runtime import autotune as at
            assert use_mcma_dispatch, \
                "per-request QoS tiers route through the dispatch engine; " \
                "needs use_mcma_dispatch"
            base = self.qos_app.error_bound if self.qos_app is not None \
                else cfg.approx.error_bound
            if qos_tiers is True:
                qos_tiers = cfg.approx.tier_bounds \
                    or at.default_tier_bounds(base)
            self.tier_bounds = tuple(sorted(float(b) for b in qos_tiers))
            assert self.tier_bounds[0] > 0, self.tier_bounds
            self.tier_margins = np.asarray(
                at.margins_from_bounds(self.tier_bounds, base,
                                       scale=qos_margin_scale), np.float32)
            # requests without a bound serve at the tier closest to the
            # bound the router was trained at
            self.default_tier = int(np.argmin(
                [abs(b - base) for b in self.tier_bounds]))
            cfg = dataclasses.replace(cfg, approx=dataclasses.replace(
                cfg.approx, n_tiers=len(self.tier_bounds),
                tier_bounds=self.tier_bounds,
                tier_margins=tuple(float(m) for m in self.tier_margins)))
            self.cfg = cfg
        # route_scope: "tick" routes once per decode tick (one DispatchPlan
        # from the tick-router head, reused by every layer of the scan) —
        # the per-tick metrics the server (and the autotune controller)
        # observe are then the single tick-level dispatch decision rather
        # than a mean of L per-layer ones.  None honors the config.
        self.route_scope = route_scope
        # use_mcma_dispatch: decode ticks run the ApproxFFN through the
        # MCMA Pallas weight-switch engine (runtime/dispatch.py) and the
        # server accumulates the invocation rate, weighting each tick by
        # its active-slot count.  Every tick passes the active-slot mask
        # into the decode step, so free slots (fed token 0) are excluded
        # from the router, the capacity dispatch, and every invoke stat —
        # the rates are exact even on a mostly-idle slot table.
        self.use_mcma_dispatch = use_mcma_dispatch
        # mesh: distributed deployment.  Params/cache are sharded by the
        # declarative rules (sharding/rules.py) and every decode step is
        # traced under steps.serve_mesh_context, so the serve-mode FFNs run
        # their shard_map-native dispatch (the MCMA engine per data shard,
        # invoke_stats psum-reduced to global totals).  The mesh's
        # data-axis size must divide ``batch`` for the manual path to
        # engage.
        self.mesh = mesh
        # autotune: online capacity adaptation (runtime/autotune.py).
        # True -> the default ladder around cfg's static operating point;
        # a sequence of OperatingPoints -> that ladder.  One decode step
        # per rung is compiled lazily on first use; the controller picks
        # the rung per tick from the served global invoke_stats, targeting
        # ``drop_budget`` dropped-row fraction at minimum capacity.
        self.controller = None
        if autotune:
            from repro.runtime import autotune as at
            assert use_mcma_dispatch, \
                "autotune consumes invoke_stats; needs use_mcma_dispatch"
            ladder = at.default_ladder(cfg) if autotune is True \
                else tuple(autotune)
            shards = self._dp_shards()
            assert batch % shards == 0, (batch, shards)
            n = cfg.approx.n_approx
            caps_fn = lambda pt: at.point_caps(pt, batch // shards, n,
                                               n_shards=shards)
            # cold-start at the configured static operating point when the
            # ladder contains it (the controller then only MOVES once the
            # served stats justify it), else at the cheapest rung
            base = at.OperatingPoint(cfg.approx.exact_frac,
                                     cfg.approx.invoke_frac,
                                     cfg.approx.shard_slack)
            kw = dict(autotune_kwargs or {})
            if "start" not in kw and base in ladder:
                kw["start"] = ladder.index(base)
            self.controller = at.CapacityController(
                ladder, caps_fn, drop_budget=drop_budget, **kw)
        self._steps = {}             # ladder index -> jitted decode step
        self.decode = self._make_step(None)
        self.invocation_sum = 0.0    # active-slot-weighted invocation sum
        self.active_sum = 0          # total active slots over all ticks
        self.dropped_sum = 0.0       # layer-mean dropped rows over ticks
        self.dispatched_sum = None   # (n+1,) layer-mean dispatched rows
        self.routed_sum = None       # (n+1,) layer-mean routed rows
        self.routed_history = []     # per-tick (n+1,) routed counts — the
                                     # ladder_from_counts signal; bounded
                                     # to the most recent window so a
                                     # long-lived server never grows
                                     # memory linearly in ticks
        self.routed_history_cap = 4096
        self.tier_routed_sum = None      # (n_tiers, n+1) per-tier routed
        self.tier_dispatched_sum = None  # (n_tiers, n+1) per-tier served
        self.cache = M.init_cache(cfg, batch, max_len)
        if mesh is not None:
            self.params = self._shard_params(params)
            self.cache = self._shard_cache(self.cache)
        self.slots: list[Request | None] = [None] * batch
        self.queue: list[Request] = []
        self.remaining_prompt: list[np.ndarray] = [np.zeros((0,), np.int32)] * batch
        self.key = jax.random.PRNGKey(seed)
        self.greedy = greedy
        self.ticks = 0
        self._fresh = None  # lazily-built pristine cache for slot resets

    def _dp_shards(self) -> int:
        if self.mesh is None:
            return 1
        from repro.sharding import rules as R
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        return int(np.prod([sizes[a] for a in R.dp_axes(self.mesh)]))

    def _make_step(self, point):
        return jax.jit(
            steps_lib.make_decode_step(
                self.cfg, use_mcma_dispatch=self.use_mcma_dispatch,
                with_stats=self.use_mcma_dispatch, operating_point=point,
                route_scope=self.route_scope),
            donate_argnums=(1,))

    def _active_step(self):
        """The decode step for this tick: the controller's current ladder
        rung when autotuning (compiled lazily per rung, then cached — a
        switch is a dict lookup, never a retrace), else the static step."""
        if self.controller is None:
            return self.decode
        idx = self.controller.index
        if idx not in self._steps:
            self._steps[idx] = self._make_step(self.controller.ladder[idx])
        return self._steps[idx]

    def _named_shardings(self, specs):
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.tree.map(lambda q: NamedSharding(self.mesh, q), specs,
                            is_leaf=lambda q: isinstance(q, P))

    def _shard_params(self, params):
        from repro.sharding import rules as R
        specs, _ = R.param_pspecs(self.mesh, params)
        return jax.device_put(params, self._named_shardings(specs))

    def _shard_cache(self, cache):
        from repro.sharding import rules as R
        return jax.device_put(cache,
                              self._named_shardings(R.cache_pspecs(self.mesh,
                                                                   cache)))

    def _decode(self, *args):
        with steps_lib.serve_mesh_context(self.mesh):
            return self._active_step()(*args)

    def submit(self, req: Request):
        """Queue a request; per-request QoS is validated HERE, loudly.

        ``req.error_bound`` is checked against the deployment's tier table
        (anchored on the registry app's quality.py bound when ``qos_app``
        was given): a bound tighter than the tightest tier cannot be
        honored and raises, as does a non-positive/non-finite one; a valid
        bound quantizes onto the largest tier bound <= the request (served
        at-or-tighter than asked, never looser).  ``req.tier`` selects a
        tier index directly and must be in range."""
        if (req.error_bound is not None or req.tier is not None) \
                and self.tier_bounds is None:
            raise ValueError(
                f"request {req.rid} carries a QoS error_bound/tier but this "
                "server has no tier table — construct DecodeServer("
                "qos_tiers=...) (or qos_app=...) to serve per-request "
                "quality")
        if req.error_bound is not None:
            eb = float(req.error_bound)
            lo = self.tier_bounds[0]
            app = f" (app '{self.qos_app.name}' registry quality bound " \
                  f"{self.qos_app.error_bound})" if self.qos_app else ""
            if not np.isfinite(eb) or eb <= 0.0:
                raise ValueError(f"request {req.rid}: error_bound {eb!r} "
                                 f"is not a positive finite relative "
                                 f"error{app}")
            if eb < lo - 1e-12:
                raise ValueError(
                    f"request {req.rid}: error_bound {eb} is tighter than "
                    f"the tightest served tier {lo} — out of range for "
                    f"tiers {self.tier_bounds}{app}")
            # largest tier bound <= the request: at-or-tighter than asked
            # (a bound looser than every tier clamps to the loosest)
            req.tier = max(i for i, b in enumerate(self.tier_bounds)
                           if b <= eb + 1e-12)
        elif req.tier is not None:
            if not 0 <= int(req.tier) < len(self.tier_bounds):
                raise ValueError(
                    f"request {req.rid}: tier {req.tier} out of range for "
                    f"{len(self.tier_bounds)} tiers {self.tier_bounds}")
            req.tier = int(req.tier)
        self.queue.append(req)

    def _admit(self):
        for i in range(self.batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                self.remaining_prompt[i] = np.asarray(req.prompt, np.int32)
                if self._fresh is None:
                    self._fresh = M.init_cache(self.cfg, self.batch, self.max_len)
                    if self.mesh is not None:
                        self._fresh = self._shard_cache(self._fresh)
                self.cache = M.reset_slot(self.cfg, self.cache, self._fresh, i)

    def _gather_tokens(self) -> np.ndarray:
        toks = np.zeros((self.batch, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if self.remaining_prompt[i].size:       # prompt-feeding phase
                toks[i, 0] = self.remaining_prompt[i][0]
                self.remaining_prompt[i] = self.remaining_prompt[i][1:]
            elif req.out:
                toks[i, 0] = req.out[-1]
            else:
                toks[i, 0] = req.prompt[-1]
        return toks

    def tick(self):
        """One decode step for all active slots."""
        self._admit()
        if not any(s is not None for s in self.slots):
            return False
        toks = self._gather_tokens()
        if self.use_mcma_dispatch:
            # active-slot mask: idle slots are excluded from the dispatch
            # and its stats inside the step (the free-slot bias fix), so
            # every metric below is exact for the occupied slots only
            mask = jnp.asarray([s is not None for s in self.slots])
            if self.tier_bounds is not None:
                # per-slot QoS tier vector, riding next to the mask; the
                # margins vector is a traced input — one compiled step
                # serves every tier mix
                tiers = np.asarray(
                    [self.default_tier if s is None or s.tier is None
                     else s.tier for s in self.slots], np.int32)
                logits, self.cache, m = self._decode(
                    self.params, self.cache, jnp.asarray(toks), mask,
                    jnp.asarray(tiers), jnp.asarray(self.tier_margins))
            else:
                logits, self.cache, m = self._decode(self.params, self.cache,
                                                     jnp.asarray(toks), mask)
            if "invocation" in m:
                active = sum(s is not None for s in self.slots)
                self.invocation_sum += float(m["invocation"]) * active
                self.active_sum += active
            if "dropped_rows" in m:
                self.dropped_sum += float(m["dropped_rows"])
                disp = np.asarray(m["dispatched"], float)
                routed = np.asarray(m["class_counts"], float)
                self.dispatched_sum = disp if self.dispatched_sum is None \
                    else self.dispatched_sum + disp
                self.routed_sum = routed if self.routed_sum is None \
                    else self.routed_sum + routed
                self.routed_history.append(routed)
                if len(self.routed_history) > self.routed_history_cap:
                    del self.routed_history[0]
                if "tier_counts" in m:
                    tc = np.asarray(m["tier_counts"], float)
                    td = np.asarray(m["tier_dispatched"], float)
                    self.tier_routed_sum = tc \
                        if self.tier_routed_sum is None \
                        else self.tier_routed_sum + tc
                    self.tier_dispatched_sum = td \
                        if self.tier_dispatched_sum is None \
                        else self.tier_dispatched_sum + td
                if self.controller is not None:
                    self.controller.observe(
                        {"class_counts": routed, "dropped": m["dropped_rows"]})
        else:
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(toks))
        if self.greedy:
            nxt = np.asarray(jnp.argmax(logits, -1))
        else:
            self.key, k = jax.random.split(self.key)
            nxt = np.asarray(jax.random.categorical(k, logits))
        pos = np.asarray(self.cache["pos"])           # (B,) per-slot
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if self.remaining_prompt[i].size:
                continue                              # still consuming prompt
            req.out.append(int(nxt[i]))
            if (self.eos is not None and req.out[-1] == self.eos) \
                    or len(req.out) >= req.max_new \
                    or int(pos[i]) >= self.max_len - 1:
                req.done = True
                self.slots[i] = None
        self.ticks += 1
        return True

    def run_until_drained(self, max_ticks: int = 10_000):
        t0 = time.time()
        while (self.queue or any(s is not None for s in self.slots)) \
                and self.ticks < max_ticks:
            self.tick()
        stats = {"ticks": self.ticks, "wall_s": time.time() - t0}
        if self.use_mcma_dispatch:
            stats["invocation_rate"] = \
                self.invocation_sum / max(self.active_sum, 1)
            # the autotuner's objective, observable from server stats:
            # global dropped rows and per-class routed/dispatched counts
            # (layer-mean per tick, summed over ticks; mesh runs report
            # psum-reduced global totals)
            stats["dropped_rows"] = self.dropped_sum
            if self.routed_sum is not None:
                stats["routed_per_class"] = self.routed_sum.tolist()
                stats["dispatched_per_class"] = self.dispatched_sum.tolist()
                total = max(float(self.routed_sum.sum()), 1.0)
                stats["dropped_frac"] = self.dropped_sum / total
                # invocation actually SERVED (approx rows executed, not
                # just routed) — what capacity autotuning maximizes
                stats["served_invocation_rate"] = \
                    float(self.dispatched_sum[1:].sum()) / total
            if self.tier_bounds is not None \
                    and self.tier_routed_sum is not None:
                # the drain summary's QoS ledger: served invocation and
                # dropped fraction attributed to each error-bound tier
                per = []
                for k, bound in enumerate(self.tier_bounds):
                    routed_k = self.tier_routed_sum[k]
                    disp_k = self.tier_dispatched_sum[k]
                    rows = float(routed_k.sum())
                    per.append({
                        "tier": k,
                        "error_bound": float(bound),
                        "margin": float(self.tier_margins[k]),
                        "rows": rows,
                        "served_invocation_rate":
                            float(disp_k[1:].sum()) / max(rows, 1.0),
                        "routed_invocation_rate":
                            float(routed_k[1:].sum()) / max(rows, 1.0),
                        "dropped_rows": float((routed_k - disp_k).sum()),
                        "dropped_frac":
                            float((routed_k - disp_k).sum()) / max(rows, 1.0),
                    })
                stats["per_tier"] = per
        if self.controller is not None:
            stats["autotune"] = self.controller.summary()
        return stats

    def derived_ladder(self, **kwargs):
        """runtime/autotune.ladder_from_counts over this server's served
        per-tick ``routed_per_class`` history: capacity rungs whose
        per-class budgets track the observed class-count quantiles — the
        asymmetric ladder to deploy for the NEXT run of this mix."""
        from repro.runtime import autotune as at
        assert self.routed_history, \
            "no served invoke stats yet (needs use_mcma_dispatch ticks)"
        return at.ladder_from_counts(
            np.asarray(self.routed_history), self.batch,
            tier_margins=tuple(float(m) for m in self.tier_margins)
            if self.tier_bounds is not None else (), **kwargs)
