"""Shared serve-flag surface for every CLI entry point.

Three surfaces drifted apart over six PRs — launch/serve.py,
examples/serve_decode.py, benchmarks/bench_serve.py each re-declared the
same serving flags with subtly different inventories.  They now all call

    ap = argparse.ArgumentParser()
    add_serve_options(ap, batch=4, max_len=128)   # per-surface defaults
    args = ap.parse_args(argv)
    options = ServeOptions.from_args(args)

so a new serving knob added HERE (plus its ``ServeOptions`` field) lands
in all three for free.  ``add_serve_options`` only registers flags; the
implication chain (--qos-app implies --qos implies --mcma-dispatch, a
library implies --mcma-dispatch) lives in ``ServeOptions.from_args`` so
programmatic callers get it too.
"""
from __future__ import annotations

import argparse


def add_serve_options(parser: argparse.ArgumentParser,
                      **defaults) -> argparse.ArgumentParser:
    """Register the canonical serving flags as one argument group.

    ``defaults`` override per-flag defaults for the calling surface
    (e.g. ``add_serve_options(ap, batch=4, max_len=96)``) — keys must
    name registered dests.  Returns the parser for chaining.
    """
    g = parser.add_argument_group(
        "serving", "DecodeServer deployment (runtime/options.ServeOptions)")
    g.add_argument("--batch", type=int, default=8,
                   help="decode slot-table size")
    g.add_argument("--max-len", type=int, default=512,
                   help="per-slot KV-cache length (prompt + generated)")
    g.add_argument("--seed", type=int, default=0)
    g.add_argument("--mcma-dispatch", action="store_true",
                   help="serve the ApproxFFN through the weight-switch "
                        "dispatch engine (implies --approx where the "
                        "surface has it)")
    g.add_argument("--backend", choices=("pallas", "pallas_fused", "xla"),
                   default=None,
                   help="dispatch executor override (default: the "
                        "config's approx.backend); pallas_fused runs the "
                        "gather/scatter-fused kernel "
                        "(kernels/fused_dispatch.py)")
    g.add_argument("--route-scope", choices=("layer", "tick"), default=None,
                   help="MCMA routing granularity at decode: 'tick' makes "
                        "ONE dispatch plan per tick (reused by every "
                        "layer); 'layer' routes per layer (default: the "
                        "config's route_scope)")
    g.add_argument("--autotune", action="store_true",
                   help="adapt serve capacities online from the served "
                        "invoke_stats (runtime/autotune.py; implies "
                        "--mcma-dispatch): the controller walks a ladder "
                        "of precompiled operating points targeting "
                        "--drop-budget dropped rows at max invocation")
    g.add_argument("--drop-budget", type=float, default=0.05,
                   help="autotune target: max fraction of routed rows "
                        "dropped over capacity (default 0.05)")
    g.add_argument("--qos", action="store_true",
                   help="per-request QoS tiers (implies --mcma-dispatch): "
                        "each request carries an error_bound, validated "
                        "and quantized onto the tier table at submit time")
    g.add_argument("--qos-app", default=None,
                   help="apps/registry.py app whose error bound anchors "
                        "the QoS tier table (implies --qos; default "
                        "anchor: the config's approx.error_bound)")
    g.add_argument("--tier-bounds", default=None,
                   help="comma-separated ascending error bounds "
                        "overriding the default (tight, base, loose) "
                        "tier table, e.g. 0.05,0.1,0.2")
    g.add_argument("--library-size", type=int, default=0,
                   help="approximator-library residency (implies "
                        "--mcma-dispatch): serve a library of this many "
                        "trained approximators with --n-resident of them "
                        "resident, hot-swapped by the ResidencyController "
                        "(0 = off, the all-resident engine)")
    g.add_argument("--n-resident", type=int, default=0,
                   help="resident slots with --library-size (0 = "
                        "min(4, library_size))")
    g.add_argument("--prefill-chunk", type=int, default=16,
                   help="chunked prefill: S prompt tokens per prefill "
                        "tick through the compiled chunk step, "
                        "interleaved with decode ticks (0 = token-by-"
                        "token reference mode; non-uniform families fall "
                        "back automatically)")
    g.add_argument("--admission", choices=("cost", "fifo"), default="cost",
                   help="queue admission: 'cost' = prompt length x QoS "
                        "tier multiplier with aging (default), 'fifo' = "
                        "strict arrival order")
    g.add_argument("--overflow", choices=("reject", "trim"),
                   default="reject",
                   help="submit-time policy when prompt + max_new "
                        "exceeds max_len: reject loudly (default) or "
                        "trim the prompt to its last max_len - max_new "
                        "tokens")
    g.add_argument("--aging", type=float, default=0.05,
                   help="cost-admission aging rate (starvation guard)")
    g.add_argument("--kv-page-size", type=int, default=0,
                   help="paged KV cache: page length in tokens (must "
                        "divide --max-len; admission then prices PAGES "
                        "instead of prompt length; 0 = the dense "
                        "(batch, max_len) layout, the bit-exact oracle)")
    g.add_argument("--kv-pages", type=int, default=0,
                   help="page-pool size with --kv-page-size (0 = batch x "
                        "max_len/page_size, byte-parity with dense; set "
                        "lower so long-max-len deployments stop "
                        "reserving worst-case memory per slot)")
    if defaults:
        known = {a.dest for a in parser._actions}
        unknown = set(defaults) - known
        assert not unknown, f"add_serve_options: unknown defaults {unknown}"
        parser.set_defaults(**defaults)
    return parser
