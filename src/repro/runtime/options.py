"""The consolidated serving API: ``ServeOptions`` + ``LibrarySpec``.

Six PRs of serving features accreted ~20 loose keyword arguments on
``DecodeServer.__init__`` and three argparse surfaces re-declaring the
same flags.  This module is the single place serve-time state is
declared from now on:

    server = DecodeServer(cfg, params, options=ServeOptions(
        batch=8, use_mcma_dispatch=True, autotune=True,
        library=LibrarySpec(library_size=16, n_resident=4)))

``ServeOptions`` is frozen — a value object describing one deployment,
safe to share between a launcher, a benchmark, and a test.  The legacy
kwarg form (``DecodeServer(cfg, params, batch=8, ...)``) still works
through a one-``DeprecationWarning`` shim that folds the kwargs into a
``ServeOptions`` (runtime/server.py), so every pre-existing call site
keeps its exact semantics.

``LibrarySpec`` declares the approximator-library residency runtime
(ISSUE 7 / the paper's weight-shipping design at library scale): a
library of ``library_size`` trained approximators of which
``n_resident`` occupy the prepadded weight stacks at any moment, with a
``ResidencyController`` (runtime/autotune.py) promoting/demoting library
classes from the served routed-per-class EMA.  The spec carries ONLY
serve-time policy; the trained library size itself is
``ApproxConfig.library_size`` (configs/base.py) and must match.

``ServeOptions.from_args`` pairs with ``runtime/cli.add_serve_options``
so the three CLI surfaces (launch/serve.py, examples/serve_decode.py,
benchmarks/bench_serve.py) share one flag inventory — a new knob lands
in all three for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional


@dataclasses.dataclass(frozen=True)
class LibrarySpec:
    """Approximator-library residency policy (serve-time).

    library_size    trained approximators in the library (must equal
                    ``ApproxConfig.library_size`` of the checkpoint)
    n_resident      slots in the prepadded weight stacks — the classes
                    servable without a swap (becomes the serving
                    ``n_approx``; capacities are per-slot)
    promote_margin  promote the hottest off-set class over the coldest
                    resident when its routed-share EMA exceeds
                    ``promote_margin x`` the resident's (ratio hysteresis
                    — a borderline class doesn't thrash)
    demote_margin   absolute routed-share floor: a resident serving more
                    than this fraction of traffic is never demoted,
                    whatever is knocking
    observe_window  controller decides once per this many observed ticks
    cooldown        ticks after a swap before the next decision window
                    counts (lets the EMA re-converge on the new set)
    ema             smoothing factor for the routed-per-class shares
    start           initial resident library ids; () = the first
                    ``n_resident`` classes (library ids 0..n_resident-1)
    """

    library_size: int
    n_resident: int
    promote_margin: float = 1.5
    demote_margin: float = 0.25
    observe_window: int = 8
    cooldown: int = 16
    ema: float = 0.3
    start: tuple = ()

    def __post_init__(self):
        assert self.n_resident >= 1, "need at least one resident slot"
        assert self.library_size >= self.n_resident, (
            f"library_size={self.library_size} must hold at least the "
            f"{self.n_resident} resident classes")
        assert self.promote_margin >= 1.0, \
            "promote_margin < 1 would thrash on noise"
        if self.start:
            assert len(self.start) == self.n_resident and \
                all(0 <= s < self.library_size for s in self.start), (
                    f"start={self.start} must name {self.n_resident} "
                    f"distinct library ids < {self.library_size}")

    def initial_residency(self) -> tuple:
        return tuple(self.start) if self.start \
            else tuple(range(self.n_resident))


@dataclasses.dataclass(frozen=True)
class ServeOptions:
    """Everything a ``DecodeServer`` deployment decides at serve time.

    Groups (one field per historic ``DecodeServer`` kwarg, same names,
    same defaults — the legacy shim folds kwargs straight in):

    batching:    batch, max_len, eos, greedy, seed
    dispatch:    use_mcma_dispatch, backend ("pallas"/"pallas_fused"/
                 "xla"/None = config; pallas_fused = the gather/scatter-
                 fused kernel, kernels/fused_dispatch.py),
                 route_scope ("layer"/"tick"/None = config), mesh
    autotune:    autotune (True = default ladder, or an explicit rung
                 tuple), drop_budget, autotune_kwargs
    QoS:         qos_tiers (True = default table, or an ascending bound
                 tuple), qos_app, qos_margin_scale
    scheduling:  prefill_chunk, admission ("cost"/"fifo"),
                 overflow ("reject"/"trim"), aging
    memory:      kv_page_size (paged KV cache page length in tokens;
                 must divide max_len; 0 = the dense (batch, max_len)
                 layout, the bit-exact oracle), kv_pages (page-pool
                 size; 0 = batch x max_len/page_size, byte-parity with
                 dense — set lower so long-max_len deployments stop
                 reserving worst-case memory per slot; docs/serving.md)
    library:     a ``LibrarySpec`` enabling approximator-library
                 residency (None = the historic all-resident engine)
    """

    batch: int = 8
    max_len: int = 512
    eos: Optional[int] = None
    greedy: bool = True
    seed: int = 0
    use_mcma_dispatch: bool = False
    mesh: Any = None
    autotune: Any = None
    drop_budget: float = 0.05
    autotune_kwargs: Optional[dict] = None
    route_scope: Optional[str] = None
    qos_tiers: Any = None
    qos_app: Optional[str] = None
    qos_margin_scale: float = 4.0
    prefill_chunk: int = 0
    admission: str = "cost"
    overflow: str = "reject"
    aging: float = 0.05
    kv_page_size: int = 0
    kv_pages: int = 0
    backend: Optional[str] = None
    library: Optional[LibrarySpec] = None

    @classmethod
    def from_args(cls, args, **overrides) -> "ServeOptions":
        """Build from an argparse namespace produced by
        ``runtime/cli.add_serve_options`` (missing attributes keep their
        field defaults, so a surface may register only a subset of the
        shared flags).  ``overrides`` win over both.

        Applies the historic implication chain: ``--qos-app`` /
        ``--tier-bounds`` imply QoS; QoS / ``--autotune`` / a library
        imply the MCMA dispatch engine.
        """
        kw = {}
        for f in ("batch", "max_len", "drop_budget", "route_scope",
                  "qos_app", "prefill_chunk", "admission", "overflow",
                  "aging", "kv_page_size", "kv_pages", "backend", "seed",
                  "greedy", "eos"):
            if hasattr(args, f):
                kw[f] = getattr(args, f)
        if getattr(args, "autotune", False):
            kw["autotune"] = True
        if getattr(args, "tier_bounds", None):
            tb = args.tier_bounds
            kw["qos_tiers"] = tuple(float(b) for b in tb.split(",")) \
                if isinstance(tb, str) else tuple(tb)
        elif getattr(args, "qos", False) or kw.get("qos_app"):
            kw["qos_tiers"] = True
        if getattr(args, "library_size", 0):
            kw["library"] = LibrarySpec(
                library_size=args.library_size,
                n_resident=getattr(args, "n_resident", 0)
                or min(4, args.library_size))
        kw.update(overrides)
        if kw.get("autotune") or kw.get("qos_tiers") or kw.get("library"):
            kw.setdefault("use_mcma_dispatch", True)
        elif getattr(args, "mcma_dispatch", False):
            kw["use_mcma_dispatch"] = True
        return cls(**kw)
