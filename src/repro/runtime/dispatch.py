"""MCMA dispatch runtime — the single serving-side invocation engine.

The paper's NPU swaps the invoked approximator "within a cycle" by shipping
a weight set from on-chip cache to the PE weight buffers (§III-D).  This
module is the TPU-serving analog, one jit-stable pipeline behind
``mcma_dispatch``:

  classify   router/classifier logits -> per-row class (0 = exact / nC)
  capacity   static per-class token budgets (GShard convention:
             over-capacity rows contribute zero; the residual carries them)
  class-sort rows grouped into single-class row-tiles
             (kernels/ops.class_sort_plan)
  switch     the scalar-prefetch Pallas kernel streams each tile's
             approximator weights HBM->VMEM behind the previous tile's
             compute (kernels/switched_mlp.py) — the weight-buffer swap
  exact      class-0 (non-approximable, "nC") rows run the exact function
             on a gathered capacity buffer; in the Pallas path the
             nC/over-capacity rows ride through the kernel under a
             zero-weight pseudo-approximator so the grouped matmul stays
             one kernel launch (their contribution is exactly 0)
  scatter    results return to the original row order

Backends:
  * ``backend="pallas"`` — the weight-switch kernel path above
    (``interpret=True`` runs it on CPU; compiled on TPU).
  * ``backend="xla"``    — the portable per-class gather/scatter loop the
    seed shipped.  It is the semantic oracle: tests require the Pallas
    path to match it on every dispatched row.

Every call also returns ``invoke_stats`` (per-class routed counts,
post-capacity dispatched counts, dropped rows, exact fraction, executed
rows vs useful rows) so servers and benchmarks can report invocation rate
— the paper's headline metric — per request batch.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.kernels import ops


def route(logits: jax.Array) -> jax.Array:
    """Router/classifier logits (T, n+1) -> class ids (T,); 0 = exact."""
    return jnp.argmax(logits.astype(jnp.float32), -1).astype(jnp.int32)


def apply_approximator(xb: jax.Array, w1: jax.Array, b1: jax.Array,
                       w2: jax.Array, b2: jax.Array) -> jax.Array:
    """One approximator's tanh MLP on a row block — the single definition
    of the per-class math shared by the XLA oracle backend and the manual
    sharded serve path (models/approx_ffn._approx_serve_manual)."""
    h = jnp.tanh(jnp.dot(xb, w1.astype(xb.dtype)) + b1.astype(xb.dtype))
    return jnp.dot(h, w2.astype(xb.dtype)) + b2.astype(xb.dtype)


def _rank_in_class(cls: jax.Array, n_classes: int) -> jax.Array:
    """rank[i] = #rows j<=i with cls[j]==cls[i], minus one (arrival order)."""
    oh = jax.nn.one_hot(cls, n_classes, dtype=jnp.int32)      # (T, n_classes)
    return jnp.take_along_axis(jnp.cumsum(oh, 0) - 1, cls[:, None], 1)[:, 0]


def capacity_path(x: jax.Array, mask: jax.Array, cap: int,
                  fn: Callable[[jax.Array], jax.Array]) -> jax.Array:
    """Gather <=cap rows where mask, apply fn, scatter back (zeros elsewhere).

    Static shapes throughout: rows ranked past ``cap`` fall into a trash
    slot and contribute zero — identical math to the seed's serve path.
    """
    _, d = x.shape
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1               # rank in class
    keep = mask & (pos < cap)
    idx = jnp.where(keep, pos, cap)                            # cap = trash
    buf = jnp.zeros((cap + 1, d), x.dtype).at[idx].set(x * keep[:, None])
    y = fn(buf[:cap])
    y = jnp.concatenate([y, jnp.zeros((1, y.shape[-1]), y.dtype)], 0)
    return y[idx] * keep[:, None]


def mcma_dispatch(x: jax.Array, logits: jax.Array,
                  exact_fn: Callable[[jax.Array], jax.Array],
                  a_w1: jax.Array, a_b1: jax.Array,
                  a_w2: jax.Array, a_b2: jax.Array, *,
                  exact_cap: int, invoke_cap: int, backend: str = "xla",
                  block_t: int = 128, interpret: bool = False):
    """Full MCMA invocation pipeline over a flat row batch.

    x: (T, d); logits: (T, n_approx+1) router scores (class 0 = exact);
    exact_fn: (cap, d) -> (cap, d_out) exact path applied to the gathered
    class-0 buffer; a_*: stacked approximator weights, leading dim n_approx.
    ``exact_cap``/``invoke_cap``/``backend``/``block_t``/``interpret`` must
    be static under jit (they determine shapes / the traced program).

    Returns ``(y, invoke_stats)`` with y: (T, d_out) in the original row
    order and invoke_stats a dict of jnp scalars/vectors:

      class_counts  (n+1,) routed rows per class (sums to T)
      dispatched    (n+1,) rows actually executed after capacity
      dropped       scalar, over-capacity rows (zero contribution)
      exact_frac    scalar, class_counts[0] / T
      invocation    scalar, 1 - exact_frac (the paper's invocation rate)
      executed_rows scalar, rows of compute actually launched
      padding_rows  scalar, executed_rows - sum(dispatched) (capacity slack
                    for XLA; tile padding, nC deadweight, and the static
                    worst-case trailing tiles for Pallas)
    """
    t, _ = x.shape
    n = a_w1.shape[0]
    cls = route(logits)
    counts = jnp.bincount(cls, length=n + 1)

    # exact ("nC") rows: both backends share the capacity gather path
    out = capacity_path(x, cls == 0, exact_cap, exact_fn)

    if backend == "xla":
        for i in range(n):
            def approx_i(xb, i=i):
                return apply_approximator(xb, a_w1[i], a_b1[i],
                                          a_w2[i], a_b2[i])
            out = out + capacity_path(x, cls == i + 1, invoke_cap, approx_i)
        executed = jnp.asarray(exact_cap + n * invoke_cap, jnp.int32)
    elif backend == "pallas":
        # capacity first, then one grouped kernel launch over ALL rows:
        # kept approx rows keep their class; exact + over-capacity rows are
        # assigned a zero-weight pseudo-class n, whose tiles compute exact
        # zeros (tanh(0)@0 + 0), so no post-mask is needed.
        rank = _rank_in_class(cls, n + 1)
        kept = (cls > 0) & (rank < invoke_cap)
        eff = jnp.where(kept, cls - 1, n).astype(jnp.int32)
        zcls = lambda w: jnp.concatenate([w, jnp.zeros_like(w[:1])], 0)
        out = out + ops.switched_apply(
            x, eff, zcls(a_w1), zcls(a_b1), zcls(a_w2), zcls(a_b2),
            block_t=block_t, interpret=interpret)
        # the kernel launches the full static worst-case grid (including
        # trailing zero tiles past the occupied region), so that is what
        # executed_rows must count — n+1 classes including the pseudo-class
        t_pad = ops.worst_case_rows(t, n + 1, block_t)
        executed = jnp.asarray(exact_cap + t_pad, jnp.int32)
    else:
        raise ValueError(f"unknown dispatch backend: {backend!r}")

    caps = jnp.asarray([exact_cap] + [invoke_cap] * n, counts.dtype)
    dispatched = jnp.minimum(counts, caps)
    exact_frac = (counts[0] / t).astype(jnp.float32)
    stats = {
        "class_counts": counts,
        "dispatched": dispatched,
        "dropped": jnp.sum(counts - dispatched),
        "exact_frac": exact_frac,
        "invocation": (1.0 - exact_frac).astype(jnp.float32),
        "executed_rows": executed,
        "padding_rows": executed - jnp.sum(dispatched).astype(jnp.int32),
    }
    return out, stats
