"""MCMA dispatch runtime — the single serving-side invocation engine.

The paper's NPU swaps the invoked approximator "within a cycle" by shipping
a weight set from on-chip cache to the PE weight buffers (§III-D).  This
module is the TPU-serving analog, one jit-stable pipeline behind
``mcma_dispatch``:

  classify   router/classifier logits -> per-row class (0 = exact / nC)
  capacity   static per-class token budgets (GShard convention:
             over-capacity rows contribute zero; the residual carries them)
  class-sort rows grouped into single-class row-tiles
             (kernels/ops.class_sort_plan)
  switch     the scalar-prefetch Pallas kernel streams each tile's
             approximator weights HBM->VMEM behind the previous tile's
             compute (kernels/switched_mlp.py) — the weight-buffer swap
  exact      class-0 (non-approximable, "nC") rows run the exact function
             on a gathered capacity buffer; in the Pallas path the
             nC/over-capacity rows ride through the kernel under a
             zero-weight pseudo-approximator so the grouped matmul stays
             one kernel launch (their contribution is exactly 0)
  scatter    results return to the original row order

Backends:
  * ``backend="pallas"`` — the weight-switch kernel path above
    (``interpret=True`` runs it on CPU; compiled on TPU).
  * ``backend="pallas_fused"`` — the same plan executed by the FUSED
    kernel (kernels/fused_dispatch.py): the class-sort permutation rides
    into the kernel as a scalar-prefetched row-index vector, so the
    gather/scatter legs disappear from the XLA program and activations
    cross HBM once per layer.  Bit-identical to "pallas".
  * ``backend="xla"``    — the portable per-class gather/scatter loop the
    seed shipped.  It is the semantic oracle: tests require both Pallas
    paths to match it on every dispatched row.

Every call also returns ``invoke_stats`` (per-class routed counts,
post-capacity dispatched counts, dropped rows, exact fraction, executed
rows vs useful rows) so servers and benchmarks can report invocation rate
— the paper's headline metric — per request batch.

The engine is shard_map-native: called inside a ``shard_map`` over the
data axes with ``stats_axes=<those axes>``, each data shard classifies,
capacities, class-sorts, and runs the weight-switch kernel on its OWN
rows (no cross-shard dispatch traffic — the same lesson as the manual MoE
path), while the invoke_stats are ``psum``-reduced over ``stats_axes`` so
every caller sees the global totals, exactly equal to summing each
shard's local stats on one device.  ``mcma_dispatch_sharded`` is the
ready-made wrapper for flat row batches; the model layers
(models/approx_ffn.py) embed the engine in their own shard_map instead.

Per-request QoS: quality is a per-ROW runtime value, not a config
constant.  Every row may carry a tier (``tier``, (T,) int32) indexing a
TRACED ``(n_tiers,)`` vector of exact-logit margins (``route``): tight
error bounds bias borderline rows to the exact path, loose bounds hand
them to their best approximator — one compiled program serves every
margin setting, and the invoke_stats split routed/dispatched/dropped
per tier so servers can report served invocation per QoS class.

Plan/execute split: the route -> capacity -> class-sort half of the
pipeline is ``make_dispatch_plan`` and returns a ``DispatchPlan`` (class
ids, within-class ranks, the class-sort permutation, keep/slot buffers,
per-class counts — everything that depends on the LOGITS but not on the
layer's weights); ``execute_dispatch`` applies one layer's approximators
and exact path against a plan.  ``mcma_dispatch`` is exactly
``make_dispatch_plan`` + ``execute_dispatch`` + ``plan_invoke_stats``,
so the paper's one-decision-per-input semantics fall out for free: route
once per decode tick, reuse the SAME plan across all L layers of the
scan (``ApproxConfig.route_scope = "tick"``), and each layer is one
weight-switch kernel launch on already-sorted rows.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.kernels import ops

# Backends whose plans carry a live class-sort (order/pos/tile_cls) and
# launch the worst-case single-class-tile grid; "xla" carries placeholders.
PALLAS_BACKENDS = ("pallas", "pallas_fused")
DISPATCH_BACKENDS = ("xla",) + PALLAS_BACKENDS


def route(logits: jax.Array, tier: jax.Array | None = None,
          tier_margins: jax.Array | None = None) -> jax.Array:
    """Router/classifier logits (T, n+1) -> class ids (T,); 0 = exact.

    Per-request QoS: ``tier`` ((T,) int32 in [0, n_tiers)) indexes
    ``tier_margins`` ((n_tiers,) float32, a TRACED vector — margins change
    per call without retracing), a per-tier bias added to the EXACT-path
    logit before the argmax.  A positive margin makes the exact path win
    ties it would otherwise lose (tighter error bound, less invocation); a
    negative margin hands borderline rows to their best approximator
    (looser bound, more invocation).  ``tier=None`` — or any tier whose
    margin is 0.0 — reproduces the plain argmax bit-for-bit (x + 0.0
    changes no float comparison), so a uniform default-tier batch routes
    exactly as the margin-free engine did.
    """
    lg = logits.astype(jnp.float32)
    if tier is not None and tier_margins is not None:
        lg = lg.at[:, 0].add(tier_margins.astype(jnp.float32)[tier])
    return jnp.argmax(lg, -1).astype(jnp.int32)


def apply_approximator(xb: jax.Array, w1: jax.Array, b1: jax.Array,
                       w2: jax.Array, b2: jax.Array) -> jax.Array:
    """One approximator's tanh MLP on a row block — the single definition
    of the per-class math shared by the XLA oracle backend and the manual
    sharded serve path (models/approx_ffn._approx_serve_manual)."""
    h = jnp.tanh(jnp.dot(xb, w1.astype(xb.dtype)) + b1.astype(xb.dtype))
    return jnp.dot(h, w2.astype(xb.dtype)) + b2.astype(xb.dtype)


def _rank_in_class(cls: jax.Array, n_classes: int) -> jax.Array:
    """rank[i] = #rows j<=i with cls[j]==cls[i], minus one (arrival order)."""
    oh = jax.nn.one_hot(cls, n_classes, dtype=jnp.int32)      # (T, n_classes)
    return jnp.take_along_axis(jnp.cumsum(oh, 0) - 1, cls[:, None], 1)[:, 0]


# ---------------------------------------------------------------------------
# Shared capacity gather/scatter primitives.  These four functions are the
# ONE implementation of sort-based capacity dispatch in the repo: the MCMA
# engine below, the manual expert-parallel MoE path (models/moe.py), and
# the grouped MoE reference all build on them.
# ---------------------------------------------------------------------------

def class_sort_ranks(cls: jax.Array, n: int):
    """Stable class-sort with within-class arrival ranks.

    cls: (R,) int32 in [0, n).  Returns ``(order, cls_sorted, rank,
    counts)``: visiting rows in ``order`` walks class 0 first, then 1, ...;
    ``rank[i]`` is row ``order[i]``'s arrival rank within its class;
    ``counts`` is the per-class histogram (length n).
    """
    order = jnp.argsort(cls, stable=True)
    cls_sorted = cls[order]
    counts = jnp.bincount(cls, length=n)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)])
    rank = jnp.arange(cls.shape[0]) - starts[cls_sorted]
    return order, cls_sorted, rank, counts


def capacity_slots(cls_sorted: jax.Array, rank: jax.Array, cap: int, *,
                   n_local: int, offset=0):
    """keep mask + buffer slots for a (n_local, cap) capacity buffer.

    Rows of classes outside [offset, offset + n_local) or ranked past
    ``cap`` fall into the trash slot ``n_local * cap`` (the GShard
    convention — dropped rows contribute zero).  ``offset`` may be traced
    (e.g. this model-shard's first expert id).
    """
    local = (cls_sorted >= offset) & (cls_sorted < offset + n_local)
    keep = (rank < cap) & local
    slot = jnp.where(keep, (cls_sorted - offset) * cap + rank, n_local * cap)
    return keep, slot


def scatter_rows(rows: jax.Array, slot: jax.Array, keep: jax.Array,
                 n_slots: int) -> jax.Array:
    """rows (R, d) -> (n_slots, d) buffer; slot n_slots is the trash row.

    Degenerate slots are PINNED, not incidental (the same contract as
    ops.gather_resident_stacks): a slot outside [0, n_slots] is dropped —
    routed to the trash row, never wrapped onto a real slot the way
    jit's negative-index semantics would — and duplicate slots resolve
    deterministically by summation (the buffer is zero-initialized, so
    the engine's unique valid slots are written bit-identically to a
    plain set).
    """
    slot = slot.astype(jnp.int32)
    ok = keep & (slot >= 0) & (slot <= n_slots)
    buf = jnp.zeros((n_slots + 1, rows.shape[-1]), rows.dtype)
    return buf.at[jnp.where(ok, slot, n_slots)] \
        .add(rows * ok[:, None])[:n_slots]


def gather_rows(y: jax.Array, slot: jax.Array, keep: jax.Array) -> jax.Array:
    """(n_slots, d_out) buffer -> per-row outputs; dropped rows are zero.

    A slot outside [0, n_slots) reads the appended zero row (pinned like
    ops.gather_resident_stacks — never jit's clamp onto a real slot) and
    the row comes out exactly zero; duplicate slots simply duplicate the
    buffer row.
    """
    n_slots = y.shape[0]
    y = jnp.concatenate([y, jnp.zeros((1, y.shape[-1]), y.dtype)], 0)
    slot = slot.astype(jnp.int32)
    ok = keep & (slot >= 0) & (slot < n_slots)
    return y[jnp.where(ok, slot, n_slots)] * ok[:, None]


def capacity_path(x: jax.Array, mask: jax.Array, cap: int,
                  fn: Callable[[jax.Array], jax.Array]) -> jax.Array:
    """Gather <=cap rows where mask, apply fn, scatter back (zeros elsewhere).

    Static shapes throughout: rows ranked past ``cap`` fall into a trash
    slot and contribute zero — identical math to the seed's serve path.
    """
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1               # rank in class
    keep = mask & (pos < cap)
    slot = jnp.where(keep, pos, cap)                           # cap = trash
    y = fn(scatter_rows(x, slot, keep, cap))
    return gather_rows(y, slot, keep)


# ---------------------------------------------------------------------------
# Plan/execute: the routing decision as a first-class value.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DispatchPlan:
    """One routing decision over a flat row batch, ready to execute.

    Everything here depends on the router LOGITS (and the capacities) but
    not on any layer's weights, so a plan built once per decode tick can
    be replayed against every layer of the scan.  Array fields (traced,
    pytree data):

      cls         (T,) int32 routed class per row (0 = exact; inactive
                  rows under ``row_mask`` are forced to 0)
      rank        (T,) int32 within-class arrival rank of every row
      eff         (T,) int32 kernel class ids: kept approx rows keep
                  ``cls - 1``; exact / over-capacity / inactive rows get
                  the zero-weight pseudo-class ``n_approx``
      order, pos  the class-sort permutation of ``eff`` and each row's
                  padded single-class-tile position (ops.class_sort_plan;
                  identity/zero placeholders on "xla" plans — only the
                  Pallas executor consumes the sort)
      tile_cls    (t_pad // block_t,) per-tile class for the weight switch
      exact_keep  (T,) bool — class-0 rows inside the exact capacity
      exact_slot  (T,) int32 capacity-buffer slot (exact_cap = trash)
      counts      (n_approx + 1,) routed rows per class
      dispatched  (n_approx + 1,) post-capacity executed rows per class
      t_total     () int32 active rows
      executed    () int32 rows of compute the executor will launch
      tier        (T,) int32 per-row QoS tier (zeros when the plan was
                  built without a tier vector)
      tier_counts (n_tiers, n_approx + 1) routed rows per tier per class
                  (sums over tiers to ``counts``)
      tier_dispatched  (n_tiers, n_approx + 1) post-capacity executed
                  rows per tier per class (sums over tiers to
                  ``dispatched`` — capacity is tier-blind arrival order,
                  the split just attributes the kept rows)
      lib_counts  (library_size + 1,) routed rows per LIBRARY class when
                  the plan was built with a ``residency`` map (the
                  ResidencyController's demand signal — entry 0 is the
                  router's own exact votes, off-set classes keep their
                  library column); equals ``counts`` on library-less plans
      off_set_rows () int32 active rows routed to a library class with no
                  resident slot this tick — they fall back to the exact
                  path (inside ``counts[0]``), so invocation honestly
                  reflects the residency penalty

    ``counts``/``dispatched``/``t_total``/``executed``, the per-tier
    count matrices, and ``lib_counts``/``off_set_rows`` are psum-reduced
    GLOBAL totals when the plan is built with ``stats_axes`` inside a
    shard_map; the row-shaped fields stay shard-local.  Static metadata
    (pytree aux): ``n_approx``, the capacities (``invoke_cap`` is an int
    for the uniform budget or a per-class tuple for asymmetric ones —
    ``class_caps`` normalizes), ``block_t``, ``backend``, ``n_tiers``,
    and ``library_size`` (0 = built without a residency map).
    """

    cls: jax.Array
    rank: jax.Array
    eff: jax.Array
    order: jax.Array
    pos: jax.Array
    tile_cls: jax.Array
    exact_keep: jax.Array
    exact_slot: jax.Array
    counts: jax.Array
    dispatched: jax.Array
    t_total: jax.Array
    executed: jax.Array
    tier: jax.Array
    tier_counts: jax.Array
    tier_dispatched: jax.Array
    lib_counts: jax.Array
    off_set_rows: jax.Array
    n_approx: int
    exact_cap: int
    invoke_cap: int | tuple
    block_t: int
    backend: str
    n_tiers: int
    library_size: int

    @property
    def class_caps(self) -> tuple:
        """Per-class invoke capacities, length ``n_approx`` (normalizes
        the uniform-int and asymmetric-tuple forms of ``invoke_cap``)."""
        ic = self.invoke_cap
        return tuple(ic) if isinstance(ic, (tuple, list)) \
            else (ic,) * self.n_approx


_PLAN_DATA = ("cls", "rank", "eff", "order", "pos", "tile_cls",
              "exact_keep", "exact_slot", "counts", "dispatched",
              "t_total", "executed", "tier", "tier_counts",
              "tier_dispatched", "lib_counts", "off_set_rows")
_PLAN_META = ("n_approx", "exact_cap", "invoke_cap", "block_t", "backend",
              "n_tiers", "library_size")

jax.tree_util.register_pytree_node(
    DispatchPlan,
    lambda p: (tuple(getattr(p, f) for f in _PLAN_DATA),
               tuple(getattr(p, f) for f in _PLAN_META)),
    lambda meta, data: DispatchPlan(*data, *meta))


def make_dispatch_plan(logits: jax.Array,
                       row_mask: jax.Array | None = None, *,
                       exact_cap: int | None = None,
                       invoke_cap=None,
                       operating_point=None, backend: str = "xla",
                       block_t: int = 128,
                       stats_axes: tuple = (),
                       tier: jax.Array | None = None,
                       tier_margins: jax.Array | None = None,
                       n_tiers: int | None = None,
                       residency: jax.Array | None = None) -> DispatchPlan:
    """classify -> capacity -> class-sort, once, as a reusable plan.

    logits: (T, n_approx + 1) router/classifier scores (class 0 = exact);
    ``row_mask`` marks ACTIVE rows exactly as in ``mcma_dispatch``.
    Capacities come either from explicit ``exact_cap``/``invoke_cap``
    (``invoke_cap`` is an int shared by every class or a length-n_approx
    tuple of asymmetric per-class budgets) or from an ``operating_point``
    (runtime/autotune.OperatingPoint, applied to this batch's row count
    via sharding/rules.shard_capacity; its ``invoke_fracs`` yield the
    per-class form).  ``stats_axes`` psum-reduces the count fields to
    global totals when building inside a shard_map — build and consume
    the plan inside the same shard_map region
    (sharding/rules.dispatch_plan_specs describes how its fields shard
    between the two).

    Per-request QoS: ``tier`` ((T,) int32) + ``tier_margins`` ((n_tiers,)
    float32, TRACED — one compiled program serves every margin setting)
    replace the plain argmax with the tier-indexed exact-logit margin
    (see ``route``), and the plan's ``tier_counts``/``tier_dispatched``
    split the routed/executed rows per tier.  ``tier=None`` keeps the
    margin-free routing bit-for-bit and records everything as tier 0.

    Approximator-library residency: with ``residency`` ((n_resident,)
    int32 of library ids, TRACED — a hot-set swap is a new vector through
    the same compiled program), ``logits`` carry ``library_size + 1``
    columns and routing happens over the FULL library; a slot map then
    folds each library class onto its resident slot (or onto the exact
    path when the class is off-set this tick).  The plan's ``n_approx``
    stays the RESIDENT slot count — capacities, class-sort, and the
    executor are untouched — while ``lib_counts`` keeps the full-library
    demand histogram (the ResidencyController's signal) and
    ``off_set_rows`` counts the rows paying the residency penalty.
    """
    t = logits.shape[0]
    if residency is not None:
        library_size = logits.shape[-1] - 1
        n = int(residency.shape[0])
        assert n <= library_size, (
            f"residency map holds {n} slots but the library has only "
            f"{library_size} approximators")
    else:
        library_size = 0
        n = logits.shape[-1] - 1
    if operating_point is not None:
        from repro.sharding.rules import shard_capacity
        assert exact_cap is None and invoke_cap is None, \
            "pass capacities OR an operating_point, not both"
        exact_cap = shard_capacity(t, operating_point.exact_frac,
                                   slack=operating_point.shard_slack)
        if operating_point.invoke_fracs:
            invoke_cap = tuple(
                shard_capacity(t, f, slack=operating_point.shard_slack)
                for f in operating_point.class_fracs(n))
        else:
            invoke_cap = shard_capacity(t, operating_point.invoke_frac,
                                        slack=operating_point.shard_slack)
    if isinstance(invoke_cap, list):
        invoke_cap = tuple(invoke_cap)      # hashable pytree meta
    class_caps = tuple(invoke_cap) if isinstance(invoke_cap, tuple) \
        else (int(invoke_cap),) * n
    assert len(class_caps) == n, (
        f"per-class invoke_cap tuple (len {len(class_caps)}) must carry "
        f"one budget per approximator (n_approx={n})")

    # tier bookkeeping: the static tier count comes from the margins
    # vector (or the explicit n_tiers override); tier-less plans carry a
    # single tier 0 so the per-tier stats schema is backend- and
    # caller-independent.  A tier vector without either is refused: the
    # per-tier bincount would silently drop every tier >= 1 row (and no
    # margin would apply), corrupting the QoS stats instead of failing.
    assert tier is None or tier_margins is not None or n_tiers is not None, \
        "tiered dispatch needs the (n_tiers,) tier_margins vector (or an " \
        "explicit n_tiers) alongside the tier ids"
    nt = int(tier_margins.shape[0]) if tier_margins is not None \
        else int(n_tiers or 1)
    tier_ids = jnp.zeros((t,), jnp.int32) if tier is None \
        else tier.astype(jnp.int32)

    cls = route(logits, None if tier is None else tier_ids, tier_margins)
    lib_cls = cls
    if residency is not None:
        # fold library classes onto resident slots: slot_map[lib id + 1] =
        # resident slot + 1, everything else (exact votes AND off-set
        # classes) lands on 0 = the exact path.  The fold happens BEFORE
        # capacity/class-sort, so downstream the plan is indistinguishable
        # from an n_resident-approximator plan.
        slot_map = jnp.zeros((library_size + 1,), jnp.int32) \
            .at[residency.astype(jnp.int32) + 1] \
            .set(jnp.arange(1, n + 1, dtype=jnp.int32))
        cls = slot_map[lib_cls]
    if row_mask is not None:
        mask = row_mask.astype(bool)
        # inactive rows: class 0 so they never claim an approximator rank;
        # the exact keep below additionally excludes them via the mask,
        # and the sentinel class n+1 keeps them out of counts.
        cls = jnp.where(mask, cls, 0)
        routed_col = jnp.where(mask, cls, n + 1)
        counts = jnp.bincount(routed_col, length=n + 2)[:n + 1]
        exact_mask = (cls == 0) & mask
        t_total = jnp.sum(mask.astype(jnp.int32))
    else:
        routed_col = cls
        counts = jnp.bincount(cls, length=n + 1)
        exact_mask = cls == 0
        t_total = jnp.asarray(t, jnp.int32)
    # per-tier routed split (sentinel column n+1 absorbs inactive rows)
    tier_counts = jnp.bincount(tier_ids * (n + 2) + routed_col,
                               length=nt * (n + 2)) \
        .reshape(nt, n + 2)[:, :n + 1]

    # library demand histogram + off-set accounting (residency plans only):
    # lib_counts keeps the router's FULL-library votes (off-set classes
    # keep their own column — the promotion signal), off_set_rows counts
    # the active rows folded onto the exact path for lack of a slot.
    if residency is not None:
        off_mask = (lib_cls > 0) & (cls == 0)
        if row_mask is not None:
            lib_col = jnp.where(mask, lib_cls, library_size + 1)
            off_mask = off_mask & mask
        else:
            lib_col = lib_cls
        lib_counts = jnp.bincount(lib_col, length=library_size + 2) \
            [:library_size + 1]
        off_set_rows = jnp.sum(off_mask.astype(jnp.int32))
    else:
        lib_counts = counts
        off_set_rows = jnp.zeros((), jnp.int32)

    # approximator side: capacity first, then the single-class-tile sort
    # of the effective classes (kept rows keep cls-1; exact/over-capacity/
    # inactive rows ride the zero-weight pseudo-class n).  Only the Pallas
    # executor consumes the sort fields (the XLA oracle re-derives per-class
    # slots from cls/rank), so an "xla" plan carries cheap identity/zero
    # placeholders of the same shapes instead of paying a dead argsort —
    # the plan SCHEMA is backend-independent, the sort work is not.
    rank = _rank_in_class(cls, n + 1)
    cap_of = jnp.asarray((0,) + class_caps, jnp.int32)
    kept = (cls > 0) & (rank < cap_of[cls])
    eff = jnp.where(kept, cls - 1, n).astype(jnp.int32)
    if backend in PALLAS_BACKENDS:
        order, pos, tile_cls, _, _ = ops.class_sort_plan(eff, n + 1, block_t)
    else:
        n_tiles = ops.worst_case_rows(t, n + 1, block_t) // block_t
        order = pos = jnp.arange(t, dtype=jnp.int32)
        tile_cls = jnp.zeros((n_tiles,), jnp.int32)

    # exact ("nC") side: capacity-buffer keep/slot (exact_cap = trash)
    epos = jnp.cumsum(exact_mask.astype(jnp.int32)) - 1
    exact_keep = exact_mask & (epos < exact_cap)
    exact_slot = jnp.where(exact_keep, epos, exact_cap)

    caps = jnp.asarray([exact_cap, *class_caps], counts.dtype)
    dispatched = jnp.minimum(counts, caps)
    # per-tier dispatched split: capacity keeps rows by tier-blind arrival
    # rank, so attributing the KEPT rows (exact_keep | kept) to their tier
    # sums back to ``dispatched`` per class exactly
    disp_col = jnp.where(exact_keep | kept, cls, n + 1)
    tier_dispatched = jnp.bincount(tier_ids * (n + 2) + disp_col,
                                   length=nt * (n + 2)) \
        .reshape(nt, n + 2)[:, :n + 1]
    if backend in PALLAS_BACKENDS:
        # both Pallas executors launch the full static worst-case grid
        # (including trailing zero tiles past the occupied region) — n+1
        # classes including the pseudo-class
        executed = jnp.asarray(
            exact_cap + ops.worst_case_rows(t, n + 1, block_t), jnp.int32)
    elif backend == "xla":
        executed = jnp.asarray(exact_cap + sum(class_caps), jnp.int32)
    else:
        raise ValueError(f"unknown dispatch backend: {backend!r}")
    if stats_axes:
        # inside shard_map: reduce to GLOBAL stats.  Each quantity is a sum
        # of per-shard terms, so psum of the local values equals the
        # single-device totals over the same per-shard capacities exactly.
        ax = tuple(stats_axes)
        t_total = jax.lax.psum(t_total, ax)
        counts = jax.lax.psum(counts, ax)
        dispatched = jax.lax.psum(dispatched, ax)
        executed = jax.lax.psum(executed, ax)
        tier_counts = jax.lax.psum(tier_counts, ax)
        tier_dispatched = jax.lax.psum(tier_dispatched, ax)
        if residency is not None:
            lib_counts = jax.lax.psum(lib_counts, ax)
            off_set_rows = jax.lax.psum(off_set_rows, ax)
        else:
            lib_counts = counts        # stay aliased to the reduced counts
    return DispatchPlan(cls=cls, rank=rank, eff=eff, order=order, pos=pos,
                        tile_cls=tile_cls, exact_keep=exact_keep,
                        exact_slot=exact_slot, counts=counts,
                        dispatched=dispatched, t_total=t_total,
                        executed=executed, tier=tier_ids,
                        tier_counts=tier_counts,
                        tier_dispatched=tier_dispatched,
                        lib_counts=lib_counts, off_set_rows=off_set_rows,
                        n_approx=n, exact_cap=exact_cap,
                        invoke_cap=invoke_cap, block_t=block_t,
                        backend=backend, n_tiers=nt,
                        library_size=library_size)


@dataclasses.dataclass(frozen=True)
class InvokeStats:
    """The engine's per-call invocation statistics, typed.

    Every field is a jnp scalar/vector (a pytree of pure data — the class
    is a registered pytree node, so it rides through jit / shard_map /
    ``jax.tree.map(np.asarray, stats)`` like the dict it replaces).  The
    stable public field names:

      class_counts     (n_approx + 1,) routed ACTIVE rows per RESIDENT
                       class (0 = exact); sums to the active row count
      dispatched       (n_approx + 1,) rows actually executed after
                       capacity
      dropped          scalar int, over-capacity rows (zero contribution)
      exact_frac       scalar float, class_counts[0] / active rows
      invocation       scalar float, 1 - exact_frac (the paper's
                       invocation rate; 0.0 on a fully idle batch)
      executed_rows    scalar int, rows of compute actually launched
      padding_rows     scalar int, executed_rows - sum(dispatched)
      tier_counts      (n_tiers, n_approx + 1) routed rows per QoS tier
      tier_dispatched  (n_tiers, n_approx + 1) executed rows per tier
      tier_dropped     (n_tiers,) over-capacity rows per tier
      tier_served_invocation  (n_tiers,) executed approximator rows over
                       that tier's active rows
      lib_counts       (library_size + 1,) routed rows per LIBRARY class
                       under a residency map (equals class_counts on
                       library-less calls) — the promotion signal
      off_set_exact_rows  scalar int, active rows routed to an off-set
                       library class and folded onto the exact path (0
                       without a residency map) — the residency penalty

    Dict-style access (``stats["invocation"]``, ``.get``, ``in``,
    ``dict(stats)``) is kept for existing call sites and the CSV writers;
    ``.asdict()`` is the explicit spelling.
    """

    class_counts: jax.Array
    dispatched: jax.Array
    dropped: jax.Array
    exact_frac: jax.Array
    invocation: jax.Array
    executed_rows: jax.Array
    padding_rows: jax.Array
    tier_counts: jax.Array
    tier_dispatched: jax.Array
    tier_dropped: jax.Array
    tier_served_invocation: jax.Array
    lib_counts: jax.Array
    off_set_exact_rows: jax.Array

    # -- mapping protocol (drop-in for the dict this class replaced) --------
    def __getitem__(self, key: str):
        try:
            return getattr(self, key)
        except AttributeError:
            raise KeyError(key) from None

    def __contains__(self, key) -> bool:
        return key in _STATS_FIELDS

    def __iter__(self):
        # iterate keys like the dict this replaced (without this, the
        # legacy __getitem__ iteration protocol probes stats[0])
        return iter(_STATS_FIELDS)

    def get(self, key: str, default=None):
        return getattr(self, key, default)

    def keys(self):
        return iter(_STATS_FIELDS)

    def items(self):
        return ((f, getattr(self, f)) for f in _STATS_FIELDS)

    def asdict(self) -> dict:
        return {f: getattr(self, f) for f in _STATS_FIELDS}


_STATS_FIELDS = tuple(f.name for f in dataclasses.fields(InvokeStats))

jax.tree_util.register_pytree_node(
    InvokeStats,
    lambda s: (tuple(getattr(s, f) for f in _STATS_FIELDS), None),
    lambda _, data: InvokeStats(*data))


def plan_invoke_stats(plan: DispatchPlan) -> InvokeStats:
    """The engine's ``InvokeStats``, derived from a plan (elementwise —
    cheap to call per layer; identical fields/values to
    ``mcma_dispatch``'s second return).  Already global totals for plans
    built with ``stats_axes``, so no collectives are needed here."""
    exact_frac = (plan.counts[0] / jnp.maximum(plan.t_total, 1)) \
        .astype(jnp.float32)
    # zero active rows (possible under row_mask): report invocation 0, not
    # the 1.0 that 1 - 0/1 would claim for a fully idle batch
    invocation = jnp.where(plan.t_total > 0, 1.0 - exact_frac, 0.0) \
        .astype(jnp.float32)
    tier_rows = jnp.sum(plan.tier_counts, -1)
    return InvokeStats(
        class_counts=plan.counts,
        dispatched=plan.dispatched,
        dropped=jnp.sum(plan.counts - plan.dispatched),
        exact_frac=exact_frac,
        invocation=invocation,
        executed_rows=plan.executed,
        padding_rows=plan.executed
        - jnp.sum(plan.dispatched).astype(jnp.int32),
        # per-tier QoS split (tier 0 only on tier-less plans): routed /
        # post-capacity per class, dropped rows, and the SERVED invocation
        # per tier — approximator rows actually executed over that tier's
        # active rows, the quantity a loose error bound buys more of
        tier_counts=plan.tier_counts,
        tier_dispatched=plan.tier_dispatched,
        tier_dropped=jnp.sum(plan.tier_counts - plan.tier_dispatched, -1),
        tier_served_invocation=(
            jnp.sum(plan.tier_dispatched[:, 1:], -1)
            / jnp.maximum(tier_rows, 1)).astype(jnp.float32),
        lib_counts=plan.lib_counts,
        off_set_exact_rows=plan.off_set_rows)


def execute_dispatch(plan: DispatchPlan, x: jax.Array,
                     exact_fn: Callable[[jax.Array], jax.Array],
                     a_w1: jax.Array, a_b1: jax.Array,
                     a_w2: jax.Array, a_b2: jax.Array, *,
                     interpret: bool = False,
                     weights_prepadded: bool = False) -> jax.Array:
    """Apply one layer's approximators + exact path against a plan.

    x: (T, d) rows in ORIGINAL order (the plan's permutation is applied
    internally); returns (T, d_out) in original order.  Both backends
    consume the same plan — ``plan.backend`` picks the executor — so the
    Pallas path stays bit-exact against the XLA oracle under plan reuse.
    No routing, sorting, or counting happens here: at tick scope this is
    the entire per-layer cost.
    """
    n = plan.n_approx
    assert a_w1.shape[0] - (1 if weights_prepadded else 0) == n, (
        f"approximator stack (leading dim {a_w1.shape[0]}, "
        f"weights_prepadded={weights_prepadded}) does not match the plan's "
        f"n_approx={n}")
    # exact ("nC") rows: capacity gather -> exact_fn -> scatter-back
    xg = scatter_rows(x, plan.exact_slot, plan.exact_keep, plan.exact_cap)
    out = gather_rows(exact_fn(xg), plan.exact_slot, plan.exact_keep)

    if plan.backend == "xla":
        d_out = out.shape[-1]
        for i, cap_i in enumerate(plan.class_caps):
            if weights_prepadded:
                # logical views of the padded stacks; padded regions are
                # exact zeros, so the sliced math is unchanged
                d_in = x.shape[1]
                def approx_i(xb, i=i):
                    return apply_approximator(
                        xb, a_w1[i, :d_in], a_b1[i],
                        a_w2[i][:, :d_out], a_b2[i, :d_out])
            else:
                def approx_i(xb, i=i):
                    return apply_approximator(xb, a_w1[i], a_b1[i],
                                              a_w2[i], a_b2[i])
            keep = (plan.cls == i + 1) & (plan.rank < cap_i)
            slot = jnp.where(keep, plan.rank, cap_i)
            xb = scatter_rows(x, slot, keep, cap_i)
            out = out + gather_rows(approx_i(xb), slot, keep)
    else:  # pallas family — validated by make_dispatch_plan
        # one grouped kernel launch over ALL rows on the plan's precomputed
        # class-sort: exact + over-capacity (and masked-inactive) rows ride
        # the zero-weight pseudo-class n, whose tiles compute exact zeros
        # (tanh(0)@0 + 0), so no post-mask is needed.  "pallas_fused" runs
        # the same plan through the fused kernel — the sort permutation is
        # scalar-prefetched and the standalone gather/scatter legs vanish.
        apply = ops.switched_apply if plan.backend == "pallas" \
            else ops.switched_apply_fused
        sort_plan = (plan.order, plan.pos, plan.tile_cls)
        if weights_prepadded:
            out = out + apply(
                x, plan.eff, a_w1, a_b1, a_w2, a_b2, block_t=plan.block_t,
                interpret=interpret, prepadded=True, d_out=out.shape[-1],
                sort_plan=sort_plan)
        else:
            zcls = lambda w: jnp.concatenate([w, jnp.zeros_like(w[:1])], 0)
            out = out + apply(
                x, plan.eff, zcls(a_w1), zcls(a_b1), zcls(a_w2), zcls(a_b2),
                block_t=plan.block_t, interpret=interpret,
                sort_plan=sort_plan)
    return out


def mcma_dispatch(x: jax.Array, logits: jax.Array,
                  exact_fn: Callable[[jax.Array], jax.Array],
                  a_w1: jax.Array, a_b1: jax.Array,
                  a_w2: jax.Array, a_b2: jax.Array, *,
                  exact_cap: int, invoke_cap, backend: str = "xla",
                  block_t: int = 128, interpret: bool = False,
                  stats_axes: tuple = (), row_mask: jax.Array | None = None,
                  weights_prepadded: bool = False,
                  tier: jax.Array | None = None,
                  tier_margins: jax.Array | None = None,
                  residency: jax.Array | None = None):
    """Full MCMA invocation pipeline over a flat row batch.

    x: (T, d); logits: (T, n_approx+1) router scores (class 0 = exact);
    exact_fn: (cap, d) -> (cap, d_out) exact path applied to the gathered
    class-0 buffer; a_*: stacked approximator weights, leading dim n_approx.
    ``exact_cap``/``invoke_cap``/``backend``/``block_t``/``interpret`` must
    be static under jit (they determine shapes / the traced program).

    ``stats_axes``: mesh axis names to ``psum`` the invoke_stats over when
    the call runs inside a ``shard_map`` (the compute stays fully local to
    each shard — only the scalar/per-class stats are reduced, so every
    shard reports the GLOBAL totals: counts/dispatched/dropped/executed
    summed across shards, exact_frac/invocation over the global row count).
    Empty (the default) outside shard_map.

    ``row_mask``: optional (T,) bool marking ACTIVE rows.  Inactive rows
    (e.g. a decode server's free slots, fed token 0) are forced out of
    every path: they take no class, consume no capacity slot, and are
    excluded from every stat (t_total = active rows) — so ``invocation``/
    ``exact_frac`` and the autotuner signal stay exact on partially-full
    slot tables instead of being polluted by routed garbage.  None (the
    default) treats every row as active and traces the exact same program
    as before the mask existed.

    ``weights_prepadded``: the a_* stacks are already in serving form
    (kernels/ops.prepad_switched_weights — one zero pseudo-class appended,
    feature dims lane-padded), so the Pallas path ships them to the kernel
    with zero per-call copies and the XLA oracle slices logical views.

    ``tier``/``tier_margins``: per-request QoS (see ``route`` /
    ``make_dispatch_plan``) — the per-row tier indexes a traced per-tier
    exact-logit margin, and the returned stats gain the per-tier
    ``tier_counts``/``tier_dispatched``/``tier_dropped``/
    ``tier_served_invocation`` split.  ``invoke_cap`` may be a per-class
    tuple (asymmetric capacities, e.g. from
    runtime/autotune.ladder_from_counts).

    ``residency``: optional (n_resident,) int32 of LIBRARY ids.  The a_*
    stacks then hold the FULL prepadded library (leading dim
    library_size + 1, zero pseudo-class last) and ``logits`` carry
    ``library_size + 1`` columns; the resident rows are gathered out
    (kernels/ops.gather_resident_stacks) and library classes fold onto
    resident slots in the plan (see ``make_dispatch_plan``).  Because the
    map is traced data, a hot-set swap is a new vector through the SAME
    compiled program — zero retraces.

    Returns ``(y, invoke_stats)`` with y: (T, d_out) in the original row
    order and invoke_stats an ``InvokeStats`` (typed, dict-style access —
    see its docstring for the field inventory).
    """
    if residency is not None:
        assert weights_prepadded, (
            "library residency requires prepadded stacks "
            "(ops.prepad_switched_weights over the full library)")
        assert logits.shape[-1] == a_w1.shape[0], (
            f"router width {logits.shape[-1]} != library_size + 1 = "
            f"{a_w1.shape[0]}: with a residency map the logits must cover "
            "the FULL library (pseudo-class excluded)")
        a_w1, a_b1, a_w2, a_b2 = ops.gather_resident_stacks(
            a_w1, a_b1, a_w2, a_b2, residency)
    n = a_w1.shape[0] - (1 if weights_prepadded else 0)
    # schema guard: the router always has n_approx+1 classes, so a stack
    # whose leading dim disagrees (e.g. a pre-serving-form checkpoint fed
    # through weights_prepadded=True, where the last REAL approximator
    # would silently play the zero pseudo-class) fails loudly here
    assert residency is not None or logits.shape[-1] == n + 1, (
        f"router width {logits.shape[-1]} != n_approx + 1 = {n + 1}: "
        f"approximator stack (leading dim {a_w1.shape[0]}, "
        f"weights_prepadded={weights_prepadded}) does not match — "
        "prepadded stacks must come from ops.prepad_switched_weights")
    plan = make_dispatch_plan(logits, row_mask, exact_cap=exact_cap,
                              invoke_cap=invoke_cap, backend=backend,
                              block_t=block_t, stats_axes=stats_axes,
                              tier=tier, tier_margins=tier_margins,
                              residency=residency)
    out = execute_dispatch(plan, x, exact_fn, a_w1, a_b1, a_w2, a_b2,
                           interpret=interpret,
                           weights_prepadded=weights_prepadded)
    return out, plan_invoke_stats(plan)


def mcma_dispatch_sharded(mesh, x: jax.Array, logits: jax.Array,
                          exact_fn: Callable[[object, jax.Array], jax.Array],
                          exact_params,
                          a_w1: jax.Array, a_b1: jax.Array,
                          a_w2: jax.Array, a_b2: jax.Array, *,
                          exact_cap: int, invoke_cap,
                          backend: str = "xla", block_t: int = 128,
                          interpret: bool = False, data_axes=None,
                          row_mask: jax.Array | None = None,
                          weights_prepadded: bool = False,
                          tier: jax.Array | None = None,
                          tier_margins: jax.Array | None = None,
                          residency: jax.Array | None = None):
    """``mcma_dispatch`` shard_mapped over a mesh's data axes.

    x/logits are row-sharded over the data axes (specs from
    sharding/rules.mcma_dispatch_specs); the router/approximator/exact
    weights are replicated.  ``exact_cap``/``invoke_cap`` are PER-SHARD
    capacities (each shard dispatches its local rows — derive them from a
    global operating point with sharding/rules.shard_capacity).
    ``exact_fn`` takes ``(exact_params, xb)`` so the exact weights ride
    through shard_map as an explicit (replicated) argument rather than a
    closure.  ``row_mask`` (optional, (T,) bool, row-sharded like x) marks
    active rows; inactive rows are excluded from dispatch and from the
    psum-reduced stats on every shard.  ``tier`` (optional, (T,) int32,
    row-sharded like x) + ``tier_margins`` ((n_tiers,) float32,
    replicated) apply the per-request QoS margins per shard; the per-tier
    stats are psum-reduced like every other count.  ``residency``
    (optional, (n_resident,) int32, replicated) enables library routing
    exactly as in ``mcma_dispatch`` — the off-set/library stats are
    psum-reduced too.

    Returns ``(y, invoke_stats)``: y row-sharded like x, invoke_stats
    psum-reduced to the global totals (replicated on every shard).
    """
    from repro.sharding.compat import shard_map_compat
    from repro.sharding.rules import dp_axes, mcma_dispatch_specs
    dp = tuple(data_axes) if data_axes is not None else dp_axes(mesh)
    specs = mcma_dispatch_specs(mesh, data_axes=dp,
                                with_mask=row_mask is not None,
                                with_tier=tier is not None,
                                with_residency=residency is not None)
    has_mask, has_tier = row_mask is not None, tier is not None
    has_res = residency is not None

    def local(x_l, lg_l, ep, w1, b1, w2, b2, *extra):
        extra = list(extra)
        m_l = extra.pop(0) if has_mask else None
        t_l, tm = (extra.pop(0), extra.pop(0)) if has_tier else (None, None)
        res = extra.pop(0) if has_res else None
        return mcma_dispatch(
            x_l, lg_l, partial(exact_fn, ep), w1, b1, w2, b2,
            exact_cap=exact_cap, invoke_cap=invoke_cap, backend=backend,
            block_t=block_t, interpret=interpret, stats_axes=dp,
            row_mask=m_l, weights_prepadded=weights_prepadded,
            tier=t_l, tier_margins=tm, residency=res)

    fn = shard_map_compat(local, mesh=mesh, in_specs=specs["in"],
                          out_specs=specs["out"],
                          axis_names=frozenset(dp), check=False)
    args = (x, logits, exact_params, a_w1, a_b1, a_w2, a_b2)
    if has_mask:
        args = args + (row_mask,)
    if has_tier:
        assert tier_margins is not None, \
            "sharded tiered dispatch needs the (n_tiers,) margins vector"
        args = args + (tier, tier_margins)
    if has_res:
        args = args + (residency,)
    return fn(*args)
