"""Online capacity autotuning from served invoke_stats.

The paper maximizes approximator invocation, but the serve-mode capacity
fractions (``ApproxConfig.exact_frac``/``invoke_frac``) are static: a class
that runs hot drops rows (zero contribution — quality loss carried by the
residual) while other classes' capacity slots sit idle as pure padding.
QoS-Nets-style, approximate inference should move between OPERATING POINTS
at runtime from the observed load — and the psum-reduced global
``invoke_stats`` every ``mcma_dispatch`` call returns are exactly that
signal (per-class routed counts, post-capacity dispatched counts, dropped
rows; exact on partially-full slot tables since the free-slot router bias
fix, see runtime/dispatch.mcma_dispatch's ``row_mask``).

Capacities determine SHAPES, so adaptation cannot be a traced knob:
instead the controller selects among a small static ladder of
``OperatingPoint``s, each corresponding to one precompiled jitted step
(the server keeps one decode step per rung and switches between them —
no retracing after first use of a rung).

Control law (deliberately boring — it must never thrash a serving fleet):

  * objective: keep the EMA of the dropped-row fraction under
    ``drop_budget`` while running the CHEAPEST rung that does so (cheap =
    least executed capacity; dropping to a cheaper rung both saves padded
    compute and, on the rungs below the mix's demand, trades invocation
    away — so "cheapest rung under budget" IS "max invocation at min
    cost" for a monotone ladder);
  * step UP (more capacity) when the EMA violates the budget: jump
    directly to the first rung whose PREDICTED drop fraction — replaying
    the observed per-class routed counts against that rung's capacities —
    meets the budget (observed drops at the current rung only say "not
    enough"; the prediction says how much is);
  * step DOWN one rung only after ``down_patience`` consecutive ticks in
    which the next-cheaper rung's predicted drop fraction stays under
    ``down_margin * drop_budget`` (hysteresis: the down-threshold is
    stricter than the up-threshold, so the controller never oscillates
    between two rungs on a steady mix);
  * ``cooldown`` ticks of silence after every switch (a switch changes
    the stats distribution; judging the new rung on the old EMA would
    double-trigger);
  * exponential DOWN-BACKOFF: the prediction can be systematically
    optimistic (layer-MEANED counts hide per-layer class concentration;
    global counts hide cross-shard skew), so a rung that dropped rows and
    forced a re-escalation shortly after the controller stepped down into
    it doubles the patience required before the next down attempt — a
    persistently deceptive mix converges to "sit on the safe rung"
    instead of thrashing the step cache.

On a mesh the prediction uses GLOBAL counts against GLOBAL capacities
(per-shard capacity x shard count), which is optimistic under cross-shard
skew — a shard-hot class can still drop rows at a rung the prediction
cleared.  That is safe: the up-rule is driven by OBSERVED drops, so the
controller simply climbs one more rung (or the ladder carries rungs with
``shard_slack`` > 1, the per-shard rebalancing headroom of
sharding/rules.shard_capacity).

Under tick-scope routing (``ApproxConfig.route_scope="tick"``, PR 4) the
per-layer-mean optimism disappears entirely: ONE DispatchPlan per decode
tick means every layer reports the same per-class counts, so the
controller's observation IS the tick's exact routed mix — one clean
sample per tick instead of a mean of L noisy per-layer decisions, and
the replay prediction is exact up to cross-shard skew.  The control law
is unchanged (it is scale-free in t and never assumed per-layer
variance); only the down-backoff's reason to exist shrinks.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    """One rung of the capacity ladder — a full serve-capacity config.

    ``exact_frac``/``invoke_frac`` are the capacity fractions baked into
    the jitted step's shapes; ``shard_slack`` over-provisions per-shard
    budgets against cross-shard class skew (sharding/rules.shard_capacity).

    ``invoke_fracs`` (optional, length n_approx) replaces the single
    shared ``invoke_frac`` with an ASYMMETRIC per-class capacity vector —
    ``ladder_from_counts`` derives these from served class-count
    quantiles so a heavy-tailed mix buys its hot class capacity instead
    of padding every cold one.  ``tier_margins`` are the per-tier
    exact-logit router margins of this rung; unlike the capacity fields
    they are TRACED inputs of the decode step (margins change routing,
    not shapes), so two rungs differing only in margins share one
    compiled program — the CapacityController invariant "capacities are
    shapes, precompiled per rung" is untouched.
    """

    exact_frac: float
    invoke_frac: float
    shard_slack: float = 1.0
    invoke_fracs: tuple = ()
    tier_margins: tuple = ()

    def class_fracs(self, n_approx: int) -> tuple:
        """Per-class invoke fractions, length ``n_approx``."""
        if self.invoke_fracs:
            assert len(self.invoke_fracs) == n_approx, \
                (self.invoke_fracs, n_approx)
            return tuple(self.invoke_fracs)
        return (self.invoke_frac,) * n_approx

    def cost(self, n_approx: int) -> float:
        """Relative executed capacity (rows of compute per input row)."""
        return (self.exact_frac + sum(self.class_fracs(n_approx))) \
            * self.shard_slack


def default_ladder(cfg) -> tuple[OperatingPoint, ...]:
    """A small ladder bracketing the static config's operating point.

    Rungs are ordered by cost: half capacity (light mixes), the static
    config itself, 1.5x headroom, and a full-capacity top rung that can
    never drop a row — the controller's escape hatch for adversarial
    mixes.  Capacity fractions saturate at 1.0 (a capacity past T never
    fills).
    """
    a = cfg.approx
    base = OperatingPoint(a.exact_frac, a.invoke_frac, a.shard_slack)
    rungs = (
        OperatingPoint(min(a.exact_frac * 0.5, 1.0),
                       min(a.invoke_frac * 0.5, 1.0), a.shard_slack),
        base,
        OperatingPoint(min(a.exact_frac * 1.5, 1.0),
                       min(a.invoke_frac * 1.5, 1.0), a.shard_slack),
        OperatingPoint(1.0, 1.0, a.shard_slack),
    )
    # dedup (e.g. exact_frac=1.0 collapses rungs) preserving cost order
    out: list[OperatingPoint] = []
    for r in sorted(rungs, key=lambda r: r.cost(a.n_approx)):
        if not out or r != out[-1]:
            out.append(r)
    return tuple(out)


def point_caps(pt: OperatingPoint, t_local: int, n_approx: int,
               n_shards: int = 1) -> np.ndarray:
    """GLOBAL per-class capacity vector (n_approx + 1,) of a rung — the
    same per-shard formula the dispatch paths use
    (sharding/rules.shard_capacity), summed over shards.  Asymmetric
    rungs (``invoke_fracs``) yield per-class entries."""
    from repro.sharding.rules import shard_capacity
    ec = shard_capacity(t_local, pt.exact_frac, slack=pt.shard_slack)
    ics = [shard_capacity(t_local, f, slack=pt.shard_slack)
           for f in pt.class_fracs(n_approx)]
    return np.asarray([ec * n_shards] + [ic * n_shards for ic in ics],
                      float)


def ladder_from_counts(class_counts, t: int, *,
                       quantiles=(0.5, 0.75, 0.95), headroom: float = 1.1,
                       shard_slack: float = 1.0,
                       tier_margins: tuple = ()) \
        -> tuple[OperatingPoint, ...]:
    """Derive a capacity ladder from the SERVED class-count distribution.

    ``class_counts``: (ticks, n_approx + 1) per-tick routed counts (a
    server's ``routed_per_class`` history; a single (n_approx + 1,)
    vector is treated as one observation); ``t`` is the row count the
    counts were observed over (the server's batch).  For each quantile
    ``q`` one rung is built whose PER-CLASS capacity fraction is that
    class's q-quantile demand (x ``headroom``), so a heavy-tailed mix
    gets an asymmetric ``invoke_fracs`` vector — the hot class's budget
    grows while cold classes stop paying for padding the hand-picked
    shared ``invoke_frac`` forced on them (closes the ROADMAP "autotune
    the ladder itself" item).  A full-capacity escape rung is always
    appended; rungs are cost-ordered and deduped, exactly the contract
    ``CapacityController`` expects of ``default_ladder``.
    """
    c = np.asarray(class_counts, float)
    if c.ndim == 1:
        c = c[None]
    assert c.ndim == 2 and c.shape[1] >= 2, c.shape
    assert t > 0
    n = c.shape[1] - 1
    floor = 1.0 / t                         # shard_capacity's min of 1 row
    rungs = []
    for q in sorted(quantiles):
        demand = np.quantile(c, q, axis=0) * headroom / t
        ef = float(np.clip(demand[0], floor, 1.0))
        ifs = tuple(float(np.clip(v, floor, 1.0)) for v in demand[1:])
        rungs.append(OperatingPoint(ef, max(ifs), shard_slack,
                                    invoke_fracs=ifs,
                                    tier_margins=tuple(tier_margins)))
    rungs.append(OperatingPoint(1.0, 1.0, shard_slack,
                                invoke_fracs=(1.0,) * n,
                                tier_margins=tuple(tier_margins)))
    out: list[OperatingPoint] = []
    for r in sorted(rungs, key=lambda r: r.cost(n)):
        if not out or r != out[-1]:
            out.append(r)
    return tuple(out)


def margins_from_bounds(bounds, base_bound: float,
                        scale: float = 4.0) -> tuple[float, ...]:
    """Per-tier exact-logit margins from per-tier error bounds.

    The router was co-trained with labels computed at ``base_bound``, so
    its logits encode "best approximator beats the bound" at that one
    quality level.  A tier demanding a TIGHTER bound should win more
    borderline rows for the exact path (positive margin), a looser one
    fewer (negative): ``margin = scale * log(base_bound / bound)`` is the
    monotone log-odds-style map (zero exactly at the trained bound).
    ``scale`` calibrates logit units per factor-of-e of bound; the
    margins are traced serve inputs, so recalibrating never recompiles.
    """
    assert base_bound > 0
    return tuple(float(scale * np.log(base_bound / b)) for b in bounds)


def default_tier_bounds(base_bound: float,
                        spread: float = 2.0) -> tuple[float, ...]:
    """Ascending (tight, base, loose) error-bound rungs bracketing a
    trained/base quality bound — the server's default QoS tier table."""
    assert base_bound > 0 and spread > 1.0
    return (base_bound / spread, base_bound, base_bound * spread)


@dataclasses.dataclass
class Switch:
    """One ladder move, recorded for the trajectory."""

    tick: int
    from_index: int
    to_index: int
    drop_ema: float


class CapacityController:
    """Selects the active ladder rung from per-tick global invoke_stats.

    ``caps_fn(point) -> (n+1,) global capacity vector`` tells the
    controller what each rung would dispatch (servers build it from their
    batch/mesh geometry via ``point_caps``).  ``observe`` consumes one
    tick's stats (``class_counts``, ``dropped`` — layer-meaned values are
    fine, the law is scale-free in t) and returns the rung index to use
    for the NEXT tick.
    """

    def __init__(self, ladder: Sequence[OperatingPoint],
                 caps_fn: Callable[[OperatingPoint], np.ndarray], *,
                 drop_budget: float = 0.05, ema: float = 0.5,
                 down_patience: int = 8, down_margin: float = 0.5,
                 cooldown: int = 3, start: int | None = None):
        assert len(ladder) >= 1
        assert 0.0 < drop_budget < 1.0
        self.ladder = tuple(ladder)
        self.caps_fn = caps_fn
        self.drop_budget = drop_budget
        self.ema_alpha = ema
        self.down_patience = down_patience
        self.down_margin = down_margin
        self.cooldown = cooldown
        self.index = start if start is not None else 0
        self.tick = 0
        self.drop_ema: float | None = None
        self.history: list[Switch] = []
        self._down_ok = 0
        self._last_switch = -10 ** 9
        self._down_hold = down_patience   # current (backed-off) patience
        self._last_down_tick = None       # tick of the latest down-switch

    @property
    def point(self) -> OperatingPoint:
        return self.ladder[self.index]

    def _predicted_drop_frac(self, counts: np.ndarray, index: int) -> float:
        """Drop fraction the observed routed mix would suffer at a rung
        (global counts vs global caps; optimistic under cross-shard skew,
        see module docstring)."""
        caps = np.asarray(self.caps_fn(self.ladder[index]), float)
        t = float(counts.sum())
        if t <= 0:
            return 0.0
        return float(np.maximum(counts - caps, 0.0).sum()) / t

    def observe(self, stats) -> int:
        """Consume one tick's stats dict; returns the rung for next tick.

        ``stats`` needs ``class_counts`` (n+1,) and ``dropped`` (scalar);
        extra keys are ignored so a server can pass its metric dict
        straight through.
        """
        counts = np.asarray(stats["class_counts"], float)
        dropped = float(np.asarray(stats["dropped"]))
        t = counts.sum()
        drop_frac = dropped / t if t > 0 else 0.0
        a = self.ema_alpha
        self.drop_ema = drop_frac if self.drop_ema is None \
            else a * drop_frac + (1 - a) * self.drop_ema
        self.tick += 1
        if self.tick - self._last_switch <= self.cooldown:
            return self.index

        if self.drop_ema > self.drop_budget \
                and self.index < len(self.ladder) - 1:
            # violated: jump to the first rung predicted to meet budget
            target = len(self.ladder) - 1
            for j in range(self.index + 1, len(self.ladder)):
                if self._predicted_drop_frac(counts, j) <= self.drop_budget:
                    target = j
                    break
            self._switch(target)
        elif self.index > 0 and self.drop_ema <= self.drop_budget \
                and self._predicted_drop_frac(counts, self.index - 1) \
                <= self.drop_budget * self.down_margin:
            # the EMA gate matters when pinned at the TOP rung: with no
            # rung left to climb, a violating mix must hold position, not
            # drift down on the occasional light tick's prediction
            self._down_ok += 1
            if self._down_ok >= self._down_hold:
                self._switch(self.index - 1)
        else:
            self._down_ok = 0
        return self.index

    def _switch(self, to_index: int):
        if to_index > self.index and self._last_down_tick is not None \
                and self.tick - self._last_down_tick \
                <= 4 * (self.cooldown + 1):
            # re-escalating right after a step-down: the prediction lied
            # for this mix — back off future down attempts exponentially
            self._down_hold = min(self._down_hold * 2, 1 << 10)
        elif to_index < self.index:
            self._last_down_tick = self.tick
        self.history.append(Switch(self.tick, self.index, to_index,
                                   float(self.drop_ema or 0.0)))
        self.index = to_index
        self._down_ok = 0
        self._last_switch = self.tick
        # the new rung changes the drop distribution; restart the EMA
        self.drop_ema = None

    def summary(self) -> dict:
        """Trajectory record for server stats / bench CSVs."""
        return {
            "final_index": self.index,
            "final_point": dataclasses.asdict(self.point),
            "switches": [dataclasses.asdict(s) for s in self.history],
            "drop_ema": self.drop_ema,
            "ticks": self.tick,
        }


@dataclasses.dataclass
class Swap:
    """One residency move (library promote/demote), recorded."""

    tick: int
    promoted: int            # library id entering the resident set
    demoted: int             # library id leaving it
    slot: int                # resident slot that changed owner
    hot_ema: float           # promoted class's routed-share EMA
    cold_ema: float          # demoted class's routed-share EMA


class ResidencyController:
    """Picks WHICH library classes are resident, beside the
    CapacityController's HOW MUCH capacity.

    The dispatch engine routes over the full approximator library but can
    only execute the ``n_resident`` classes whose weights occupy the
    prepadded stacks (runtime/dispatch.make_dispatch_plan residency fold;
    off-set classes fall back to exact).  This controller watches the
    served full-library demand histogram (``lib_counts`` in the
    invoke_stats — QoS-Nets' routed_per_class adaptation) and promotes
    the hottest off-set class over the coldest resident.  A swap is a new
    traced residency vector through the same compiled step — zero
    retraces (kernels/ops.gather_resident_stacks).

    Thrash hysteresis, two gates both required to swap:
      * ratio: the challenger's routed-share EMA must exceed
        ``promote_margin x`` the coldest resident's — a borderline class
        oscillating around parity never swaps;
      * floor: a resident serving more than ``demote_margin`` of total
        traffic is never demoted, whatever is knocking.
    Decisions fire once per ``observe_window`` observed ticks, suppressed
    for ``cooldown`` ticks after a swap (the EMA must re-converge on the
    new set before it is trusted again); at most one swap per decision.

    ``spec`` is a runtime/options.LibrarySpec; ``observe`` consumes one
    tick's stats (needs ``lib_counts``, (library_size + 1,) with entry 0
    the exact votes) and returns the CURRENT residency tuple of library
    ids — the server re-feeds it to the compiled step each tick.
    """

    def __init__(self, spec):
        self.spec = spec
        self.residency: tuple[int, ...] = spec.initial_residency()
        self.tick = 0
        self.ema: np.ndarray | None = None   # (library_size,) routed shares
        self.history: list[Swap] = []
        self._last_swap = -10 ** 9

    def observe(self, stats) -> tuple[int, ...]:
        lib_counts = np.asarray(stats["lib_counts"], float)
        shares = lib_counts[1:]              # drop the exact column
        t = lib_counts.sum()
        if t > 0:
            shares = shares / t
            a = self.spec.ema
            self.ema = shares if self.ema is None \
                else a * shares + (1 - a) * self.ema
        self.tick += 1
        if self.ema is None \
                or self.tick - self._last_swap <= self.spec.cooldown \
                or self.tick % self.spec.observe_window != 0:
            return self.residency

        resident = set(self.residency)
        off = [c for c in range(self.spec.library_size)
               if c not in resident]
        if not off:
            return self.residency
        hot = max(off, key=lambda c: self.ema[c])
        slot = int(np.argmin([self.ema[c] for c in self.residency]))
        cold = self.residency[slot]
        eps = 1e-9
        if self.ema[hot] > self.spec.promote_margin \
                * max(float(self.ema[cold]), eps) \
                and float(self.ema[cold]) <= self.spec.demote_margin:
            self.history.append(Swap(self.tick, int(hot), int(cold), slot,
                                     float(self.ema[hot]),
                                     float(self.ema[cold])))
            r = list(self.residency)
            r[slot] = int(hot)
            self.residency = tuple(r)
            self._last_swap = self.tick
        return self.residency

    def summary(self) -> dict:
        """Trajectory record for server stats / bench CSVs."""
        return {
            "final_residency": list(self.residency),
            "swaps": [dataclasses.asdict(s) for s in self.history],
            "swap_count": len(self.history),
            "lib_ema": None if self.ema is None
            else [float(v) for v in self.ema],
            "ticks": self.tick,
        }
