"""Step functions — the compilation units of the whole system.

``make_train_step(cfg)``   -> (state, batch) -> (state, metrics)
``make_prefill_step(cfg)`` -> (params, batch) -> (last_logits, cache)
``make_decode_step(cfg)``  -> (params, cache, inputs) -> (logits, cache)

train_step = microbatched fwd+bwd (lax.scan gradient accumulation when
cfg-level ``grad_accum > 1``) + global-norm clip + cosine LR + AdamW.
All functions are pure and jit-friendly; sharding is applied by the caller
(launch/dryrun.py, runtime/trainer.py) via in_shardings/out_shardings.

The decode/chunk steps are cache-layout agnostic: a paged KV cache
(models/model.init_cache with page_size > 0) rides through the same
``cache`` pytree — pooled ``k``/``v`` leaves plus a ``block_table`` —
so the step signatures and their compiled-once contract are unchanged.
The server mutates the block table HOST-side and refreshes the traced
leaf each tick (same shape/dtype always -> zero retraces).
"""
from __future__ import annotations

import contextlib
import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, cosine_schedule


def init_train_state(key, cfg: ModelConfig):
    params = M.init_model(key, cfg)
    return {"params": params, "opt": adamw_init(params),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg: ModelConfig, *, grad_accum: int = 1,
                    base_lr: float = 3e-4, warmup: int = 200,
                    total_steps: int = 10_000, max_grad_norm: float = 1.0):
    """Returns train_step(state, batch)->(state, metrics).  ``batch`` =
    {"inputs": (B, S)[, d], "labels": (B, S)}; B must divide by grad_accum."""

    def loss_fn(params, inputs, labels):
        return M.lm_loss(cfg, params, inputs, labels)

    def train_step(state, batch):
        params = state["params"]
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch["inputs"], batch["labels"])
        else:
            # microbatch accumulation: scan over grad_accum slices of B.
            # Per-microbatch metrics ride the scan ys and are meaned over
            # the accumulation axis — equal-sized slices, so the mean of
            # per-slice means equals the full-batch value for every
            # token-meaned metric (invocation, router_acc, lm_loss, the
            # per-class dispatch vectors...); they used to be silently
            # dropped whenever grad_accum > 1.
            def mb(carry, sl):
                acc, lsum = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, sl["inputs"], sl["labels"])
                return (jax.tree.map(jnp.add, acc, g), lsum + l), m
            slices = jax.tree.map(
                lambda a: a.reshape(grad_accum, a.shape[0] // grad_accum,
                                    *a.shape[1:]), batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, lsum), ms = jax.lax.scan(mb, (zeros, 0.0), slices)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = lsum / grad_accum
            metrics = jax.tree.map(lambda v: jnp.mean(v, axis=0), ms)

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = cosine_schedule(state["step"], base_lr=base_lr, warmup=warmup,
                             total=total_steps)
        params, opt = adamw_update(params, grads, state["opt"], state["step"],
                                   lr=lr)
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, cache, _, _ = M.forward(cfg, params, batch["inputs"],
                                        collect_cache=True, serve=True)
        return logits[:, -1], cache
    return prefill_step


def mcma_serve_config(cfg: ModelConfig, *, backend: str | None = None) -> ModelConfig:
    """Serve-mode cfg routing the ApproxFFN through the MCMA weight-switch
    dispatch engine (runtime/dispatch.py).  Default backend is the Pallas
    kernel (interpreter mode off-TPU so the same step compiles in CI/CPU
    runs); ``backend="pallas_fused"`` runs the gather/scatter-fused
    kernel; ``backend="xla"`` swaps in the pure-XLA dispatch — the
    oracle the benches gate both kernels against."""
    assert cfg.approx.enable, "MCMA dispatch requires cfg.approx.enable"
    backend = backend or "pallas"
    from repro.runtime.dispatch import PALLAS_BACKENDS
    return dataclasses.replace(cfg, approx=dataclasses.replace(
        cfg.approx, backend=backend,
        interpret=backend in PALLAS_BACKENDS
        and jax.default_backend() != "tpu"))


@contextlib.contextmanager
def serve_mesh_context(mesh):
    """Trace/serve context for mesh deployments.

    Activates the mesh plus batch-sharded activations so the serve-mode
    modules (ApproxFFN, MoE) detect the distributed deployment
    (sharding/activations.manual_dp_context) and take their
    shard_map-native dispatch paths — the MCMA engine per data shard with
    psum-reduced invoke_stats.  ``mesh=None`` is a no-op so single-device
    callers share the same code path.  Must wrap the call that TRACES the
    jitted step (jit traces lazily, so wrapping every call is the safe
    pattern — the context is cheap after the first).
    """
    if mesh is None:
        yield None
        return
    from jax.sharding import PartitionSpec as P
    from repro.sharding import rules as R
    from repro.sharding.activations import activation_sharding
    with mesh, activation_sharding(P(R.dp_axes(mesh), None, None)):
        yield mesh


def _serve_cfg(cfg: ModelConfig, *, use_mcma_dispatch: bool,
               operating_point, route_scope: str | None,
               backend: str | None) -> ModelConfig:
    """Shared cfg munging for the serve-mode steps (decode + prefill
    chunk): MCMA backend selection, route-scope override, operating-point
    capacity replacement.  Both steps MUST come out of the same cfg or
    the prefill chunk and the decode tick would disagree on dispatch."""
    if use_mcma_dispatch:
        cfg = mcma_serve_config(cfg, backend=backend)
    if route_scope is not None:
        if route_scope not in ("layer", "tick"):
            raise ValueError(f"unknown route_scope: {route_scope!r} "
                             "(expected 'layer' or 'tick')")
        cfg = dataclasses.replace(cfg, approx=dataclasses.replace(
            cfg.approx, route_scope=route_scope))
    if operating_point is not None:
        pt = operating_point
        cfg = dataclasses.replace(cfg, approx=dataclasses.replace(
            cfg.approx, exact_frac=pt.exact_frac,
            invoke_frac=pt.invoke_frac, shard_slack=pt.shard_slack,
            invoke_fracs=tuple(pt.invoke_fracs),
            tier_margins=tuple(pt.tier_margins) or cfg.approx.tier_margins))
    return cfg


def make_decode_step(cfg: ModelConfig, *, use_mcma_dispatch: bool = False,
                     with_stats: bool = False, operating_point=None,
                     route_scope: str | None = None,
                     backend: str | None = None):
    """``use_mcma_dispatch`` swaps the serve-mode FFN engine to the MCMA
    Pallas dispatch; ``with_stats`` makes the step also return the
    layer-meaned dispatch metrics (invocation rate etc.) per tick.

    ``operating_point`` (runtime/autotune.OperatingPoint) overrides the
    config's serve capacity fractions — capacities are SHAPES, so each
    ladder rung is its own compilation unit; the server precompiles one
    step per rung and the autotuner switches between them (never
    retraces a live one).

    ``route_scope`` overrides ``cfg.approx.route_scope``: "tick" makes
    the step route ONCE per tick (one DispatchPlan from the tick-router
    head, hoisted above the layer scan and reused by every layer — the
    paper's per-input decision); "layer" keeps per-layer routing.  Under
    ``with_stats`` a tick-scope step's metrics are the single tick-level
    observation (every layer reports the same plan), so an autotuner
    consumes one exact sample per tick instead of L noisy per-layer ones.

    The returned step takes an optional trailing ``row_mask`` ((B,) bool
    of ACTIVE slots); pass it on partially-full slot tables so idle rows
    never bias the dispatch stats (the free-slot router-bias fix).  It
    also takes optional ``tier`` ((B,) int32 per-slot QoS tier) +
    ``tier_margins`` ((n_tiers,) float32) — both TRACED inputs, so one
    compiled step serves every tier mix and margin setting; only the
    capacity fields of an operating point (shapes) force a recompile.

    The optional trailing ``residency`` ((n_resident,) int32 library
    class ids, library configs only) is likewise TRACED — the
    ResidencyController swaps the hot set by feeding a new vector
    through the SAME compiled step, zero retraces.

    ``backend`` (with ``use_mcma_dispatch``) overrides the dispatch
    backend: default "pallas", "pallas_fused" for the gather/scatter-
    fused kernel, or "xla" for the oracle engine."""
    cfg = _serve_cfg(cfg, use_mcma_dispatch=use_mcma_dispatch,
                     operating_point=operating_point,
                     route_scope=route_scope, backend=backend)

    def decode_step(params, cache, inputs, row_mask=None, tier=None,
                    tier_margins=None, residency=None):
        return M.decode(cfg, params, cache, inputs, serve=True,
                        collect_metrics=with_stats, row_mask=row_mask,
                        tier=tier, tier_margins=tier_margins,
                        residency=residency)
    return decode_step


def make_prefill_chunk_step(cfg: ModelConfig, *, use_mcma_dispatch: bool = False,
                            with_stats: bool = False, operating_point=None,
                            route_scope: str | None = None,
                            backend: str | None = None):
    """Chunked-prefill step: consume up to S prompt tokens per slot into
    the SAME decode cache layout ``make_decode_step`` advances, without
    computing logits (the final prompt token always goes through the
    decode step, so the first sampled token is bit-identical to
    token-by-token prefill).

    Signature: ``prefill_chunk_step(params, cache, tokens, n_valid,
    row_mask=None, tier=None, tier_margins=None) -> (cache, metrics)``
    with ``tokens`` (B, S) int32 right-padded per row and ``n_valid``
    (B,) int32 counting real tokens (0 = slot not prefilling this tick —
    nothing is written for that row).  KV writes use scatter-with-drop
    indexing, so a row can never clamp-corrupt the last cache position.

    Shares ``_serve_cfg`` with ``make_decode_step`` so both phases run
    the identical dispatch configuration; chunk-phase dispatch metrics
    come back under the same keys but must be accumulated SEPARATELY
    from decode ticks (the autotuner's signal is decode-phase only).
    Uniform (dense-attention) models only — SSM/hybrid/sliding-window
    caches are not positionally addressable, the server falls back to
    token-by-token feeding for those."""
    cfg = _serve_cfg(cfg, use_mcma_dispatch=use_mcma_dispatch,
                     operating_point=operating_point,
                     route_scope=route_scope, backend=backend)

    def prefill_chunk_step(params, cache, tokens, n_valid, row_mask=None,
                           tier=None, tier_margins=None, residency=None):
        return M.decode_chunk(cfg, params, cache, tokens, n_valid,
                              serve=True, collect_metrics=with_stats,
                              row_mask=row_mask, tier=tier,
                              tier_margins=tier_margins,
                              residency=residency)
    return prefill_chunk_step
