"""Serving runtime — the public API lives HERE.

``tests/test_public_api.py`` snapshots this surface: additions are
deliberate (extend the snapshot), removals/renames are breaking.  The
canonical deployment:

    from repro.runtime import DecodeServer, ServeOptions, LibrarySpec

    server = DecodeServer(cfg, params, options=ServeOptions(
        batch=8, use_mcma_dispatch=True, autotune=True,
        library=LibrarySpec(library_size=16, n_resident=4)))
"""
from repro.runtime.cli import add_serve_options
from repro.runtime.dispatch import (DispatchPlan, InvokeStats,
                                    execute_dispatch, make_dispatch_plan,
                                    mcma_dispatch, plan_invoke_stats)
from repro.runtime.options import LibrarySpec, ServeOptions
from repro.runtime.autotune import (CapacityController, OperatingPoint,
                                    ResidencyController, Swap, Switch,
                                    default_ladder, ladder_from_counts)
from repro.runtime.server import DecodeServer, DrainStats, Request

__all__ = [
    "CapacityController",
    "DecodeServer",
    "DispatchPlan",
    "DrainStats",
    "InvokeStats",
    "LibrarySpec",
    "OperatingPoint",
    "Request",
    "ResidencyController",
    "ServeOptions",
    "Swap",
    "Switch",
    "add_serve_options",
    "default_ladder",
    "execute_dispatch",
    "ladder_from_counts",
    "make_dispatch_plan",
    "mcma_dispatch",
    "plan_invoke_stats",
]
