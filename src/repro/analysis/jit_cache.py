"""Zero-retrace assertions over jit caches — the shared helper behind the
engine's no-retrace contract.

The serving architecture's core invariant: traced inputs (QoS margins,
residency vectors, tier mixes, row masks) flow through ONE compiled
program; only shapes (capacities, batch) may compile a new one.  Tests,
benches, and the trace auditor (repro.analysis.audit) all pin it the same
way — count the jit cache entries after exercising the traced inputs:

    from repro.analysis.jit_cache import assert_zero_retrace
    fn = jax.jit(...)
    for margins in settings:
        fn(margins)
    assert_zero_retrace(fn, "margins")   # was: assert fn._cache_size() == 1

``_cache_size`` is a private jax attribute; where a jax version does not
expose it, ``cache_size`` returns None and the assertion degrades to a
no-op (the bit-exactness tests still hold the semantic line).
"""
from __future__ import annotations


def cache_size(fn) -> int | None:
    """Number of compiled programs behind a ``jax.jit`` callable, or None
    when this jax does not expose the counter."""
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        return None
    return int(probe())


def assert_zero_retrace(fn, what: str = "a traced-input change", *,
                        expected: int = 1) -> None:
    """Assert ``fn`` compiled exactly ``expected`` program(s).

    ``what`` names the traced input that must not retrace — it leads the
    failure message, so call sites stay at least as specific as the ad-hoc
    asserts this replaces (e.g. ``assert_zero_retrace(fn, "margins")`` ->
    "margins forced a retrace: ...").
    """
    n = cache_size(fn)
    if n is None:        # jax without _cache_size: nothing to count
        return
    assert n == expected, (
        f"{what} forced a retrace: {n} compiled programs where {expected} "
        f"expected — traced inputs must reuse the same jitted program "
        f"(only shapes/static args may compile a new one)")
