"""Stage 1: the AST linter driver.

Walks Python sources (default: ``src/repro``), parses each module once,
and runs every rule in ``repro.analysis.rules.ALL_RULES`` over it.  Pure
stdlib — the lint stage never imports jax, so it runs in well under a
second and is safe to hook anywhere.

``__pycache__`` / ``.pytest_cache`` / VCS and output directories are
excluded unconditionally: lint findings must be keyed to checked-in
sources only (the .gitignore keeps the same directories out of the
repo).
"""
from __future__ import annotations

import ast
from pathlib import Path

from repro.analysis import astutil
from repro.analysis.findings import Finding
from repro.analysis.rules import ALL_RULES

EXCLUDE_DIRS = {"__pycache__", ".pytest_cache", ".git", ".hypothesis",
                "out", ".venv", "node_modules", "runs"}

# serve-path modules: every function body in these executes under jit
# (RL002 scans them whole; elsewhere only jit-decorated functions are in
# scope).  Prefixes are repo-relative with forward slashes.
SERVE_PATH_PREFIXES = (
    "src/repro/kernels/",
    "src/repro/runtime/dispatch.py",
    "src/repro/runtime/steps.py",
    "src/repro/models/",
)

# where RL004 learns the declared mesh axis names
AXIS_SPEC_MODULE = "src/repro/sharding/rules.py"


class LintContext:
    """Per-run shared state handed to every rule via ModuleInfo.ctx."""

    def __init__(self, root: Path):
        self.root = Path(root)
        self._axes: set[str] | None | bool = False   # False = not computed

    def is_serve_path(self, relpath: str) -> bool:
        return relpath.startswith(SERVE_PATH_PREFIXES)

    def declared_axes(self) -> set[str] | None:
        """Mesh axis names the spec layer declares: identifier-like string
        constants inside ``*_axes`` functions and ``P(...)`` calls of
        sharding/rules.py.  None when the module is absent (rule RL004
        then stays silent)."""
        if self._axes is not False:
            return self._axes
        spec = self.root / AXIS_SPEC_MODULE
        if not spec.is_file():
            self._axes = None
            return None
        tree = ast.parse(spec.read_text())
        axes: set[str] = set()

        def strings(node):
            return {n.value for n in ast.walk(node)
                    if isinstance(n, ast.Constant)
                    and isinstance(n.value, str) and n.value.isidentifier()
                    and len(n.value) <= 16}

        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name.endswith("_axes"):
                for stmt in node.body:
                    if not (isinstance(stmt, ast.Expr)
                            and isinstance(stmt.value, ast.Constant)):
                        axes |= strings(stmt)      # skip the docstring
            elif isinstance(node, ast.Call) and isinstance(node.func,
                                                           ast.Name) \
                    and node.func.id == "P":
                for a in node.args:
                    axes |= strings(a)
        self._axes = axes
        return axes


def iter_source_files(paths: list[Path]) -> list[Path]:
    files = []
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            files.append(p)
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if not any(part in EXCLUDE_DIRS for part in f.parts):
                    files.append(f)
    return files


def lint_paths(paths: list[Path], root: Path,
               rules=ALL_RULES) -> list[Finding]:
    """Run ``rules`` over every source under ``paths``; findings carry
    ``root``-relative paths.  A module that fails to parse is itself a
    finding (rule LINT) rather than a crash."""
    ctx = LintContext(root)
    findings: list[Finding] = []
    for f in iter_source_files([Path(p) for p in paths]):
        try:
            rel = f.resolve().relative_to(Path(root).resolve()).as_posix()
        except ValueError:
            rel = f.as_posix()
        try:
            mod = astutil.parse_module(f, rel, ctx)
        except SyntaxError as e:
            findings.append(Finding(rule="LINT", path=rel,
                                    line=e.lineno or 0, scope="",
                                    detail="syntax-error",
                                    message=f"not parseable: {e.msg}"))
            continue
        for rule in rules:
            findings.extend(rule.check(mod))
    findings.sort(key=lambda x: (x.path, x.line, x.rule))
    return findings
