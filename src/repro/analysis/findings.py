"""Findings + the checked-in baseline — the linter's currency.

A ``Finding`` is one keyed rule violation.  Its ``key`` deliberately
excludes the line number: the baseline must survive unrelated edits that
shift code around, so findings are identified by (rule, file, enclosing
scope, detail) and the line is display-only.

Baseline workflow (docs/analysis.md):
  * ``python -m repro.analysis --update-baseline`` writes every current
    finding's key to the baseline file, one per line; ``#`` comments (one
    line of justification per grandfathered entry) are kept verbatim.
  * a finding whose key appears in the baseline is reported as
    grandfathered and does NOT fail the run; every NEW finding does.
  * baseline entries that no longer match any finding are reported as
    stale (fix landed — prune the entry) but never fail the run.

This module is importable without jax so the lint stage stays cheap.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str        # "RL001".."RL005" (lint) / "TA001".."TA003" (audit)
    path: str        # repo-relative, forward slashes
    line: int        # 1-based; 0 when the finding has no source anchor
    message: str     # human-readable, specific
    detail: str = ""  # stable discriminator for the key (symbol, axis, ...)
    scope: str = ""   # enclosing function/class name ("" = module level)

    @property
    def key(self) -> str:
        """Line-number-free identity used for baseline matching."""
        parts = [self.rule, self.path, self.scope, self.detail]
        return ":".join(p.replace(":", "_") for p in parts)

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{self.rule} {loc} [{self.scope or '<module>'}] {self.message}"


def load_baseline(path: Path) -> set[str]:
    """Baseline keys; missing file = empty baseline."""
    if not path.is_file():
        return set()
    keys = set()
    for line in path.read_text().splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            keys.add(line)
    return keys


def write_baseline(path: Path, findings: list[Finding]) -> None:
    lines = ["# repro.analysis baseline — grandfathered findings, one key",
             "# per line.  Add a '# why' comment above every entry you",
             "# suppress; prune entries the tool reports as stale.", ""]
    lines += sorted({f.key for f in findings})
    path.write_text("\n".join(lines) + "\n")


def split_by_baseline(findings: list[Finding], baseline: set[str]):
    """-> (new, grandfathered, stale_keys)."""
    new = [f for f in findings if f.key not in baseline]
    old = [f for f in findings if f.key in baseline]
    stale = baseline - {f.key for f in findings}
    return new, old, stale
