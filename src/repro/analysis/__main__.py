"""``python -m repro.analysis`` — run the lint + trace-audit gate.

Exit status is the contract CI enforces: 0 when every finding is either
absent or grandfathered in the baseline file, 1 when any NEW finding
appears (or, with ``--no-baseline``, when any finding at all appears).

    python -m repro.analysis                     # both stages, baseline
    python -m repro.analysis --stage lint        # stdlib-only, no jax
    python -m repro.analysis --stage audit
    python -m repro.analysis src/repro/kernels   # lint a subtree
    python -m repro.analysis --update-baseline   # re-grandfather

The baseline (default ``analysis_baseline.txt`` at the repo root) holds
one finding key per line — keys are line-number-free
(``rule:path:scope:detail``) so unrelated edits never churn it.  Stale
baseline entries (fixed findings) are reported but never fail the run;
``--update-baseline`` rewrites the file from the current findings.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="MCMA engine contract gate: AST lint + trace audit")
    ap.add_argument("paths", nargs="*", help="files/dirs to lint "
                    "(default: src/repro, tests, benchmarks)")
    ap.add_argument("--stage", choices=("all", "lint", "audit"),
                    default="all")
    ap.add_argument("--root", default=".", help="repo root (baseline + "
                    "finding paths are relative to it)")
    ap.add_argument("--baseline", default=None,
                    help="baseline file (default: ROOT/analysis_baseline.txt)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline: any finding fails")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from current findings")
    ap.add_argument("--backends", default="xla,pallas,pallas_fused",
                    help="audit backends (comma-separated)")
    ap.add_argument("--no-steps", action="store_true",
                    help="audit the engine only, skip the model steps")
    args = ap.parse_args(argv)

    from repro.analysis import findings as F

    root = Path(args.root)
    baseline_path = Path(args.baseline) if args.baseline \
        else root / "analysis_baseline.txt"
    all_findings = []

    if args.stage in ("all", "lint"):
        from repro.analysis import run_lint
        t0 = time.time()
        lint = run_lint(args.paths or None, root)
        print(f"[lint]  {len(lint)} finding(s) in {time.time() - t0:.2f}s")
        all_findings += lint

    if args.stage in ("all", "audit"):
        from repro.analysis import run_audit
        t0 = time.time()
        audit = run_audit(backends=tuple(args.backends.split(",")),
                          with_steps=not args.no_steps)
        print(f"[audit] {len(audit)} finding(s) in {time.time() - t0:.2f}s")
        all_findings += audit

    if args.update_baseline:
        F.write_baseline(baseline_path, all_findings)
        print(f"baseline: wrote {len(all_findings)} key(s) to "
              f"{baseline_path}")
        return 0

    baseline = set() if args.no_baseline else F.load_baseline(baseline_path)
    new, old, stale = F.split_by_baseline(all_findings, baseline)
    for f in new:
        print(f.render())
    if old:
        print(f"[baseline] {len(old)} grandfathered finding(s) suppressed")
    for key in sorted(stale):
        print(f"[stale] baseline entry no longer found: {key} "
              "(run --update-baseline)")
    if new:
        print(f"FAILED: {len(new)} new finding(s); fix them or (last "
              f"resort) grandfather via --update-baseline")
        return 1
    print("OK: no new findings")
    return 0


if __name__ == "__main__":
    sys.exit(main())
