"""Dynamic jaxpr op counting — the fused-dispatch audit's measuring stick.

``count_dynamic_ops`` walks a jaxpr counting how many times the named
primitives EXECUTE per call: a scan body's ops count once per trip
(static jaxpr counts would hide the per-layer cost a tick-scope plan
amortizes), and pjit/remat/scan/pallas sub-jaxprs are entered
recursively.  Grown out of bench_dispatch's local counter so the
fused-kernel gates (bench_dispatch --backend-sweep,
tests/test_fused_dispatch.py) share one definition of "how many
standalone gathers does this program run per layer".

Two knobs matter for the fusion audit:

  * ``min_operand_rank=2`` restricts the count to ACTIVATION-sized moves
    — gathers/scatters whose operand is a matrix — so the plan's cheap
    int32 index-vector bookkeeping (1-D scatters) doesn't drown the
    signal.
  * ``enter_pallas=False`` stops at ``pallas_call`` boundaries: the
    fused kernel's point is precisely that its gather/scatter are
    VMEM-local kernel I/O rather than standalone XLA ops over HBM, so
    the STANDALONE count excludes kernel bodies.  (In interpret mode the
    kernel body lowers to XLA too — entering it would count the fused
    moves twice over.)
"""
from __future__ import annotations

# gather/scatter family by jaxpr primitive name; jnp.take / advanced
# indexing lower to "gather", .at[].set to "scatter", .at[].add to
# "scatter-add" (dispatch.scatter_rows' pinned duplicate semantics)
GATHER_PRIMITIVES = frozenset({"gather"})
SCATTER_PRIMITIVES = frozenset({
    "scatter", "scatter-add", "scatter-mul", "scatter-min", "scatter-max",
})
MOVE_PRIMITIVES = GATHER_PRIMITIVES | SCATTER_PRIMITIVES


def sub_jaxprs(eqn, *, enter_pallas: bool = True):
    """All jaxpr-valued params of an eqn (pjit/scan/remat/pallas bodies).

    ``enter_pallas=False`` skips a ``pallas_call``'s kernel body — its
    ops are kernel-internal, not standalone program ops.
    """
    if not enter_pallas and eqn.primitive.name == "pallas_call":
        return []
    out = []
    for v in eqn.params.values():
        for u in (v if isinstance(v, (list, tuple)) else (v,)):
            if hasattr(u, "jaxpr") and hasattr(u, "consts"):  # ClosedJaxpr
                out.append(u.jaxpr)
            elif hasattr(u, "eqns"):                          # Jaxpr
                out.append(u)
    return out


def _operand_rank(eqn) -> int:
    """Rank of the eqn's first operand (the gathered/scattered array)."""
    if not eqn.invars:
        return 0
    aval = getattr(eqn.invars[0], "aval", None)
    return getattr(aval, "ndim", 0)


def count_dynamic_ops(jaxpr, names, *, min_operand_rank: int = 0,
                      enter_pallas: bool = True) -> int:
    """How many times primitives in ``names`` EXECUTE per call."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)            # accept ClosedJaxpr
    total = 0
    for eqn in jaxpr.eqns:
        mult = eqn.params.get("length", 1) \
            if eqn.primitive.name == "scan" else 1
        if eqn.primitive.name in names \
                and _operand_rank(eqn) >= min_operand_rank:
            total += 1
        for sub in sub_jaxprs(eqn, enter_pallas=enter_pallas):
            total += mult * count_dynamic_ops(
                sub, names, min_operand_rank=min_operand_rank,
                enter_pallas=enter_pallas)
    return total


def activation_moves(jaxpr) -> tuple[int, int]:
    """(standalone gathers, standalone scatters) over activation-sized
    (rank >= 2) operands, pallas kernel bodies excluded — the fusion
    audit's headline numbers.  Under ``backend="pallas_fused"`` the
    engine's per-layer execute shows (1, 1): the exact-path capacity
    buffers; the class-sort legs are gone.  Unfused pallas shows (3, 3).
    """
    g = count_dynamic_ops(jaxpr, GATHER_PRIMITIVES, min_operand_rank=2,
                          enter_pallas=False)
    s = count_dynamic_ops(jaxpr, SCATTER_PRIMITIVES, min_operand_rank=2,
                          enter_pallas=False)
    return g, s
