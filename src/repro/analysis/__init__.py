"""Static + trace analysis for the MCMA serving engine.

Two stages, one findings vocabulary:

  * **lint** (``repro.analysis.lint``) — a pure-stdlib AST pass over the
    sources enforcing the contracts the AST can see: RL001 retrace
    hazards, RL002 host syncs on the serve path, RL003 pytree
    registration drift, RL004 undeclared collective axes, RL005
    unguarded Pallas grid arithmetic;
  * **audit** (``repro.analysis.audit``) — traces the real engine
    entrypoints across capacities x QoS margins x residency sets and
    asserts one-compile-per-entrypoint (TA001), int32 stats (TA002),
    and no host callbacks (TA003).

CLI: ``python -m repro.analysis`` (see ``__main__``); ``make analyze``
runs both stages against the checked-in baseline and fails on any NEW
finding.  ``repro.analysis.jit_cache.assert_zero_retrace`` is the
shared test-side helper replacing ad-hoc ``fn._cache_size() == 1``
asserts.
"""
from repro.analysis.findings import (Finding, load_baseline,
                                     split_by_baseline, write_baseline)
from repro.analysis.jit_cache import assert_zero_retrace, cache_size

__all__ = [
    "Finding", "load_baseline", "split_by_baseline", "write_baseline",
    "assert_zero_retrace", "cache_size", "run_lint", "run_audit",
]


def run_lint(paths=None, root="."):
    """Stage 1 over ``paths`` (default: src/repro, tests, benchmarks
    under ``root``).  Stdlib-only — safe without jax installed."""
    from pathlib import Path

    from repro.analysis.lint import lint_paths
    root = Path(root)
    if paths is None:
        paths = [p for p in (root / "src" / "repro", root / "tests",
                             root / "benchmarks") if p.exists()]
    return lint_paths([Path(p) for p in paths], root)


def run_audit(**kw):
    """Stage 2 (imports jax; see ``repro.analysis.audit.run_audit``)."""
    from repro.analysis.audit import run_audit as _run
    return _run(**kw)
