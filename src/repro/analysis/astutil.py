"""Shared AST plumbing for the lint rules (no jax import — pure stdlib).

Every rule works on a ``ModuleInfo``: the parsed tree plus an import
alias map so calls are matched on their CANONICAL dotted name
(``jax.lax.psum`` whether the module wrote ``jax.lax.psum``,
``lax.psum``, or ``from jax.lax import psum``).
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path


@dataclasses.dataclass
class ModuleInfo:
    path: str                  # repo-relative, forward slashes
    tree: ast.Module
    aliases: dict[str, str]    # local name -> canonical dotted prefix
    ctx: "object" = None       # LintContext (lint.py) — rules may use it

    def canonical(self, node: ast.AST) -> str | None:
        """Canonical dotted name of a Name/Attribute chain, alias-expanded
        (``pl.cdiv`` -> ``jax.experimental.pallas.cdiv``); None when the
        expression is not a plain dotted chain."""
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        parts.append(node.id)
        parts.reverse()
        parts[0] = self.aliases.get(parts[0], parts[0])
        return ".".join(parts)


def collect_aliases(tree: ast.Module) -> dict[str, str]:
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = \
                    a.name if a.asname else a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def parse_module(path: Path, rel: str, ctx=None) -> ModuleInfo:
    tree = ast.parse(path.read_text(), filename=str(path))
    return ModuleInfo(path=rel, tree=tree, aliases=collect_aliases(tree),
                      ctx=ctx)


def functions(tree: ast.AST):
    """Every (func_node, enclosing_stack) in the tree, outermost first.
    The stack holds the chain of enclosing FunctionDef/AsyncFunctionDef/
    ClassDef nodes (closest last)."""
    out = []

    def visit(node, stack):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.append((child, tuple(stack)))
                visit(child, stack + [child])
            elif isinstance(child, ast.ClassDef):
                visit(child, stack + [child])
            else:
                visit(child, stack)
    visit(tree, [])
    return out


def param_names(fn: ast.FunctionDef) -> list[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def assigned_names(fn: ast.FunctionDef) -> set[str]:
    """Names bound anywhere inside ``fn`` (assignments, for targets,
    with-as, comprehension targets, nested defs/lambda params excluded)."""
    bound: set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        bound.add(n.id)
        elif isinstance(node, (ast.For, ast.comprehension)):
            for n in ast.walk(node.target):
                if isinstance(n, ast.Name):
                    bound.add(n.id)
        elif isinstance(node, ast.withitem) and node.optional_vars:
            for n in ast.walk(node.optional_vars):
                if isinstance(n, ast.Name):
                    bound.add(n.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            bound.add(node.name)
    return bound


def jit_decorator(mod: ModuleInfo, fn: ast.FunctionDef):
    """The ``jax.jit`` decoration of ``fn``, if any.

    Recognized forms: ``@jax.jit``, ``@jit``, ``@jax.jit(...)``, and
    ``@functools.partial(jax.jit, ...)``.  Returns the Call node carrying
    the jit kwargs (or the bare decorator node for ``@jax.jit``), else
    None.
    """
    for dec in fn.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = mod.canonical(target)
        if name in ("jax.jit", "jit", "jax.jit.jit"):
            return dec
        if isinstance(dec, ast.Call) and name in ("functools.partial",
                                                  "partial"):
            if dec.args and mod.canonical(dec.args[0]) in ("jax.jit", "jit"):
                return dec
    return None


def string_items(node: ast.AST) -> list[str] | None:
    """Resolve a string literal or tuple/list of string literals; None
    when any element is not a plain constant string."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for el in node.elts:
            if isinstance(el, ast.Constant) and isinstance(el.value, str):
                out.append(el.value)
            else:
                return None
        return out
    return None


def dump(node: ast.AST) -> str:
    """Location-free structural fingerprint for comparing sub-expressions
    (``t`` in ``t // b`` vs ``t`` in ``assert t % b == 0``)."""
    return ast.dump(node, annotate_fields=False)
