"""Stage 2: the trace auditor.

The linter (stage 1) proves contracts the AST can see; this stage proves
the ones only tracing can: it builds the real engine entrypoints —
``make_dispatch_plan`` / ``execute_dispatch``, ``mcma_dispatch``,
``mcma_dispatch_sharded`` on a mesh, and the decode / prefill-chunk
steps (dense AND paged-KV layouts — 2 page sizes x 2 row masks with
varying block-table contents) — and drives each compiled program across
a capacity ladder, QoS margin settings, residency sets, and row masks,
asserting the three runtime contracts every PR so far has defended ad
hoc:

  TA001  exactly one compile per entrypoint per capacity point: QoS
         margins, residency vectors, tiers, and row masks are TRACED
         inputs — only capacities (shapes) may compile a new program;
  TA002  invoke-stats counters are int32 — a dtype drift (int64 under
         x64, int16 from a careless cast) breaks the psum exactness
         contract and the autotuner's accumulators;
  TA003  no host callbacks inside the traced program — a stray
         ``jax.debug.callback`` / ``pure_callback`` stalls every decode
         tick on a device->host round trip.

Findings use the same ``Finding`` record as the linter, with
``audit:<entrypoint>`` paths, so the CLI and baseline machinery treat
both stages uniformly.  The helpers (``retrace_findings``,
``stats_dtype_findings``, ``callback_findings``) are reusable on any
jitted function — tests use them directly instead of copy-pasting
``fn._cache_size() == 1`` asserts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis.findings import Finding
from repro.analysis.jit_cache import cache_size

# host-callback primitives by jaxpr name (TA003)
CALLBACK_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "host_callback_call", "outside_call",
})


# ---------------------------------------------------------------------------
# reusable checks
# ---------------------------------------------------------------------------

def retrace_findings(fn, *, scope: str, path: str = "audit:trace",
                     expected: int = 1) -> list[Finding]:
    """TA001 on an already-exercised jitted ``fn``: its compile cache
    must hold exactly ``expected`` programs.  Silently passes when the
    jax build does not expose a cache counter."""
    n = cache_size(fn)
    if n is None or n == expected:
        return []
    return [Finding(
        rule="TA001", path=path, line=0, scope=scope, detail="retrace",
        message=(f"{scope}: {n} compiled programs where {expected} "
                 "expected — a traced input (margins / residency / tier "
                 "/ row_mask) forced a retrace; only capacities (shapes) "
                 "may compile new programs"))]


def stats_dtype_findings(stats, *, scope: str,
                         path: str = "audit:trace") -> list[Finding]:
    """TA002: every integer-dtype leaf of an invoke-stats pytree must be
    exactly int32 (the psum exactness contract and the autotuner's
    accumulators assume it)."""
    findings = []
    leaves = jax.tree_util.tree_leaves_with_path(
        stats.asdict() if hasattr(stats, "asdict") else stats)
    for keypath, leaf in leaves:
        dt = getattr(leaf, "dtype", None)
        if dt is None or not jnp.issubdtype(dt, jnp.integer):
            continue
        if dt != jnp.int32:
            name = jax.tree_util.keystr(keypath)
            findings.append(Finding(
                rule="TA002", path=path, line=0, scope=scope,
                detail=f"stats-dtype:{name}",
                message=(f"{scope}: stats leaf {name} is {dt}, not int32 "
                         "— integer counters must stay int32 end to end "
                         "(psum exactness, autotune accumulators)")))
    return findings


def _sub_jaxprs(value):
    if isinstance(value, jax.core.ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, jax.core.Jaxpr):
        yield value
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _sub_jaxprs(v)


def _walk_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from _walk_eqns(sub)


def callback_findings(fn, args, *, scope: str, kwargs=None,
                      path: str = "audit:trace") -> list[Finding]:
    """TA003: abstractly trace ``fn(*args)`` and walk the jaxpr (and all
    nested call/scan/cond jaxprs) for host-callback primitives."""
    jaxpr = jax.make_jaxpr(fn)(*args, **(kwargs or {}))
    findings = []
    seen = set()
    for eqn in _walk_eqns(jaxpr.jaxpr):
        name = eqn.primitive.name
        if name in CALLBACK_PRIMITIVES and name not in seen:
            seen.add(name)
            findings.append(Finding(
                rule="TA003", path=path, line=0, scope=scope,
                detail=f"callback:{name}",
                message=(f"{scope}: traced program contains a {name} "
                         "host callback — every invocation round-trips "
                         "to the host and stalls the decode tick")))
    return findings


# ---------------------------------------------------------------------------
# the audited entrypoints
# ---------------------------------------------------------------------------

# capacity ladder: ≥3 (exact_cap, invoke_cap) points; each is its own
# compilation unit by design (capacities are shapes)
CAPACITY_LADDER = ((64, 32), (48, 16), (32, 8))
MARGIN_SETS = ([8.0, 0.0, -8.0], [0.0, 0.0, 0.0])      # 2 QoS margin vectors
RESIDENCY_SETS = ([4, 1], [2, 5])                      # 2 hot sets, lib=6
_T, _LIB, _D, _DH = 64, 6, 32, 12


def _mk_engine_case(seed: int = 0):
    """Inputs + library-wide router logits + prepadded library stacks,
    mirroring the shapes the library tests pin."""
    from repro.kernels import ops
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(ks[0], (_T, _D), jnp.float32) * 0.5
    router = jax.random.normal(ks[1], (_D, _LIB + 1)) * 0.5
    w1 = jax.random.normal(ks[2], (_LIB, _D, _DH)) * 0.2
    b1 = jax.random.normal(ks[3], (_LIB, _DH)) * 0.1
    w2 = jax.random.normal(ks[4], (_LIB, _DH, _D)) * 0.2
    b2 = jax.random.normal(ks[5], (_LIB, _D)) * 0.1
    wi = jax.random.normal(jax.random.fold_in(ks[0], 7), (_D, 2 * _D)) * 0.1
    wo = jax.random.normal(jax.random.fold_in(ks[0], 8), (2 * _D, _D)) * 0.1
    stacks = ops.prepad_switched_weights(w1, b1, w2, b2)
    return x, x @ router, stacks, (wi, wo)


def _variants():
    """The traced-input grid every compiled program must absorb:
    2 margin vectors x 2 residency sets x 2 row masks, with a mixed
    3-tier vector throughout."""
    tier = jnp.asarray([i % 3 for i in range(_T)], jnp.int32)
    masks = (jnp.ones((_T,), bool),
             jnp.asarray([True] * (_T - 8) + [False] * 8))
    out = []
    for m in MARGIN_SETS:
        for r in RESIDENCY_SETS:
            for mask in masks:
                out.append((tier, jnp.asarray(m, jnp.float32),
                            jnp.asarray(r, jnp.int32), mask))
    return out


def _audit_engine(backend: str) -> list[Finding]:
    """jit(mcma_dispatch) per capacity-ladder point: TA001/TA002/TA003."""
    from repro.runtime import dispatch as D
    x, logits, stacks, (wi, wo) = _mk_engine_case()
    exact_fn = lambda xb: jnp.dot(jax.nn.silu(jnp.dot(xb, wi)), wo)
    findings = []
    for exact_cap, invoke_cap in CAPACITY_LADDER:
        scope = f"mcma_dispatch[{backend},cap=({exact_cap},{invoke_cap})]"

        def run(xv, lg, tier, margins, residency, mask):
            return D.mcma_dispatch(
                xv, lg, exact_fn, *stacks, exact_cap=exact_cap,
                invoke_cap=invoke_cap, backend=backend, block_t=16,
                interpret=backend != "xla", weights_prepadded=True,
                row_mask=mask, tier=tier, tier_margins=margins,
                residency=residency)

        fn = jax.jit(run)
        stats = None
        for tier, margins, residency, mask in _variants():
            _, stats = fn(x, logits, tier, margins, residency, mask)
        findings += retrace_findings(fn, scope=scope, path="audit:engine")
        findings += stats_dtype_findings(stats, scope=scope,
                                         path="audit:engine")
        findings += callback_findings(run, (x, logits) + _variants()[0],
                                      scope=scope, path="audit:engine")
    return findings


def _audit_plan_execute(backend: str) -> list[Finding]:
    """The split API: one compiled plan builder + one compiled executor
    absorb every traced-input variant at a fixed capacity point."""
    from repro.runtime import dispatch as D
    x, logits, stacks, (wi, wo) = _mk_engine_case(1)
    exact_fn = lambda xb: jnp.dot(jax.nn.silu(jnp.dot(xb, wi)), wo)
    exact_cap, invoke_cap = CAPACITY_LADDER[1]
    scope_p = f"make_dispatch_plan[{backend}]"
    scope_e = f"execute_dispatch[{backend}]"

    plan_fn = jax.jit(lambda lg, tier, margins, residency, mask:
                      D.make_dispatch_plan(
                          lg, mask, exact_cap=exact_cap,
                          invoke_cap=invoke_cap, backend=backend,
                          block_t=16, tier=tier, tier_margins=margins,
                          residency=residency))
    # a plan built against a residency set executes against the
    # resident-GATHERED stacks (the hot set), exactly as the server does
    from repro.kernels import ops
    exec_fn = jax.jit(lambda plan, xv, residency: D.execute_dispatch(
        plan, xv, exact_fn, *ops.gather_resident_stacks(*stacks, residency),
        interpret=backend != "xla", weights_prepadded=True))
    findings = []
    for tier, margins, residency, mask in _variants():
        plan = plan_fn(logits, tier, margins, residency, mask)
        exec_fn(plan, x, residency)
    findings += retrace_findings(plan_fn, scope=scope_p, path="audit:engine")
    findings += retrace_findings(exec_fn, scope=scope_e, path="audit:engine")
    tier, margins, residency, mask = _variants()[0]
    findings += callback_findings(
        lambda lg, t, m, r, k: D.plan_invoke_stats(
            D.make_dispatch_plan(lg, k, exact_cap=exact_cap,
                                 invoke_cap=invoke_cap, backend=backend,
                                 block_t=16, tier=t, tier_margins=m,
                                 residency=r)).asdict(),
        (logits, tier, margins, residency, mask),
        scope=scope_p, path="audit:engine")
    return findings


def _audit_sharded(backend: str) -> list[Finding]:
    """mcma_dispatch_sharded on a 1-device ("data",) mesh: the shard_map
    wrapper must preserve the zero-retrace contract."""
    import numpy as np
    from repro.runtime import dispatch as D
    x, logits, stacks, (wi, wo) = _mk_engine_case(2)
    exact_fn = lambda p, xb: jnp.dot(jax.nn.silu(jnp.dot(xb, p[0])), p[1])
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    exact_cap, invoke_cap = CAPACITY_LADDER[0]
    scope = f"mcma_dispatch_sharded[{backend}]"

    fn = jax.jit(lambda xv, lg, tier, margins, residency, mask:
                 D.mcma_dispatch_sharded(
                     mesh, xv, lg, exact_fn, (wi, wo), *stacks,
                     exact_cap=exact_cap, invoke_cap=invoke_cap,
                     backend=backend, block_t=16,
                     interpret=backend != "xla",
                     weights_prepadded=True, row_mask=mask, tier=tier,
                     tier_margins=margins, residency=residency))
    stats = None
    for tier, margins, residency, mask in _variants():
        _, stats = fn(x, logits, tier, margins, residency, mask)
    findings = retrace_findings(fn, scope=scope, path="audit:engine")
    findings += stats_dtype_findings(stats, scope=scope, path="audit:engine")
    return findings


def _audit_steps(backend: str) -> list[Finding]:
    """The served entrypoints: one compiled decode step and one compiled
    prefill-chunk step absorb margins, residency swaps, tiers, and row
    masks on the smoke model with a 6-wide library."""
    import dataclasses

    from repro.configs.registry import get_config, smoke_config
    from repro.models import model as M
    from repro.runtime import steps as steps_lib

    base = smoke_config(get_config("internlm2-1.8b"))
    cfg = dataclasses.replace(base, approx=dataclasses.replace(
        base.approx, enable=True, library_size=6, backend=backend,
        **(dict(interpret=True, block_t=16) if backend != "xla" else {})))
    b = 4
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    toks = jnp.arange(1, b + 1, dtype=jnp.int32)[:, None]
    tier = jnp.asarray([0, 1, 2, 1], jnp.int32)
    masks = (jnp.ones((b,), bool), jnp.asarray([True, True, True, False]))

    decode = steps_lib.make_decode_step(cfg, use_mcma_dispatch=True,
                                        with_stats=True)
    chunk = steps_lib.make_prefill_chunk_step(cfg, use_mcma_dispatch=True,
                                              with_stats=True)
    decode_fn, chunk_fn = jax.jit(decode), jax.jit(chunk)
    ctoks = jnp.tile(toks, (1, 4))
    n_valid = jnp.asarray([4, 2, 4, 0], jnp.int32)

    findings, metrics = [], None
    for m in MARGIN_SETS:
        for r in RESIDENCY_SETS:
            for mask in masks:
                margins = jnp.asarray(m, jnp.float32)
                residency = jnp.asarray(r, jnp.int32)
                cache = M.init_cache(cfg, b, 32)
                _, _, metrics = decode_fn(params, cache, toks, mask, tier,
                                          margins, residency)
                cache = M.init_cache(cfg, b, 32)
                chunk_fn(params, cache, ctoks, n_valid, mask, tier,
                         margins, residency)
    findings += retrace_findings(decode_fn, scope=f"decode_step[{backend}]",
                                 path="audit:steps")
    findings += retrace_findings(chunk_fn,
                                 scope=f"prefill_chunk_step[{backend}]",
                                 path="audit:steps")
    findings += stats_dtype_findings(
        {k: v for k, v in metrics.items()
         if hasattr(v, "dtype") and jnp.issubdtype(v.dtype, jnp.integer)},
        scope=f"decode_step[{backend}]", path="audit:steps")
    findings += callback_findings(
        decode, (params, M.init_cache(cfg, b, 32), toks, masks[0], tier,
                 jnp.asarray(MARGIN_SETS[0], jnp.float32),
                 jnp.asarray(RESIDENCY_SETS[0], jnp.int32)),
        scope=f"decode_step[{backend}]", path="audit:steps")
    return findings


def _audit_paged_steps(backend: str) -> list[Finding]:
    """The paged-KV serving entrypoints: per page size (its own shape,
    so its own compilation unit) ONE compiled decode step and ONE
    compiled prefill-chunk step absorb every block-table content, slot
    position, and row mask the allocator can produce — page allocation
    and free churn are traced-input changes, never retraces."""
    import dataclasses

    from repro.configs.registry import get_config, smoke_config
    from repro.models import model as M
    from repro.runtime import steps as steps_lib

    base = smoke_config(get_config("internlm2-1.8b"))
    cfg = dataclasses.replace(base, approx=dataclasses.replace(
        base.approx, enable=True, library_size=6, backend=backend,
        **(dict(interpret=True, block_t=16) if backend != "xla" else {})))
    b, max_len = 4, 32
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    toks = jnp.arange(1, b + 1, dtype=jnp.int32)[:, None]
    tier = jnp.asarray([0, 1, 2, 1], jnp.int32)
    masks = (jnp.ones((b,), bool), jnp.asarray([True, True, True, False]))
    margins = jnp.asarray(MARGIN_SETS[0], jnp.float32)
    residency = jnp.asarray(RESIDENCY_SETS[0], jnp.int32)

    ctoks = jnp.tile(toks, (1, 4))
    n_valid = jnp.asarray([4, 2, 4, 0], jnp.int32)

    findings = []
    for page_size in (8, 16):
        assert max_len % page_size == 0, (max_len, page_size)
        n_pp = max_len // page_size
        n_pages = b * n_pp
        # fresh step closures per page size: jax.jit keys its cache on
        # the underlying callable, so re-wrapping ONE closure would count
        # the other page size's (legitimately different-shape) program
        # as a retrace
        decode = steps_lib.make_decode_step(cfg, use_mcma_dispatch=True,
                                            with_stats=True)
        chunk = steps_lib.make_prefill_chunk_step(
            cfg, use_mcma_dispatch=True, with_stats=True)
        # two allocator states: in-order pages vs a scrambled free list
        # with partially-filled rows (holes stay -1)
        ident = jnp.arange(n_pages, dtype=jnp.int32).reshape(b, n_pp)
        perm = jnp.asarray(
            [(7 * k + 3) % n_pages for k in range(n_pages)],
            jnp.int32).reshape(b, n_pp)
        perm = perm.at[:, n_pp // 2:].set(-1) if n_pp > 1 else perm
        tables = (ident, perm)

        decode_fn, chunk_fn = jax.jit(decode), jax.jit(chunk)
        for bt in tables:
            for mask in masks:
                cache = M.init_cache(cfg, b, max_len, page_size=page_size,
                                     kv_pages=n_pages)
                cache = dict(cache, block_table=bt)
                decode_fn(params, cache, toks, mask, tier, margins,
                          residency)
                cache = M.init_cache(cfg, b, max_len, page_size=page_size,
                                     kv_pages=n_pages)
                cache = dict(cache, block_table=bt)
                chunk_fn(params, cache, ctoks, n_valid, mask, tier,
                         margins, residency)
        tag = f"[{backend},P={page_size}]"
        findings += retrace_findings(
            decode_fn, scope=f"paged_decode_step{tag}", path="audit:steps")
        findings += retrace_findings(
            chunk_fn, scope=f"paged_prefill_chunk_step{tag}",
            path="audit:steps")
        findings += callback_findings(
            decode,
            (params, M.init_cache(cfg, b, max_len, page_size=page_size,
                                  kv_pages=n_pages),
             toks, masks[0], tier, margins, residency),
            scope=f"paged_decode_step{tag}", path="audit:steps")
    return findings


def run_audit(*, backends=("xla", "pallas", "pallas_fused"),
              with_steps: bool = True) -> list[Finding]:
    """Trace-audit every engine entrypoint; [] = every contract holds.

    The default sweep covers all three executors — the XLA oracle, the
    unfused Pallas kernel, and the fused-dispatch kernel
    (``pallas_fused``, kernels/fused_dispatch.py) — so the fused
    entrypoint is held to the same one-compile / int32-stats /
    no-callback contracts.  ``backends`` narrows the sweep;
    ``with_steps=False`` skips the (heavier) decode / prefill-chunk
    model steps for quick engine-only runs."""
    jax.config.update("jax_platform_name", "cpu")
    findings: list[Finding] = []
    for be in backends:
        findings += _audit_engine(be)
        findings += _audit_plan_execute(be)
        findings += _audit_sharded(be)
        if with_steps:
            findings += _audit_steps(be)
            findings += _audit_paged_steps(be)
    findings.sort(key=lambda f: (f.path, f.scope, f.rule))
    return findings
