"""RL005 — Pallas block/grid arithmetic must prove divisibility.

The weight-switch kernel's contract (kernels/switched_mlp.py): every
grid/block computation that floor-divides must either use ``pl.cdiv``,
the round-up idiom ``(x + b - 1) // b``, or sit behind an explicit
divisibility assert (``assert t % block_t == 0``) in the same function.
A bare ``t // block_t`` silently TRUNCATES when t stops dividing — rows
past the last full tile never launch, the kernel returns zeros for them,
and the pallas-vs-xla oracle gate is the only thing standing between
that and production (this is exactly how a block_t change corrupts the
class-sort plan: ops.class_sort_plan pads to ``worst_case_rows`` and
asserts the tile math stays exact).

A second Pallas contract: a ``BlockSpec`` index_map must take one
argument per grid dimension (plus one per scalar-prefetch operand under
``PrefetchScalarGridSpec`` — the fused dispatch kernel prefetches TWO
operands, the row-index vector and tile_cls, so its maps take
``grid rank + 2`` args).  Checked when the grid is a literal tuple (or a
single local assignment of one); the index_map may be an inline lambda
OR a name resolving to a single same-function ``def`` / lambda
assignment (kernels/fused_dispatch.py factors its maps out as named
functions).

Scope: modules that import ``jax.experimental.pallas``, plus the
``kernels/`` tree (ops.py builds the tile grids without importing
pallas).  Page-grid arithmetic is covered EVERYWHERE: a floor division
whose denominator mentions ``page`` (the paged-KV block tables in
models/, the server's allocator) is held to the same contract in any
module — a non-dividing page size silently truncates the block table
exactly like a grid tile.
"""
from __future__ import annotations

import ast

from repro.analysis import astutil
from repro.analysis.findings import Finding

RULE_ID = "RL005"
SUMMARY = ("Pallas grid/block floor divisions need pl.cdiv, the round-up "
           "idiom, or a same-function divisibility assert; BlockSpec "
           "index_map arity must match grid rank + scalar prefetch")


def _uses_pallas(mod: astutil.ModuleInfo) -> bool:
    """Modules that import pallas, plus everything under ``kernels/`` —
    ops.py computes the class-sort tile grids the Pallas kernels consume
    without importing pallas itself, and its arithmetic is bound by the
    same divisibility contract."""
    if any(v.startswith("jax.experimental.pallas")
           for v in mod.aliases.values()):
        return True
    return "kernels/" in mod.path


def _divisibility_asserts(fn: ast.FunctionDef) -> set[tuple[str, str]]:
    """{(dump(numerator), dump(denominator))} proven by asserts of the
    form ``assert a % b == 0`` (also found inside and/or chains)."""
    proven = set()
    for node in ast.walk(fn):
        if not isinstance(node, ast.Assert):
            continue
        tests = [node.test]
        while tests:
            t = tests.pop()
            if isinstance(t, ast.BoolOp):
                tests.extend(t.values)
                continue
            if isinstance(t, ast.Compare) and len(t.ops) == 1 \
                    and isinstance(t.ops[0], ast.Eq) \
                    and isinstance(t.left, ast.BinOp) \
                    and isinstance(t.left.op, ast.Mod) \
                    and isinstance(t.comparators[0], ast.Constant) \
                    and t.comparators[0].value == 0:
                proven.add((astutil.dump(t.left.left),
                            astutil.dump(t.left.right)))
        # noqa: the while pops handle nested BoolOps
    return proven


def _is_roundup_idiom(num: ast.AST, den: ast.AST) -> bool:
    """(x + b - 1) // b — numerator mentions the denominator and
    subtracts/adds a 1 next to it."""
    nd, dd = astutil.dump(num), astutil.dump(den)
    if dd not in nd:
        return False
    return any(isinstance(n, ast.Constant) and n.value == 1
               for n in ast.walk(num))


def _resolve_grid(node: ast.AST, fn: ast.FunctionDef):
    """Grid rank: literal tuple, int constant (rank 1), or a single local
    assignment of one.  None = unresolvable."""
    if isinstance(node, ast.Tuple):
        return len(node.elts)
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return 1
    if isinstance(node, ast.Name):
        assigns = [n for n in ast.walk(fn)
                   if isinstance(n, ast.Assign) and len(n.targets) == 1
                   and isinstance(n.targets[0], ast.Name)
                   and n.targets[0].id == node.id]
        if len(assigns) == 1:
            v = assigns[0].value
            if isinstance(v, ast.Tuple):
                return len(v.elts)
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return 1
    return None


def _resolve_named_map(name_node: ast.Name, fn: ast.FunctionDef, mod):
    """An index_map passed by NAME: resolve to the single same-function
    ``def`` (nested functions included) or lambda assignment, falling
    back to a module-level ``def``.  None = unresolvable (imported,
    shadowed, or ambiguous) — those stay unchecked rather than guessed.
    """
    nm = name_node.id
    cands = [n for n in ast.walk(fn) if isinstance(n, ast.FunctionDef)
             and n.name == nm]
    cands += [n.value for n in ast.walk(fn)
              if isinstance(n, ast.Assign) and len(n.targets) == 1
              and isinstance(n.targets[0], ast.Name)
              and n.targets[0].id == nm
              and isinstance(n.value, ast.Lambda)]
    if not cands:
        cands = [n for n in mod.tree.body
                 if isinstance(n, ast.FunctionDef) and n.name == nm]
    return cands[0] if len(cands) == 1 else None


def _check_index_map_arity(mod, fn, spec_call, findings):
    """``spec_call`` is a GridSpec / PrefetchScalarGridSpec /
    pallas_call(...) Call carrying grid= — check every BlockSpec lambda
    in its subtree."""
    grid_node = next((kw.value for kw in spec_call.keywords
                      if kw.arg == "grid"), None)
    if grid_node is None:
        return
    rank = _resolve_grid(grid_node, fn)
    if rank is None:
        return
    prefetch = next((kw.value for kw in spec_call.keywords
                     if kw.arg == "num_scalar_prefetch"), None)
    n_prefetch = prefetch.value if isinstance(prefetch, ast.Constant) \
        and isinstance(prefetch.value, int) else 0
    want = rank + n_prefetch
    for call in [n for n in ast.walk(spec_call) if isinstance(n, ast.Call)]:
        name = mod.canonical(call.func) or ""
        if not name.endswith("BlockSpec"):
            continue
        for arg in list(call.args) + [kw.value for kw in call.keywords
                                      if kw.arg == "index_map"]:
            target = arg
            if isinstance(arg, ast.Name):
                target = _resolve_named_map(arg, fn, mod)
            if not isinstance(target, (ast.Lambda, ast.FunctionDef)):
                continue
            if target.args.vararg is not None:
                continue                      # *args absorbs any arity
            got = len(target.args.args)
            if got != want:
                findings.append(Finding(
                    rule=RULE_ID, path=mod.path, line=arg.lineno,
                    scope=fn.name,
                    detail=f"index-map-arity:{got}:{want}",
                    message=(f"BlockSpec index_map takes {got} args "
                             f"but the grid has rank {rank} with "
                             f"{n_prefetch} scalar-prefetch operand(s)"
                             f" — it must take {want}")))


def check(mod: astutil.ModuleInfo) -> list[Finding]:
    pallas_scope = _uses_pallas(mod)
    findings = []
    for fn, _ in astutil.functions(mod.tree):
        proven = _divisibility_asserts(fn)
        for node in ast.walk(fn):
            if isinstance(node, ast.BinOp) \
                    and isinstance(node.op, ast.FloorDiv):
                num, den = node.left, node.right
                # outside the pallas/kernels scope only page-grid
                # divisions are bound by the contract
                if not pallas_scope \
                        and "page" not in astutil.dump(den).lower():
                    continue
                if (astutil.dump(num), astutil.dump(den)) in proven:
                    continue
                if _is_roundup_idiom(num, den):
                    continue
                findings.append(Finding(
                    rule=RULE_ID, path=mod.path, line=node.lineno,
                    scope=fn.name,
                    detail=f"floordiv:{ast.unparse(node)[:48]}",
                    message=(f"`{ast.unparse(node)}` floor-divides with no "
                             "pl.cdiv / round-up idiom / divisibility "
                             "assert in this function — a non-dividing "
                             "size silently truncates the grid (rows past "
                             "the last tile never launch)")))
            elif pallas_scope and isinstance(node, ast.Call):
                name = mod.canonical(node.func) or ""
                if name.endswith(("GridSpec", "pallas_call")) \
                        or "pallas_call" in name:
                    _check_index_map_arity(mod, fn, node, findings)
    return findings
