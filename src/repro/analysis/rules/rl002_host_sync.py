"""RL002 — host synchronization inside jitted serve-path code.

A ``.item()`` / ``float(traced)`` / ``np.asarray(traced)`` inside traced
code either fails at trace time (ConcretizationTypeError, the lucky
case) or — when it happens to run on a concrete value during tracing —
silently bakes one call's value into the compiled program.  On the serve
path it also forces a device->host sync that stalls the decode tick.

Scope: the serve-path modules (``LintContext.SERVE_PATH`` — the dispatch
engine, the step builders, the kernels, and the model forward modules,
whose function bodies all execute under jit), plus any jit-decorated
function anywhere in the tree.  Host-side modules (server loop, benches,
launchers) legitimately call ``np.asarray`` on step OUTPUTS and are out
of scope.

``int(x.shape[0])``-style calls are exempt: shapes/dtypes/ndim are
static metadata, reading them never syncs.
"""
from __future__ import annotations

import ast

from repro.analysis import astutil
from repro.analysis.findings import Finding

RULE_ID = "RL002"
SUMMARY = ("no host-sync calls (.item(), float()/int() on traced arrays, "
           "np.asarray, device_get) inside jitted serve-path code")

_HOST_METHODS = ("item", "tolist", "block_until_ready")
_HOST_CALLS = ("numpy.asarray", "numpy.array", "jax.device_get")
_STATIC_ATTRS = ("shape", "ndim", "dtype", "size", "sharding")
_CASTS = ("float", "int", "bool")


def _array_params(fn: ast.FunctionDef) -> set[str]:
    """Parameters annotated as arrays (``jax.Array``, ``jnp.ndarray``)."""
    out = set()
    for p in fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs:
        if p.annotation is not None:
            ann = ast.unparse(p.annotation)
            if "Array" in ann or "ndarray" in ann:
                out.add(p.arg)
    return out


def _mentions_array_without_static_attr(node: ast.AST,
                                        arrays: set[str]) -> bool:
    has_static = any(isinstance(n, ast.Attribute) and n.attr in _STATIC_ATTRS
                     for n in ast.walk(node))
    if has_static:
        return False
    return any(isinstance(n, ast.Name) and n.id in arrays
               for n in ast.walk(node))


def _own_nodes(fn: ast.FunctionDef):
    """Nodes belonging to ``fn`` itself — nested def/class bodies are
    excluded (they are visited as functions in their own right), lambda
    bodies are included (nobody else visits them)."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        n = stack.pop()
        yield n
        if not isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef)):
            stack.extend(ast.iter_child_nodes(n))


def check(mod: astutil.ModuleInfo) -> list[Finding]:
    in_scope_module = mod.ctx is not None and mod.ctx.is_serve_path(mod.path)
    findings = []
    for fn, stack in astutil.functions(mod.tree):
        jitted = astutil.jit_decorator(mod, fn) is not None or any(
            isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef))
            and astutil.jit_decorator(mod, s) is not None for s in stack)
        if not (in_scope_module or jitted):
            continue
        arrays = _array_params(fn)
        for call in [n for n in _own_nodes(fn) if isinstance(n, ast.Call)]:
            name = mod.canonical(call.func)
            if isinstance(call.func, ast.Attribute) \
                    and call.func.attr in _HOST_METHODS \
                    and not call.args and not call.keywords:
                findings.append(Finding(
                    rule=RULE_ID, path=mod.path, line=call.lineno,
                    scope=fn.name, detail=f"method:{call.func.attr}",
                    message=(f".{call.func.attr}() is a host sync — "
                             "inside jitted serve-path code it either "
                             "fails at trace time or bakes in one "
                             "call's value")))
            elif name in _HOST_CALLS:
                findings.append(Finding(
                    rule=RULE_ID, path=mod.path, line=call.lineno,
                    scope=fn.name, detail=f"call:{name}",
                    message=(f"{name}() materializes on host — traced "
                             "serve-path values must stay jnp; use "
                             "jnp.asarray for constants")))
            elif (isinstance(call.func, ast.Name)
                  and call.func.id in _CASTS and len(call.args) == 1
                  and _mentions_array_without_static_attr(call.args[0],
                                                          arrays)):
                findings.append(Finding(
                    rule=RULE_ID, path=mod.path, line=call.lineno,
                    scope=fn.name,
                    detail=f"cast:{call.func.id}:"
                           f"{ast.unparse(call.args[0])[:40]}",
                    message=(f"{call.func.id}() on a traced array "
                             "forces a host sync / concretization "
                             "(shape/dtype reads are exempt — this "
                             "argument reads the array's VALUE)")))
    return findings
