"""RL003 — pytree-registration drift on traced-data dataclasses.

The ``DispatchPlan``/``InvokeStats`` pattern (runtime/dispatch.py): a
frozen dataclass of jax arrays, registered with a flatten lambda reading
an explicit field-name tuple and an unflatten calling the constructor
POSITIONALLY (``lambda meta, data: DispatchPlan(*data, *meta)``).  Two
ways this silently corrupts data instead of erroring:

  * the dataclass is never registered: jit treats every instance as a
    static leaf — each new plan RETRACES the whole program (a production
    recompile stall, not a crash);
  * a field is added to the dataclass but not to the flatten tuple (it
    silently drops through jit), or the tuple order drifts from the
    field order (the positional unflatten reassembles values into the
    WRONG fields — cls becomes rank, counts becomes dispatched...).

The rule flags (a) any dataclass with an array-annotated field that is
not ``register_pytree_node``-ed in its module, and (b) any registration
whose resolvable flatten-name tuples do not reconstruct the dataclass
field list exactly, in order.  ``tuple(f.name for f in
dataclasses.fields(Cls))`` is recognized as "all fields, in order".
"""
from __future__ import annotations

import ast

from repro.analysis import astutil
from repro.analysis.findings import Finding

RULE_ID = "RL003"
SUMMARY = ("dataclasses holding jax arrays must be registered pytrees, and "
           "flatten/unflatten field tuples must match the field order")

_ALL_FIELDS = "__ALL_FIELDS__"   # sentinel: dataclasses.fields(Cls) in order


def _is_traced_array_ann(ann: str) -> bool:
    """True when the annotation names a JAX array type.  ``np.ndarray``
    fields (host-side request/registry dataclasses) and ``Callable[...,
    jax.Array]`` fields (functions OVER arrays, not arrays) are not
    traced data and must not trip the registration requirement."""
    if "Callable" in ann:
        return False
    return ("jax.Array" in ann or "jnp.ndarray" in ann
            or ann.split("|")[0].strip() in ("Array", "chex.Array"))


def _dataclasses(mod: astutil.ModuleInfo):
    """{class name: (node, [field names], has_array_field)}"""
    out = {}
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        is_dc = False
        for dec in node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            if mod.canonical(target) in ("dataclasses.dataclass",
                                         "dataclass"):
                is_dc = True
        if not is_dc:
            continue
        fields, has_array = [], False
        for stmt in node.body:
            if isinstance(stmt, ast.AnnAssign) \
                    and isinstance(stmt.target, ast.Name):
                ann = ast.unparse(stmt.annotation)
                if "ClassVar" in ann:
                    continue
                fields.append(stmt.target.id)
                if _is_traced_array_ann(ann):
                    has_array = True
        out[node.name] = (node, fields, has_array)
    return out


def _is_fields_call(mod, node: ast.AST, cls: str | None) -> bool:
    """``dataclasses.fields(Cls)`` (for the right class, when known)."""
    if not (isinstance(node, ast.Call)
            and mod.canonical(node.func) in ("dataclasses.fields", "fields")
            and node.args):
        return False
    return cls is None or (isinstance(node.args[0], ast.Name)
                           and node.args[0].id == cls)


def _module_tuple(mod: astutil.ModuleInfo, name: str, cls: str):
    """Resolve a module-level NAME to a field-name list: a literal tuple
    of strings, or ``tuple(f.name for f in dataclasses.fields(Cls))``
    (-> the all-fields sentinel).  None = unresolvable."""
    for node in mod.tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id == name:
            items = astutil.string_items(node.value)
            if items is not None:
                return items
            for n in ast.walk(node.value):
                if _is_fields_call(mod, n, cls):
                    return _ALL_FIELDS
    return None


def _names_from_expr(mod: astutil.ModuleInfo, expr: ast.AST, cls: str):
    """A flatten-side children expression -> field-name list.

    Handles: ``None`` (no aux), a literal string tuple, ``NAME`` resolved
    at module level, and ``tuple(getattr(p, f) for f in X)`` where X is a
    NAME / literal / ``dataclasses.fields(Cls)``.  None = unresolvable.
    """
    if isinstance(expr, ast.Constant) and expr.value is None:
        return []
    items = astutil.string_items(expr)
    if items is not None:
        return items
    if isinstance(expr, ast.Name):
        return _module_tuple(mod, expr.id, cls)
    if isinstance(expr, ast.Call) and isinstance(expr.func, ast.Name) \
            and expr.func.id == "tuple" and len(expr.args) == 1 \
            and isinstance(expr.args[0], ast.GeneratorExp):
        gen = expr.args[0].generators[0]
        src = gen.iter
        if _is_fields_call(mod, src, cls):
            return _ALL_FIELDS
        if isinstance(src, ast.Name):
            return _module_tuple(mod, src.id, cls)
        return astutil.string_items(src)
    return None


def _registrations(mod: astutil.ModuleInfo):
    """[(call node, class name, flatten lambda, unflatten lambda)]"""
    out = []
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            name = mod.canonical(node.func) or ""
            if name.endswith("register_pytree_node") and len(node.args) >= 3 \
                    and isinstance(node.args[0], ast.Name):
                out.append((node, node.args[0].id, node.args[1],
                            node.args[2]))
    return out


def _expected_ctor_order(mod, cls, flatten, unflatten):
    """Field order the positional unflatten reconstructs, or None when
    any part is not statically resolvable (then the rule stays silent —
    documented limitation, not a finding)."""
    if not (isinstance(flatten, ast.Lambda)
            and isinstance(flatten.body, ast.Tuple)
            and len(flatten.body.elts) == 2):
        return None
    data = _names_from_expr(mod, flatten.body.elts[0], cls)
    meta = _names_from_expr(mod, flatten.body.elts[1], cls)
    if data is None or meta is None:
        return None
    if not (isinstance(unflatten, ast.Lambda)
            and isinstance(unflatten.body, ast.Call)
            and isinstance(unflatten.body.func, ast.Name)
            and unflatten.body.func.id == cls
            and not unflatten.body.keywords):
        return None
    # map the unflatten's *starred args back to (data, meta) by the
    # lambda's own parameter names: lambda aux, children -> Cls(...)
    lam_params = [p.arg for p in unflatten.args.args]
    if len(lam_params) != 2:
        return None
    by_param = {lam_params[0]: meta, lam_params[1]: data}  # (aux, children)
    order = []
    for a in unflatten.body.args:
        if isinstance(a, ast.Starred) and isinstance(a.value, ast.Name) \
                and a.value.id in by_param:
            order.append(by_param[a.value.id])
        else:
            return None
    if any(part == _ALL_FIELDS for part in order):
        return _ALL_FIELDS if order.count(_ALL_FIELDS) == len(order) == 1 \
            or (len(order) == 2 and order[1] == [] ) else None
    return [f for part in order for f in part]


def check(mod: astutil.ModuleInfo) -> list[Finding]:
    findings = []
    classes = _dataclasses(mod)
    regs = _registrations(mod)
    registered = {cls for _, cls, _, _ in regs}

    for cls, (node, fields, has_array) in classes.items():
        if has_array and cls not in registered:
            findings.append(Finding(
                rule=RULE_ID, path=mod.path, line=node.lineno, scope=cls,
                detail="unregistered",
                message=(f"dataclass {cls} holds jax arrays but is not "
                         "register_pytree_node-ed in this module — jit "
                         "treats each instance as static and RETRACES "
                         "per instance")))

    for call, cls, flatten, unflatten in regs:
        if cls not in classes:
            continue
        fields = classes[cls][1]
        expected = _expected_ctor_order(mod, cls, flatten, unflatten)
        if expected is None:
            continue        # unresolvable pattern: out of the rule's reach
        if expected == _ALL_FIELDS:
            continue        # dataclasses.fields(Cls) cannot drift
        if expected != fields:
            missing = [f for f in fields if f not in expected]
            extra = [f for f in expected if f not in fields]
            if missing or extra:
                why = (f"missing {missing} / unknown {extra}")
            else:
                why = "order differs from the dataclass field order"
            findings.append(Finding(
                rule=RULE_ID, path=mod.path, line=call.lineno, scope=cls,
                detail="field-drift",
                message=(f"pytree registration of {cls} drifted: {why} — "
                         "the positional unflatten will reassemble values "
                         "into the wrong fields (or drop them) instead of "
                         "erroring")))
    return findings
