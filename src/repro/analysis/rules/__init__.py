"""Rule registry — one module per RL rule; ``ALL_RULES`` is what the
linter driver (repro.analysis.lint) runs.

Adding a rule (docs/analysis.md has the worked example):
  1. create ``rlNNN_<slug>.py`` here exporting ``RULE_ID``, ``SUMMARY``
     and ``check(mod: astutil.ModuleInfo) -> list[Finding]``;
  2. append the module to ``ALL_RULES`` below;
  3. give it an injected-violation self-test in tests/test_analysis.py
     (every rule family must be provably able to fail).
"""
from repro.analysis.rules import (rl001_retrace, rl002_host_sync,
                                  rl003_pytree, rl004_psum_axes,
                                  rl005_pallas_blocks)

ALL_RULES = (rl001_retrace, rl002_host_sync, rl003_pytree,
             rl004_psum_axes, rl005_pallas_blocks)

RULE_IDS = tuple(r.RULE_ID for r in ALL_RULES)
