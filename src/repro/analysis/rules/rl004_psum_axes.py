"""RL004 — collective axis names must be declared in sharding/rules.py.

Every mesh this repo builds takes its axis names from the declarative
spec layer (``sharding/rules.py``: the ``("pod", "data")`` DP meta-axis,
``"model"`` TP).  A ``lax.psum(x, "axis")`` whose name is not declared
there fails only at RUN time, inside a shard_map, on a mesh — the worst
possible place — with an unbound-axis error; or worse, a typo'd
data-axis name silently skips the stats reduction the invoke-stats
exactness contract depends on (psum'd ``counts`` must equal the
single-device totals; see runtime/dispatch.py ``stats_axes``).

Literal axis names (strings / tuples of strings, including via a local
``ax = ("data",)`` assignment) are checked against the declared set;
names that reach the collective through function parameters
(``stats_axes``-style plumbing) are accepted — the plumbing pattern is
exactly how the engine stays mesh-agnostic.
"""
from __future__ import annotations

import ast

from repro.analysis import astutil
from repro.analysis.findings import Finding

RULE_ID = "RL004"
SUMMARY = ("lax collective axis names must be declared in "
           "sharding/rules.py specs")

_COLLECTIVES = ("psum", "pmean", "pmax", "pmin", "all_gather",
                "psum_scatter", "ppermute", "all_to_all", "axis_index",
                "pbroadcast")


def _axis_arg(call: ast.Call) -> ast.AST | None:
    for kw in call.keywords:
        if kw.arg == "axis_name":
            return kw.value
    if len(call.args) >= 2:
        return call.args[1]
    if call.args and call.func and isinstance(call.func, ast.Attribute) \
            and call.func.attr == "axis_index":
        return call.args[0]
    return None


def _resolve_axes(node: ast.AST, fn: ast.FunctionDef | None):
    """Literal axis names of the argument, chasing one level of local
    assignment; None = not statically resolvable (accepted)."""
    items = astutil.string_items(node)
    if items is not None:
        return items
    if isinstance(node, ast.Name) and fn is not None:
        resolved, count = None, 0
        for n in ast.walk(fn):
            if isinstance(n, ast.Assign) and len(n.targets) == 1 \
                    and isinstance(n.targets[0], ast.Name) \
                    and n.targets[0].id == node.id:
                count += 1
                resolved = astutil.string_items(n.value)
        if count == 1:
            return resolved
    return None


def check(mod: astutil.ModuleInfo) -> list[Finding]:
    ctx = mod.ctx
    declared = ctx.declared_axes() if ctx is not None else None
    if not declared:
        return []           # no spec layer to check against
    findings = []
    fns = astutil.functions(mod.tree)

    def enclosing_fn(call):
        best = None
        for fn, _ in fns:
            if fn.lineno <= call.lineno <= max(
                    getattr(fn, "end_lineno", fn.lineno), fn.lineno):
                best = fn
        return best

    for call in [n for n in ast.walk(mod.tree) if isinstance(n, ast.Call)]:
        name = mod.canonical(call.func) or ""
        short = name.split(".")[-1]
        if short not in _COLLECTIVES or "lax" not in name:
            continue
        axis_node = _axis_arg(call)
        if axis_node is None:
            continue
        axes = _resolve_axes(axis_node, enclosing_fn(call))
        if axes is None:
            continue        # parameter-plumbed axes: mesh-agnostic by design
        for ax in axes:
            if ax not in declared:
                fn = enclosing_fn(call)
                findings.append(Finding(
                    rule=RULE_ID, path=mod.path, line=call.lineno,
                    scope=fn.name if fn else "", detail=f"axis:{ax}",
                    message=(f"{short}() over axis {ax!r} which no "
                             "sharding/rules.py spec declares (known: "
                             f"{sorted(declared)}) — this unbinds at run "
                             "time inside shard_map, or silently skips "
                             "the stats reduction on a typo")))
    return findings
