"""RL001 — jit signature/retrace hazards.

Two hazards the zero-retrace contract cannot survive:

  * ``static_argnames`` naming a parameter the decorated function does
    not have: jax silently ignores the unknown name, so the argument the
    author believed was static is traced (or the intended static arg
    starts retracing under a rename).  The in-repo contract:
    ``kernels/ops.switched_apply`` declares ``("block_t", "interpret",
    "prepadded", "d_out")`` and every one is a real keyword parameter.
  * a jit-decorated closure whose body BRANCHES on a value captured from
    an enclosing function scope: the branch is resolved at trace time,
    so flipping the captured Python value between calls either silently
    serves the stale branch or — when the caller re-jits per value —
    recompiles on every flip.  State that crosses calls must enter as a
    traced argument (margins/residency style) or a declared static arg.

Module-level constants (LANE, imports) are exempt: they cannot change
between calls without re-importing the module.
"""
from __future__ import annotations

import ast

from repro.analysis import astutil
from repro.analysis.findings import Finding

RULE_ID = "RL001"
SUMMARY = ("jit static_argnames must name real parameters; jit bodies must "
           "not branch on values closed over from enclosing functions")


def _static_argnames(dec: ast.AST) -> list[str]:
    if not isinstance(dec, ast.Call):
        return []
    for kw in dec.keywords:
        if kw.arg == "static_argnames":
            return astutil.string_items(kw.value) or []
    return []


def check(mod: astutil.ModuleInfo) -> list[Finding]:
    findings = []
    for fn, stack in astutil.functions(mod.tree):
        dec = astutil.jit_decorator(mod, fn)
        if dec is None:
            continue
        params = astutil.param_names(fn)
        for name in _static_argnames(dec):
            if name not in params:
                findings.append(Finding(
                    rule=RULE_ID, path=mod.path, line=fn.lineno,
                    scope=fn.name, detail=f"static_argnames:{name}",
                    message=(f"static_argnames names {name!r} but "
                             f"{fn.name}() has no such parameter — jax "
                             "ignores unknown names, so the argument is "
                             "traced, not static")))

        # names bound in ENCLOSING function scopes (params + assignments);
        # module globals are exempt (constant per process)
        enclosing: set[str] = set()
        for s in stack:
            if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
                enclosing |= set(astutil.param_names(s))
                enclosing |= astutil.assigned_names(s)
        if not enclosing:
            continue
        local = set(params) | astutil.assigned_names(fn)
        hazards = enclosing - local
        seen = set()
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While, ast.IfExp)):
                for n in ast.walk(node.test):
                    if isinstance(n, ast.Name) and n.id in hazards \
                            and (fn.name, n.id) not in seen:
                        seen.add((fn.name, n.id))
                        findings.append(Finding(
                            rule=RULE_ID, path=mod.path, line=node.lineno,
                            scope=fn.name, detail=f"closure-branch:{n.id}",
                            message=(f"jit-decorated {fn.name}() branches "
                                     f"on {n.id!r} closed over from an "
                                     "enclosing function — the branch "
                                     "freezes at trace time (stale result "
                                     "or a retrace per flip); pass it as "
                                     "a traced arg or declare it static")))
    return findings
