"""End-to-end LM training with the MCMA technique as a first-class layer.

Trains a small LM (olmo-family wiring) with ApproxFFN enabled: every FFN
carries n approximators + an (n+1)-way router co-trained against the
exact FFN under an error bound (DESIGN.md §4).  Reports LM loss AND the
paper's metric — invocation (fraction of tokens routed off the exact
path) — rising over training.

Presets:
    --preset smoke     ~1M params, 30 steps  (CI, <2 min CPU)
    --preset 100m      ~100M params, 300 steps (the deliverable run; use a
                       real accelerator or expect hours on CPU)

    PYTHONPATH=src python examples/train_lm_mcma.py --preset smoke
"""
import argparse
import dataclasses

import jax

from repro.configs.base import ApproxConfig, ModelConfig
from repro.data.pipeline import SyntheticLM
from repro.runtime.trainer import Trainer, TrainerConfig

PRESETS = {
    "smoke": dict(n_layers=2, d_model=64, n_heads=4, d_ff=256, vocab=512,
                  seq=64, batch=8, steps=30, d_hidden=32),
    "20m": dict(n_layers=6, d_model=384, n_heads=6, d_ff=1536, vocab=8192,
                seq=256, batch=8, steps=200, d_hidden=64),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, d_ff=3072,
                 vocab=32768, seq=512, batch=16, steps=300, d_hidden=128),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args(argv)
    p = PRESETS[args.preset]

    cfg = ModelConfig(
        name=f"lm-mcma-{args.preset}", family="dense",
        n_layers=p["n_layers"], d_model=p["d_model"], n_heads=p["n_heads"],
        n_kv_heads=p["n_heads"], d_ff=p["d_ff"], vocab=p["vocab"],
        norm="rmsnorm", act="silu", gated_ffn=True,
        param_dtype="float32", act_dtype="float32", remat=False,
        q_block=64, kv_block=64,
        approx=ApproxConfig(enable=True, n_approx=3, d_hidden=p["d_hidden"],
                            error_bound=0.15, router_weight=0.05,
                            distill_weight=1.0))
    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(lambda: __import__("repro.models.model",
                                          fromlist=["init_model"])
                       .init_model(jax.random.PRNGKey(0), cfg))))
    print(f"preset={args.preset}: {n_params / 1e6:.1f}M params "
          f"(incl. {cfg.approx.n_approx} approximators/layer + router)")

    ds = SyntheticLM(vocab=cfg.vocab, seq_len=p["seq"], global_batch=p["batch"])
    steps = args.steps or p["steps"]
    tc = TrainerConfig(total_steps=steps, ckpt_every=max(steps // 3, 10),
                       ckpt_dir=args.ckpt_dir, base_lr=1e-3,
                       warmup=max(steps // 10, 1), log_every=10)
    trainer = Trainer(cfg, tc, ds)

    # wrap step to surface the MCMA metrics
    inner = trainer.step_fn

    def step_with_metrics(state, batch):
        state, m = inner(state, batch)
        return state, m
    trainer.step_fn = step_with_metrics

    out = trainer.run()
    # final: measure invocation on a fresh batch
    from repro.models import model as M
    _, metrics = M.lm_loss(cfg, trainer.state["params"],
                           ds.batch_at(10_000)["inputs"],
                           ds.batch_at(10_000)["labels"])
    print(f"final: loss={out['final_loss']:.4f} "
          f"invocation={float(metrics.get('invocation', 0.0)):.3f} "
          f"router_acc={float(metrics.get('router_acc', 0.0)):.3f}")
    first = trainer.history[0]["loss"] if trainer.history else float("nan")
    print(f"loss {first:.3f} -> {out['final_loss']:.3f} over {out['steps']} steps")


if __name__ == "__main__":
    main()
