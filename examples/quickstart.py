"""Quickstart: the paper's pipeline end-to-end in ~1 minute on CPU.

Trains one-pass / iterative / MCMA on Black-Scholes (reduced sizes),
prints the invocation + error table (the paper's headline comparison),
and the NPU cost model's speedup estimate.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.apps import APPS, make_dataset
from repro.core import npu_model, train_iterative, train_mcma, train_one_pass


def main():
    app = APPS["blackscholes"]
    key = jax.random.PRNGKey(0)
    xtr, ytr, xte, yte = make_dataset(app, key, 4_000, 2_000)
    ks = jax.random.split(key, 3)

    print(f"app={app.name} error_bound={app.error_bound}")
    models = {
        "one-pass": train_one_pass(app, ks[0], xtr, ytr, epochs=600),
        "iterative": train_iterative(app, ks[1], xtr, ytr, epochs=600),
        "mcma-competitive": train_mcma(app, ks[2], xtr, ytr, n_approx=3,
                                       scheme="competitive", epochs=600),
    }
    base = None
    for name, m in models.items():
        met = m.evaluate(xte, yte)
        cost = npu_model.cost(app, met.invocation,
                              n_approx=3 if "mcma" in name else 1,
                              multiclass="mcma" in name)
        if base is None:
            base = cost
        print(f"{name:18s} invocation={met.invocation:.3f} "
              f"err/bound={met.err_norm:.3f} "
              f"speedup-vs-onepass={cost.speedup_vs(base):.2f}x "
              f"energy-red={cost.energy_reduction_vs(base):.2f}x")


if __name__ == "__main__":
    main()
