"""Bessel deep-dive (the paper's 2D showcase, Figs. 9-11).

Runs both MCMA allocation schemes, prints the per-iteration invocation
history (Fig. 9), each approximator's territory share (Fig. 10), and the
confusion quadrants (Fig. 11) — then pushes the dispatched test batch
through the Pallas switched-MLP kernel (interpret mode) to demonstrate
the NPU weight-switch path end to end.

    PYTHONPATH=src python examples/approx_bessel.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import APPS, make_dataset
from repro.core import train_mcma
from repro.kernels import ops, ref


def main():
    app = APPS["bessel"]
    key = jax.random.PRNGKey(1)
    xtr, ytr, xte, yte = make_dataset(app, key, 6_000, 2_000)

    for si, scheme in enumerate(("complementary", "competitive")):
        m = train_mcma(app, jax.random.fold_in(key, 10 + si),
                       xtr, ytr, n_approx=3, scheme=scheme, iters=5,
                       epochs=800)
        met = m.evaluate(xte, yte)
        print(f"\n== {scheme} ==")
        print("  invocation/iter:", " ".join(f"{v:.3f}" for v in m.history))
        print(f"  test: {met.row()}")
        print("  territory shares:", [f"{f:.3f}" for f in met.dispatch_frac])

    # ---- NPU weight-switch path via the Pallas kernel ----------------------
    cls = np.asarray(m.classify(xte))
    dispatched = cls < m.n_approx
    xd = xte[dispatched]
    cd = jnp.asarray(cls[dispatched], jnp.int32)
    w1 = jnp.stack([a[0]["w"] for a in m.a_params])
    b1 = jnp.stack([a[0]["b"] for a in m.a_params])
    w2 = jnp.stack([a[1]["w"] for a in m.a_params])
    b2 = jnp.stack([a[1]["b"] for a in m.a_params])
    got = ops.switched_apply(xd, cd, w1, b1, w2, b2, block_t=128,
                             interpret=True)
    want = ref.switched_mlp_ref(xd, cd, w1, b1, w2, b2)
    err = float(jnp.max(jnp.abs(got - want)))
    print(f"\nPallas switched-MLP on {xd.shape[0]} dispatched inputs: "
          f"max |kernel - ref| = {err:.2e}")
    assert err < 1e-4


if __name__ == "__main__":
    main()
