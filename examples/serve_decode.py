"""Batched decoding with continuous batching (the serving deliverable).

Loads a reduced config of an assigned architecture, submits a wave of
requests with staggered lengths, and drains them through the slot-table
decode server — demonstrating per-slot cache positions, slot recycling,
and (optionally) the MCMA ApproxFFN serve path with capacity dispatch.

    PYTHONPATH=src python examples/serve_decode.py --arch mixtral-8x7b
    PYTHONPATH=src python examples/serve_decode.py --approx
    PYTHONPATH=src python examples/serve_decode.py --approx --mcma-dispatch
    PYTHONPATH=src python examples/serve_decode.py --library-size 8 \\
        --n-resident 2

Serving flags are the shared ``runtime/cli.add_serve_options`` inventory
folded into a ``ServeOptions`` — the same surface as launch/serve.py and
benchmarks/bench_serve.py.
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs.registry import get_config, smoke_config
from repro.models import model as M
from repro.runtime.cli import add_serve_options
from repro.runtime.options import ServeOptions
from repro.runtime.server import DecodeServer, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--approx", action="store_true",
                    help="serve through the MCMA ApproxFFN capacity path")
    ap.add_argument("--requests", type=int, default=10)
    add_serve_options(ap, batch=4, max_len=96)
    args = ap.parse_args(argv)

    cfg = smoke_config(get_config(args.arch))
    options = ServeOptions.from_args(args)
    if args.approx or options.use_mcma_dispatch:
        cfg = dataclasses.replace(cfg, approx=dataclasses.replace(
            cfg.approx, enable=True,
            library_size=options.library.library_size
            if options.library else cfg.approx.library_size))
    assert cfg.input_mode == "tokens", "serve demo expects token models"
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    server = DecodeServer(cfg, params, options=options)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 20))
        eb = None
        if options.qos_tiers:   # cycle tight / default / loose / unspecified
            eb = (list(server.tier_bounds) + [None])[
                i % (len(server.tier_bounds) + 1)]
        reqs.append(Request(rid=i,
                            prompt=rng.integers(0, cfg.vocab, plen)
                            .astype(np.int32),
                            max_new=int(rng.integers(8, 24)),
                            error_bound=eb))
        server.submit(reqs[-1])
    stats = server.run_until_drained()
    for r in reqs[:4]:
        print(f"req {r.rid}: prompt_len={len(r.prompt)} -> "
              f"{len(r.out)} new tokens: {r.out[:8]}...")
    done = sum(r.done for r in reqs)
    path = ("MCMA-dispatch" if options.use_mcma_dispatch
            else "approx-FFN" if args.approx else "exact-FFN")
    print(f"\n{done}/{len(reqs)} requests served in {stats['ticks']} ticks "
          f"({stats['prefill_ticks']} prefill, chunk={server.prefill_chunk}) "
          f"with a {args.batch}-slot table ({path} path)")
    ttft = [r.first_token_tick - r.arrival_tick for r in reqs
            if r.first_token_tick is not None]
    if ttft:
        print(f"ttft: mean {np.mean(ttft):.1f} ticks, max {max(ttft)}")
    if "invocation_rate" in stats:
        print(f"mean invocation rate (fraction of tokens approximated): "
              f"{stats['invocation_rate']:.3f}")
    if "served_invocation_rate" in stats:
        print(f"served invocation rate (approx rows executed): "
              f"{stats['served_invocation_rate']:.3f}; dropped "
              f"{stats['dropped_rows']:.1f} rows")
    if "per_tier" in stats:
        for p in stats["per_tier"]:
            print(f"tier {p['tier']} (bound {p['error_bound']:.3f}): "
                  f"served invocation {p['served_invocation_rate']:.3f} "
                  f"over {p['rows']:.0f} rows")
    if "residency" in stats:
        r = stats["residency"]
        print(f"residency: final hot set {r['final_residency']} after "
              f"{r['swap_count']} swaps "
              f"(off-set exact rows {stats['off_set_exact_rows']:.1f})")
    if "autotune" in stats:
        a = stats["autotune"]
        print(f"autotune: {len(a['switches'])} switches, final point "
              f"{a['final_point']}")
    assert done == len(reqs)


if __name__ == "__main__":
    main()
