# Convenience targets; CI runs the same commands (.github/workflows/ci.yml).
# `test` uses whatever python is active — tests degrade gracefully when
# `hypothesis` is absent (tests/_hypothesis_compat.py).

PY ?= python
MDFLAGS = XLA_FLAGS=--xla_force_host_platform_device_count=8 JAX_PLATFORMS=cpu

.PHONY: test test-tier1 test-multidevice analyze analyze-lint bench-quick \
	bench-dispatch bench-dispatch-sharded bench-autotune bench-decode-tick \
	bench-qos bench-library bench-fused bench-ci-dispatch bench-serve \
	bench-serve-sharded deps

deps:
	$(PY) -m pip install "jax[cpu]" pytest hypothesis

test-tier1:
	$(PY) -m pytest -x -q

test:
	$(PY) -m pytest -q

# the engine contract gate (docs/analysis.md): stage 1 AST-lints the
# sources (RL001-RL005), stage 2 trace-audits the real entrypoints
# across capacities x QoS margins x residency sets (TA001-TA003); any
# finding not grandfathered in analysis_baseline.txt exits nonzero
analyze:
	PYTHONPATH=src JAX_PLATFORMS=cpu $(PY) -m repro.analysis

# stage 1 only — pure stdlib, runs in ~2s without jax installed
analyze-lint:
	PYTHONPATH=src $(PY) -m repro.analysis --stage lint

# mirrors the CI "multidevice" leg: shard_map tests (incl. the tick-scope
# mesh decode + the QoS tier-mix module) + the sharded dispatch microbench
# on 8 virtual CPU devices
test-multidevice:
	$(MDFLAGS) $(PY) -m pytest -x -q tests/test_sharding.py tests/test_sharded_dispatch.py tests/test_dispatch_plan.py tests/test_qos_tiers.py tests/test_serving.py tests/test_library.py tests/test_paged_cache.py
	PYTHONPATH=src $(MDFLAGS) $(PY) -m benchmarks.bench_dispatch --quick --devices 8 --autotune --decode-tick --qos --library --backend-sweep
	PYTHONPATH=src $(MDFLAGS) $(PY) -m benchmarks.bench_serve --quick --devices 8 --n-reqs 6

bench-quick:
	PYTHONPATH=src $(PY) -m benchmarks.run --quick --only kernels,dispatch

# mirrors the CI dispatch.csv artifact leg (pallas-vs-xla oracle gate)
bench-dispatch:
	PYTHONPATH=src $(PY) -m benchmarks.bench_dispatch --quick

bench-dispatch-sharded:
	PYTHONPATH=src $(PY) -m benchmarks.bench_dispatch --quick --devices 8

# capacity-autotuning trajectory leg: pallas-vs-xla divergence gated at
# EVERY visited operating point
bench-autotune:
	PYTHONPATH=src $(PY) -m benchmarks.bench_dispatch --quick --autotune

# tick-level dispatch planning: a full L-layer decode tick at
# route_scope=layer vs tick (asserts 1 class-sort per tick under tick
# scope; oracle-gated at both scopes)
bench-decode-tick:
	PYTHONPATH=src $(PY) -m benchmarks.bench_dispatch --quick --decode-tick

# per-request QoS tier-mix sweep: mixed error-bound batches at several
# operating points, oracle-gated per mix; asserts loose-bound rows serve
# strictly more invocation than tight-bound rows at every visited point
bench-qos:
	PYTHONPATH=src $(PY) -m benchmarks.bench_dispatch --quick --qos

# approximator-library residency: 16-member library, 4 resident slots,
# phase-shifting demand; the ResidencyController-tuned hot set must serve
# strictly more approximator rows than the static first-4 baseline at the
# same capacities, pallas==xla at every visited residency set, zero
# retraces across swaps
bench-library:
	PYTHONPATH=src $(PY) -m benchmarks.bench_dispatch --quick --library

# the CI dispatch.csv artifact leg: base shapes + autotune trajectory +
# decode-tick + QoS tier-mix + library-residency rows in ONE csv
# (separate invocations would overwrite it)
# fused-kernel sweep: fused vs unfused pallas vs xla over an L-layer
# tick; asserts <=1 standalone activation gather/scatter per layer under
# fused, bitwise fused==pallas + <1e-4 vs xla at every visited operating
# point, zero retraces, and fused no slower than unfused in interpret
bench-fused:
	PYTHONPATH=src $(PY) -m benchmarks.bench_dispatch --quick --backend-sweep

bench-ci-dispatch:
	PYTHONPATH=src $(PY) -m benchmarks.bench_dispatch --quick --autotune --decode-tick --qos --library --backend-sweep

# serving-scheduler arrival replay: Poisson/bursty streams, chunked
# prefill vs token-by-token vs the paged KV cache, p50/p99 TTFT +
# tokens/sec + resident-KV-bytes per offered load; gates chunked==token
# greedy tokens, chunked TTFT wins on long prompts, paged==dense tokens
# at strictly lower kv_bytes_resident, and pallas==xla at the server
# level.  Writes benchmarks/out/serve.csv.
bench-serve:
	PYTHONPATH=src $(PY) -m benchmarks.bench_serve --quick

bench-serve-sharded:
	PYTHONPATH=src $(MDFLAGS) $(PY) -m benchmarks.bench_serve --quick --devices 8 --n-reqs 6
