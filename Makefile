# Convenience targets; CI runs the same commands (.github/workflows/ci.yml).
# `test` uses whatever python is active — tests degrade gracefully when
# `hypothesis` is absent (tests/_hypothesis_compat.py).

PY ?= python

.PHONY: test test-tier1 bench-quick bench-dispatch deps

deps:
	$(PY) -m pip install "jax[cpu]" pytest hypothesis

test-tier1:
	$(PY) -m pytest -x -q

test:
	$(PY) -m pytest -q

bench-quick:
	PYTHONPATH=src $(PY) -m benchmarks.run --quick --only kernels,dispatch

bench-dispatch:
	PYTHONPATH=src $(PY) -m benchmarks.bench_dispatch --quick
