"""Fig. 9: invocation per co-training iteration, complementary vs
competitive allocation, on Bessel.

Expected (paper): competitive starts lower but overtakes complementary in
later iterations; complementary dips around iteration 2 when the
multiclass classifier first reshuffles the partition.
Writes benchmarks/out/alloc_iters.csv.
"""
from __future__ import annotations

import csv
import os

import jax

from repro.apps import APPS, make_dataset
from repro.core import train_mcma

OUT = os.path.join(os.path.dirname(__file__), "out")


def main(n_train=8_000, n_test=3_000, epochs=1500, iters=8, seed=0):
    os.makedirs(OUT, exist_ok=True)
    app = APPS["bessel"]
    key = jax.random.PRNGKey(seed)
    xtr, ytr, xte, yte = make_dataset(app, key, n_train, n_test)
    rows = []
    for si, scheme in enumerate(("complementary", "competitive")):
        m = train_mcma(app, jax.random.fold_in(key, 100 + si),
                       xtr, ytr, scheme=scheme, iters=iters, epochs=epochs)
        for it, inv in enumerate(m.history):
            rows.append({"scheme": scheme, "iteration": it + 1,
                         "invocation_train": round(inv, 4)})
        met = m.evaluate(xte, yte)
        rows.append({"scheme": scheme, "iteration": "final-test",
                     "invocation_train": round(met.invocation, 4)})
        print(f"{scheme}: " + " ".join(f"{v:.3f}" for v in m.history), flush=True)
    with open(os.path.join(OUT, "alloc_iters.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    main()
