"""Pallas kernel micro-bench (interpret mode on CPU: correctness + VMEM
working-set accounting, NOT wall-time — the target is TPU v5e).

For each kernel configuration we report the analytic per-tile VMEM bytes
(must be << 16 MiB more) and the HBM-traffic saving vs the unfused XLA
path that materializes the hidden activations.

The fused-dispatch leg sweeps block_t over the gather/scatter-fused
weight-switch kernel (kernels/fused_dispatch.py) vs the unfused
class-sort path: interpret-mode wall time (XLA-level op mix, not TPU
kernel speed), the fused kernel's VMEM residency bound (x and the
(T+1)-row output stay resident across the whole grid — the bound that
decides when fusion is sound), and a BITWISE equality gate per block
size.  Writes benchmarks/out/kernels.csv.
"""
from __future__ import annotations

import csv
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

OUT = os.path.join(os.path.dirname(__file__), "out")
VMEM = 16 * 2 ** 20
LANE = 128


def mlp_vmem(block_t, d_in, d_h, d_out, itemsize=2):
    tile = (block_t * d_in + d_in * d_h + block_t * d_h
            + d_h * d_out + block_t * d_out)
    return tile * itemsize


def fused_vmem(t, d_in, d_h, d_out, block_t, itemsize=2):
    """Fused dispatch kernel residency: the whole (T, d_in) activation
    block and the (T+1, d_out_p) output block live in VMEM for the full
    grid (that's what makes the in-kernel gather/scatter free of HBM
    traffic), plus one weight tile and the (block_t, d_in_p) gather
    scratch.  This is the bound that decides when fusion is sound —
    past it, fall back to the unfused class-sort path."""
    pad = lambda v: ((v + LANE - 1) // LANE) * LANE
    d_in_p, d_h_p, d_out_p = pad(d_in), pad(d_h), pad(d_out)
    resident = t * d_in + (t + 1) * d_out_p
    tile = d_in_p * d_h_p + d_h_p + d_h_p * d_out_p + d_out_p
    scratch = block_t * d_in_p
    return (resident + tile + scratch) * itemsize


def hbm_saving(t, d_h, itemsize=2):
    """Unfused XLA writes+reads the (T, d_h) hidden activations."""
    return 2 * t * d_h * itemsize


def _fused_leg(rows):
    """block_t sweep: ops.switched_apply (class-sort + standalone
    gather/scatter) vs ops.switched_apply_fused (gather/scatter folded
    into the kernel), bitwise-gated at every block size."""
    t, n, d, d_h = 1024, 4, 256, 64
    key = jax.random.PRNGKey(7)
    x = (jax.random.normal(key, (t, d)) * 0.3).astype(jnp.bfloat16)
    ks = jax.random.split(key, 3)
    w1 = (jax.random.normal(ks[0], (n, d, d_h)) * 0.1).astype(jnp.bfloat16)
    b1 = jnp.zeros((n, d_h), jnp.bfloat16)
    w2 = (jax.random.normal(ks[1], (n, d_h, d)) * 0.1).astype(jnp.bfloat16)
    b2 = jnp.zeros((n, d), jnp.bfloat16)
    cls = jax.random.randint(ks[2], (t,), 0, n)
    want = ref.switched_mlp_ref(x, cls, w1, b1, w2, b2)
    for bt in (64, 128, 256):
        times = {}
        outs = {}
        for label, fn in (("unfused", ops.switched_apply),
                          ("fused", ops.switched_apply_fused)):
            y = fn(x, cls, w1, b1, w2, b2, block_t=bt, interpret=True)
            jax.block_until_ready(y)             # compile off the clock
            t0 = time.perf_counter()
            for _ in range(3):
                y = fn(x, cls, w1, b1, w2, b2, block_t=bt, interpret=True)
            jax.block_until_ready(y)
            times[label] = (time.perf_counter() - t0) / 3 * 1e3
            outs[label] = np.asarray(y)
        assert np.array_equal(outs["fused"], outs["unfused"]), \
            f"fused != unfused bitwise at block_t={bt}"
        err = float(jnp.max(jnp.abs(outs["fused"].astype(np.float32)
                                    - np.asarray(want, np.float32))))
        vm = fused_vmem(t, d, d_h, d, bt)
        rows.append({"kernel": f"fused_dispatch_bt{bt}", "T": t,
                     "n_approx": n, "block_t": bt,
                     "vmem_tile_bytes": vm, "vmem_ok": vm < VMEM,
                     "hbm_saving_bytes": hbm_saving(t, d_h),
                     "max_abs_err_vs_ref": round(err, 5),
                     "ms_fused_interp": round(times["fused"], 3),
                     "ms_unfused_interp": round(times["unfused"], 3)})
        print(f"fused_dispatch_bt{bt:<4d} vmem-resident={vm/2**20:.2f}MiB "
              f"interp {times['fused']:.1f} vs {times['unfused']:.1f} ms "
              f"(unfused) err={err:.4f}", flush=True)


def main():
    os.makedirs(OUT, exist_ok=True)
    rows = []
    cases = [
        # (name, T, n, d_in, d_h, d_out, block_t)
        ("approx_paper_bs", 4096, 1, 6, 8, 1, 256),
        ("approx_ffn_1b", 2048, 1, 2048, 256, 2048, 256),
        ("approx_ffn_3b", 2048, 1, 2560, 256, 2560, 512),
        ("switched_n3_1b", 2048, 3, 2048, 256, 2048, 256),
        ("switched_n8_1b", 2048, 8, 2048, 256, 2048, 256),
    ]
    for name, t, n, d_in, d_h, d_out, bt in cases:
        key = jax.random.PRNGKey(0)
        x = (jax.random.normal(key, (t, d_in)) * 0.3).astype(jnp.bfloat16)
        ks = jax.random.split(key, 5)
        if n == 1:
            w1 = (jax.random.normal(ks[0], (d_in, d_h)) * 0.1).astype(jnp.bfloat16)
            b1 = jnp.zeros((d_h,), jnp.bfloat16)
            w2 = (jax.random.normal(ks[1], (d_h, d_out)) * 0.1).astype(jnp.bfloat16)
            b2 = jnp.zeros((d_out,), jnp.bfloat16)
            got = ops.mlp_apply(x, w1, b1, w2, b2, block_t=bt, interpret=True)
            want = ref.mlp_forward_ref(x, w1, b1, w2, b2)
        else:
            w1 = (jax.random.normal(ks[0], (n, d_in, d_h)) * 0.1).astype(jnp.bfloat16)
            b1 = jnp.zeros((n, d_h), jnp.bfloat16)
            w2 = (jax.random.normal(ks[1], (n, d_h, d_out)) * 0.1).astype(jnp.bfloat16)
            b2 = jnp.zeros((n, d_out), jnp.bfloat16)
            cls = jax.random.randint(ks[2], (t,), 0, n)
            got = ops.switched_apply(x, cls, w1, b1, w2, b2, block_t=bt,
                                     interpret=True)
            want = ref.switched_mlp_ref(x, cls, w1, b1, w2, b2)
        err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                    - want.astype(jnp.float32))))
        vm = mlp_vmem(bt, d_in, d_h, d_out) * (1 if n == 1 else 1)  # per tile
        rows.append({"kernel": name, "T": t, "n_approx": n,
                     "block_t": bt, "vmem_tile_bytes": vm,
                     "vmem_ok": vm < VMEM,
                     "hbm_saving_bytes": hbm_saving(t, d_h),
                     "max_abs_err_vs_ref": round(err, 5)})
        print(f"{name:18s} vmem/tile={vm/2**20:.2f}MiB "
              f"hbm_saved={hbm_saving(t, d_h)/2**20:.1f}MiB err={err:.4f}",
              flush=True)
    _fused_leg(rows)
    fields = list(rows[0].keys())
    for r in rows:
        fields += [k for k in r if k not in fields]
    with open(os.path.join(OUT, "kernels.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=fields, restval="")
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    main()
