"""Pallas kernel micro-bench (interpret mode on CPU: correctness + VMEM
working-set accounting, NOT wall-time — the target is TPU v5e).

For each kernel configuration we report the analytic per-tile VMEM bytes
(must be << 16 MiB more) and the HBM-traffic saving vs the unfused XLA
path that materializes the hidden activations.
Writes benchmarks/out/kernels.csv.
"""
from __future__ import annotations

import csv
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

OUT = os.path.join(os.path.dirname(__file__), "out")
VMEM = 16 * 2 ** 20


def mlp_vmem(block_t, d_in, d_h, d_out, itemsize=2):
    tile = (block_t * d_in + d_in * d_h + block_t * d_h
            + d_h * d_out + block_t * d_out)
    return tile * itemsize


def hbm_saving(t, d_h, itemsize=2):
    """Unfused XLA writes+reads the (T, d_h) hidden activations."""
    return 2 * t * d_h * itemsize


def main():
    os.makedirs(OUT, exist_ok=True)
    rows = []
    cases = [
        # (name, T, n, d_in, d_h, d_out, block_t)
        ("approx_paper_bs", 4096, 1, 6, 8, 1, 256),
        ("approx_ffn_1b", 2048, 1, 2048, 256, 2048, 256),
        ("approx_ffn_3b", 2048, 1, 2560, 256, 2560, 512),
        ("switched_n3_1b", 2048, 3, 2048, 256, 2048, 256),
        ("switched_n8_1b", 2048, 8, 2048, 256, 2048, 256),
    ]
    for name, t, n, d_in, d_h, d_out, bt in cases:
        key = jax.random.PRNGKey(0)
        x = (jax.random.normal(key, (t, d_in)) * 0.3).astype(jnp.bfloat16)
        ks = jax.random.split(key, 5)
        if n == 1:
            w1 = (jax.random.normal(ks[0], (d_in, d_h)) * 0.1).astype(jnp.bfloat16)
            b1 = jnp.zeros((d_h,), jnp.bfloat16)
            w2 = (jax.random.normal(ks[1], (d_h, d_out)) * 0.1).astype(jnp.bfloat16)
            b2 = jnp.zeros((d_out,), jnp.bfloat16)
            got = ops.mlp_apply(x, w1, b1, w2, b2, block_t=bt, interpret=True)
            want = ref.mlp_forward_ref(x, w1, b1, w2, b2)
        else:
            w1 = (jax.random.normal(ks[0], (n, d_in, d_h)) * 0.1).astype(jnp.bfloat16)
            b1 = jnp.zeros((n, d_h), jnp.bfloat16)
            w2 = (jax.random.normal(ks[1], (n, d_h, d_out)) * 0.1).astype(jnp.bfloat16)
            b2 = jnp.zeros((n, d_out), jnp.bfloat16)
            cls = jax.random.randint(ks[2], (t,), 0, n)
            got = ops.switched_apply(x, cls, w1, b1, w2, b2, block_t=bt,
                                     interpret=True)
            want = ref.switched_mlp_ref(x, cls, w1, b1, w2, b2)
        err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                    - want.astype(jnp.float32))))
        vm = mlp_vmem(bt, d_in, d_h, d_out) * (1 if n == 1 else 1)  # per tile
        rows.append({"kernel": name, "T": t, "n_approx": n,
                     "block_t": bt, "vmem_tile_bytes": vm,
                     "vmem_ok": vm < VMEM,
                     "hbm_saving_bytes": hbm_saving(t, d_h),
                     "max_abs_err_vs_ref": round(err, 5)})
        print(f"{name:18s} vmem/tile={vm/2**20:.2f}MiB "
              f"hbm_saved={hbm_saving(t, d_h)/2**20:.1f}MiB err={err:.4f}",
              flush=True)
    with open(os.path.join(OUT, "kernels.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    main()
