"""Benchmark driver: one sub-benchmark per paper table/figure plus the
kernel microbench and the dry-run/roofline summary.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Paper-scale knobs (Fig. 6 dataset sizes, 1500 epochs) are reduced to
CI-scale by default (8k/3k samples) — pass --full for paper sizes.
Everything writes CSVs under benchmarks/out/.
"""
from __future__ import annotations

import argparse
import os
import time
import traceback


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny sizes for smoke (epochs=200, 2k samples)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale dataset sizes (slow)")
    ap.add_argument("--only", default="",
                    help="comma list: paper,errorbound,alloc,distribution,"
                         "kernels,dispatch,serve,roofline")
    args = ap.parse_args(argv)

    from benchmarks import (bench_alloc, bench_dispatch, bench_distribution,
                            bench_errorbound, bench_kernels, bench_nablation,
                            bench_paper, bench_serve)

    if args.quick:
        kw = dict(n_train=2_000, n_test=1_000, epochs=200)
    elif args.full:
        kw = dict(n_train=None, n_test=None, epochs=1500)  # per-app Fig. 6
    else:
        kw = dict(n_train=8_000, n_test=3_000, epochs=1500)

    jobs = {
        "paper": lambda: bench_paper.main(),
        "errorbound": lambda: bench_errorbound.main(
            n_train=kw["n_train"] or 8000, n_test=kw["n_test"] or 3000,
            epochs=kw["epochs"]),
        "alloc": lambda: bench_alloc.main(
            n_train=kw["n_train"] or 8000, n_test=kw["n_test"] or 3000,
            epochs=kw["epochs"]),
        "distribution": lambda: bench_distribution.main(
            n_train=kw["n_train"] or 8000, n_test=kw["n_test"] or 3000,
            epochs=kw["epochs"]),
        "kernels": lambda: bench_kernels.main(),
        "dispatch": lambda: bench_dispatch.main(quick=args.quick),
        "serve": lambda: bench_serve.main(quick=args.quick),
        "nablation": lambda: bench_nablation.main(
            epochs=min(kw["epochs"], 800)),
        "roofline": _roofline,
    }
    only = [s for s in args.only.split(",") if s] or list(jobs)
    failures = []
    for name in only:
        print(f"\n===== bench: {name} =====", flush=True)
        t0 = time.time()
        try:
            jobs[name]()
            print(f"===== {name} done in {time.time() - t0:.0f}s =====",
                  flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")
    print("\nall benchmarks complete; outputs in benchmarks/out/ "
          "and runs/roofline.md")


def _roofline():
    if not os.path.isdir("runs/dryrun") or not os.listdir("runs/dryrun"):
        print("no dry-run cells found (run `python -m repro.launch.dryrun "
              "--all --mesh-all` first); skipping")
        return
    from repro.launch import roofline
    roofline.main([])


if __name__ == "__main__":
    main()
