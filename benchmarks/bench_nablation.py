"""Ablation: invocation vs number of approximators (the question MCCA was
built to answer — paper §III-B "how many approximators are enough to cover
the majority of the input space?").

Expected: invocation rises steeply from n=1 (== iterative) to n=2..3, then
saturates — the clusters of safe-to-approximate data are few.
Writes benchmarks/out/nablation.csv.
"""
from __future__ import annotations

import csv
import os

import jax

from repro.apps import APPS, make_dataset
from repro.core import train_mcma

OUT = os.path.join(os.path.dirname(__file__), "out")


def main(apps=("blackscholes", "bessel", "kmeans"), ns=(1, 2, 3, 4, 6),
         n_train=6_000, n_test=2_000, epochs=800, seed=0):
    os.makedirs(OUT, exist_ok=True)
    rows = []
    for app_name in apps:
        app = APPS[app_name]
        key = jax.random.PRNGKey(seed)
        xtr, ytr, xte, yte = make_dataset(app, key, n_train, n_test)
        for n in ns:
            m = train_mcma(app, jax.random.fold_in(key, n), xtr, ytr,
                           n_approx=n, scheme="competitive", iters=4,
                           epochs=epochs)
            met = m.evaluate(xte, yte)
            rows.append({"app": app_name, "n_approx": n,
                         "invocation": round(met.invocation, 4),
                         "err_over_bound": round(met.err_norm, 4),
                         "recall": round(met.recall, 4)})
            print(f"{app_name:14s} n={n} inv={met.invocation:.3f} "
                  f"err/b={met.err_norm:.3f}", flush=True)
    with open(os.path.join(OUT, "nablation.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    main()
