"""Reproduces Fig. 7(a)/(b): invocation + normalized error per benchmark for
one-pass / iterative / MCCA / MCMA-complementary / MCMA-competitive, and the
derived Fig. 8 speedup/energy via the NPU cost model.

Writes a CSV to benchmarks/out/paper_table.csv (one row per app x method).
"""
from __future__ import annotations

import csv
import os
import time

import jax

from repro.apps import APPS, make_dataset
from repro.core import (npu_model, train_iterative, train_mcca, train_mcma,
                        train_one_pass)

OUT = os.path.join(os.path.dirname(__file__), "out")

# CI-scale sizes: the paper's 70K/30K splits shrink to keep a full 8x5 sweep
# in CPU minutes; pass full=True for paper-scale sizes.
N_TRAIN, N_TEST = 8_000, 3_000
EPOCHS, ITERS, LR = 1500, 5, 3e-3


def run_app(app, key, *, n_train=N_TRAIN, n_test=N_TEST, epochs=EPOCHS,
            iters=ITERS, n_approx=3):
    xtr, ytr, xte, yte = make_dataset(app, key, n_train, n_test)
    ks = jax.random.split(key, 5)
    rows = {}
    t0 = time.time()
    rows["one-pass"] = train_one_pass(app, ks[0], xtr, ytr, epochs=epochs,
                                      lr=LR).evaluate(xte, yte)
    rows["iterative"] = train_iterative(app, ks[1], xtr, ytr, iters=iters,
                                        epochs=epochs, lr=LR).evaluate(xte, yte)
    mcca = train_mcca(app, ks[2], xtr, ytr, max_pairs=n_approx, epochs=epochs, lr=LR)
    rows["mcca"] = mcca.evaluate(xte, yte)
    for scheme in ("complementary", "competitive"):
        m = train_mcma(app, ks[3], xtr, ytr, n_approx=n_approx, scheme=scheme,
                       iters=iters, epochs=epochs, lr=LR)
        rows[f"mcma-{scheme}"] = m.evaluate(xte, yte)
    elapsed = time.time() - t0

    # NPU cost model -> speedup / energy vs one-pass (Fig. 8 normalization)
    costs = {}
    for name, met in rows.items():
        multi = name.startswith("mcma")
        n_cls = (mcca.classifiers_consulted(xte) if name == "mcca" else 1.0)
        costs[name] = npu_model.cost(
            app, met.invocation, n_approx=n_approx if multi or name == "mcca" else 1,
            n_classifier_calls=float(n_cls), multiclass=multi,
            switch_rate=0.5 if multi else 0.0)
    base = costs["one-pass"]
    return rows, costs, base, elapsed


def main(apps=None, seed=0):
    os.makedirs(OUT, exist_ok=True)
    apps = apps or list(APPS)
    results = []
    for i, name in enumerate(apps):
        app = APPS[name]
        rows, costs, base, elapsed = run_app(app, jax.random.PRNGKey(seed + i))
        for method, met in rows.items():
            c = costs[method]
            results.append(dict(
                app=name, method=method, invocation=round(met.invocation, 4),
                err_over_bound=round(met.err_norm, 4),
                recall=round(met.recall, 4), false_pos=round(met.false_pos, 4),
                speedup_vs_onepass=round(c.speedup_vs(base), 4),
                energy_red_vs_onepass=round(c.energy_reduction_vs(base), 4),
            ))
            print(f"{name:14s} {method:18s} inv={met.invocation:.3f} "
                  f"err/b={met.err_norm:.3f} spd={c.speedup_vs(base):.3f} "
                  f"en={c.energy_reduction_vs(base):.3f}")
        print(f"  [{name}: {elapsed:.0f}s]", flush=True)
    with open(os.path.join(OUT, "paper_table.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(results[0].keys()))
        w.writeheader()
        w.writerows(results)
    return results


if __name__ == "__main__":
    main()
