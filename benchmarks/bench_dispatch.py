"""Dispatch-engine microbench: XLA capacity dispatch vs the Pallas
weight-switch engine (runtime/dispatch.py) across batch sizes and
approximator counts.

On CPU the Pallas backend runs in interpreter mode, so its wall-time
column measures dispatch/plumbing overhead, not kernel speed (the kernel
target is TPU v5e — rerun there with interpret off for real numbers).
The XLA column IS a meaningful portable baseline, and both rows carry the
invoke_stats the engine reports (invocation rate, dropped rows, executed
vs useful rows) so the capacity/padding economics are visible per shape.

    PYTHONPATH=src python -m benchmarks.bench_dispatch [--quick]
    PYTHONPATH=src python -m benchmarks.bench_dispatch --quick --devices 8

``--devices N`` adds a sharded mode: the same shapes run through
``mcma_dispatch_sharded`` over an N-way data mesh (forcing N virtual CPU
devices when needed), recording the global sharded wall time next to a
shard-local single-device baseline (one shard's rows on one device) so
the scaling overhead of the shard_map path is visible per shape.  Every
mode asserts the Pallas backend against the XLA oracle.

``--autotune`` adds the capacity-autotuning microbench: a skewed,
phase-shifting synthetic request mix is served tick by tick while the
``runtime/autotune.CapacityController`` walks its operating-point ladder
(with ``--devices N`` also through the sharded engine on an N-way mesh).
Each tick appends a trajectory row (operating point, dropped rows,
routed vs served invocation); at every VISITED operating point the
Pallas backend is asserted against the XLA oracle — the divergence gate
under switched capacities.  The leg itself asserts the controller ends
under the drop budget with strictly more served invocation than the
static starting point.

``--decode-tick`` adds the tick-level dispatch-planning microbench: a
full L-layer decode tick through the REAL model decode step in
``route_scope="layer"`` (route -> class-sort -> dispatch inside every
layer of the scan) vs ``route_scope="tick"`` (ONE DispatchPlan above the
scan, every layer a weight-switch launch on already-sorted rows).  Each
row records the per-tick wall time plus the DYNAMIC sort/scatter op
counts per tick (jaxpr walk, scan-length aware) — the leg asserts tick
scope runs strictly fewer sorts (1 plan vs L) and gates the Pallas
backend against the XLA oracle at both scopes.

``--backend-sweep`` adds the fused-kernel comparison: every backend
(``xla`` | ``pallas`` | ``pallas_fused``) serves an L-layer tick from
one DispatchPlan (``lax.scan`` of ``execute_dispatch`` over per-layer
weight banks), recording ms_per_tick plus the STANDALONE activation
gather/scatter counts per layer from a jaxpr audit
(``repro.analysis.opcount.activation_moves`` — rank >= 2 operands,
pallas kernel bodies excluded).  Gates: the fused backend runs <= 1
standalone gather and <= 1 scatter per layer (strictly fewer than
unfused pallas, whose class-sort legs it folds into the kernel), is
bitwise equal to unfused pallas and < 1e-4 vs the XLA oracle at every
visited operating point (uniform + asymmetric caps, masked rows, tiered
margins), retraces nothing across plan changes, and in interpret mode
is no slower than unfused.  ``--devices N`` adds a sharded pass.

``--qos`` adds the per-request QoS tier-mix sweep: batches mixing
error-bound tiers (tight/base/loose exact-logit margins, a traced
vector — one compiled program per operating point serves every mix) run
at several capacity rungs including an asymmetric per-class one; each
mix is pallas-vs-xla gated and the leg asserts loose-bound rows serve
strictly more invocation than tight-bound rows in the same batch at
every visited operating point, with per-tier margin/rows/served-
invocation columns in the CSV.

Writes benchmarks/out/dispatch.csv (modes: single | sharded |
shard-local | autotune | decode-tick | qos | backend-sweep |
backend-sweep-sharded).
"""
from __future__ import annotations

import argparse
import csv
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.jit_cache import assert_zero_retrace
from repro.analysis.opcount import activation_moves, count_dynamic_ops
from repro.runtime import dispatch as D

OUT = os.path.join(os.path.dirname(__file__), "out")


def _make_case(key, t, n, d, d_h, d_ff):
    ks = jax.random.split(key, 7)
    x = jax.random.normal(ks[0], (t, d), jnp.float32) * 0.5
    router = jax.random.normal(ks[1], (d, n + 1)) * 0.5
    w1 = jax.random.normal(ks[2], (n, d, d_h)) * 0.2
    b1 = jnp.zeros((n, d_h))
    w2 = jax.random.normal(ks[3], (n, d_h, d)) * 0.2
    b2 = jnp.zeros((n, d))
    wi = jax.random.normal(ks[4], (d, d_ff)) * 0.1
    wo = jax.random.normal(ks[5], (d_ff, d)) * 0.1
    return x, x @ router, (w1, b1, w2, b2), (wi, wo)


def _time(fn, *args, iters):
    y, _ = fn(*args)
    jax.block_until_ready(y)                     # compile outside the clock
    t0 = time.perf_counter()
    for _ in range(iters):
        y, stats = fn(*args)
    jax.block_until_ready(y)
    return (time.perf_counter() - t0) / iters * 1e3, stats


def _record(rows, *, t, n, d, backend, block_t, interpret, ms, stats,
            devices, mode):
    row = {
        "T": t, "n_approx": n, "d_model": d, "backend": backend,
        "block_t": block_t, "interpret": interpret,
        "devices": devices, "mode": mode,
        "ms_per_call": round(ms, 3),
        "invocation": round(float(stats["invocation"]), 4),
        "exact_frac": round(float(stats["exact_frac"]), 4),
        "dropped": int(stats["dropped"]),
        "executed_rows": int(stats["executed_rows"]),
        "padding_rows": int(stats["padding_rows"]),
    }
    rows.append(row)
    print(f"T={t:6d} n={n} {mode:11s} x{devices} {backend:6s} "
          f"{ms:9.2f} ms/call inv={row['invocation']:.3f} "
          f"pad_rows={row['padding_rows']}", flush=True)
    return row


def _check_oracle(rows, outs, t, n):
    """Gate: the Pallas backend must match the XLA oracle on every row."""
    err = float(np.abs(outs["pallas"] - outs["xla"]).max())
    for row in rows[-2:]:
        row["max_abs_err_vs_xla"] = round(err, 7) \
            if row["backend"] == "pallas" else 0.0
    assert err < 1e-4, f"backend divergence at T={t} n={n}: {err}"


def _skewed_logits(key, t, n, hot, hot_frac):
    """Router logits sending ~hot_frac of rows to class ``hot`` and the
    rest roughly uniform over the other classes (incl. exact)."""
    ks = jax.random.split(key, 2)
    cls = jnp.where(jax.random.uniform(ks[0], (t,)) < hot_frac, hot,
                    jax.random.randint(ks[1], (t,), 0, n + 1))
    return jax.nn.one_hot(cls, n + 1) * 10.0


def _autotune_leg(rows, *, quick, devices, drop_budget=0.05):
    """Serve a phase-shifting skewed mix through the controller's ladder;
    gate pallas-vs-xla at every visited operating point."""
    from repro.runtime.autotune import (CapacityController, OperatingPoint,
                                        point_caps)
    from repro.sharding.rules import shard_capacity
    t, n = (256, 3) if quick else (1024, 4)
    d, d_h, d_ff, block_t = (128, 32, 256, 64) if quick \
        else (256, 64, 1024, 128)
    on_cpu = jax.default_backend() != "tpu"
    key = jax.random.PRNGKey(17)
    x, _, (w1, b1, w2, b2), (wi, wo) = _make_case(key, t, n, d, d_h, d_ff)
    exact_fn = lambda xb: jnp.dot(jax.nn.silu(jnp.dot(xb, wi)), wo)
    exact_fn_p = lambda ep, xb: jnp.dot(jax.nn.silu(jnp.dot(xb, ep[0])),
                                        ep[1])

    ladder = (OperatingPoint(0.5, 0.15), OperatingPoint(0.5, 0.3),
              OperatingPoint(0.6, 0.5), OperatingPoint(1.0, 1.0))
    mesh = jax.make_mesh((devices,), ("data",)) if devices > 1 else None
    tl = t // devices
    ctrl = CapacityController(
        ladder, lambda pt: point_caps(pt, tl, n, n_shards=devices),
        drop_budget=drop_budget, cooldown=1, down_patience=4)

    fns = {}                                  # (rung, backend) -> jitted fn

    def run_point(idx, xx, lg, backend):
        pt = ladder[idx]
        ec = shard_capacity(tl, pt.exact_frac, slack=pt.shard_slack)
        ic = shard_capacity(tl, pt.invoke_frac, slack=pt.shard_slack)
        interp = on_cpu and backend == "pallas"
        if (idx, backend) not in fns:
            if mesh is None:
                fns[(idx, backend)] = jax.jit(
                    lambda a, b, be=backend, ip=interp, e=ec, i=ic:
                    D.mcma_dispatch(a, b, exact_fn, w1, b1, w2, b2,
                                    exact_cap=e, invoke_cap=i, backend=be,
                                    block_t=block_t, interpret=ip))
            else:
                fns[(idx, backend)] = jax.jit(
                    lambda a, b, be=backend, ip=interp, e=ec, i=ic:
                    D.mcma_dispatch_sharded(
                        mesh, a, b, exact_fn_p, (wi, wo), w1, b1, w2, b2,
                        exact_cap=e, invoke_cap=i, backend=be,
                        block_t=block_t, interpret=ip))
        return fns[(idx, backend)](xx, lg)

    # two-phase mix: light/balanced, then one class runs hot — the static
    # starting rung drops a large share of approximable rows
    phases = [(0.25, 8), (0.85, 24)] if quick else [(0.25, 10), (0.85, 40)]
    tick = 0
    static_idx = ctrl.index
    static_acc = np.zeros(2)                  # dropped, served approx rows
    tuned_acc = np.zeros(2)
    total_rows = 0
    hot = n                                   # hottest approximator class
    for hot_frac, ticks in phases:
        for _ in range(ticks):
            lg = _skewed_logits(jax.random.fold_in(key, tick), t, n, hot,
                                hot_frac)
            yx, sx = run_point(ctrl.index, x, lg, "xla")
            yp, sp = run_point(ctrl.index, x, lg, "pallas")
            err = float(np.abs(np.asarray(yp) - np.asarray(yx)).max())
            assert err < 1e-4, \
                f"pallas-vs-xla divergence at operating point " \
                f"{ladder[ctrl.index]}: {err}"
            # static baseline: the same mix pinned at the starting rung
            # (free while the controller still sits on it)
            ss = sx if ctrl.index == static_idx \
                else run_point(static_idx, x, lg, "xla")[1]
            static_acc += (float(ss["dropped"]),
                           float(np.asarray(ss["dispatched"])[1:].sum()))
            tuned_acc += (float(sx["dropped"]),
                          float(np.asarray(sx["dispatched"])[1:].sum()))
            total_rows += t
            pt = ladder[ctrl.index]
            rows.append({
                "T": t, "n_approx": n, "d_model": d, "backend": "both",
                "block_t": block_t, "interpret": on_cpu,
                "devices": devices, "mode": "autotune",
                "tick": tick, "op_index": ctrl.index,
                "op_exact_frac": pt.exact_frac,
                "op_invoke_frac": pt.invoke_frac,
                "invocation": round(float(sx["invocation"]), 4),
                "exact_frac": round(float(sx["exact_frac"]), 4),
                "dropped": int(sx["dropped"]),
                "served_invocation": round(
                    float(np.asarray(sx["dispatched"])[1:].sum())
                    / max(float(np.asarray(sx["class_counts"]).sum()), 1),
                    4),
                "executed_rows": int(sx["executed_rows"]),
                "padding_rows": int(sx["padding_rows"]),
                "max_abs_err_vs_xla": round(err, 7),
            })
            ctrl.observe(jax.tree.map(np.asarray, sx))
            tick += 1
    final = ctrl.index
    print(f"autotune x{devices}: {len(ctrl.history)} switches, final point "
          f"{ladder[final]}; dropped {tuned_acc[0]:.0f} vs static "
          f"{static_acc[0]:.0f} rows; served approx rows "
          f"{tuned_acc[1]:.0f} vs static {static_acc[1]:.0f}", flush=True)
    # the leg's own acceptance gate: under budget, strictly above static
    assert static_acc[0] / total_rows > 0.10, \
        "mix not skewed enough to stress the static config"
    last = [r for r in rows if r["mode"] == "autotune"][-max(
        1, phases[-1][1] // 2):]
    tail_drop = sum(r["dropped"] for r in last) / (len(last) * t)
    assert tail_drop <= drop_budget, (tail_drop, drop_budget)
    assert tuned_acc[1] > static_acc[1], \
        "autotune must serve strictly more approximator rows than static"


def _qos_leg(rows, *, quick, devices=1):
    """Per-request QoS tier-mix sweep: one batch mixing error-bound tiers
    through the tiered engine (exact-logit margins, a traced vector — the
    jitted program is shared by every mix) at several operating points,
    including an asymmetric per-class-capacity rung.  Gates pallas vs the
    XLA oracle per mix, and asserts the QoS contract: loose-bound rows
    serve STRICTLY more invocation than tight-bound rows in the same
    batch at every visited operating point."""
    from repro.runtime.autotune import (OperatingPoint, default_tier_bounds,
                                        margins_from_bounds)
    from repro.sharding.rules import shard_capacity
    t, n = (256, 3) if quick else (1024, 4)
    d, d_h, d_ff, block_t = (128, 32, 256, 64) if quick \
        else (256, 64, 1024, 128)
    on_cpu = jax.default_backend() != "tpu"
    key = jax.random.PRNGKey(23)
    x, lg, (w1, b1, w2, b2), (wi, wo) = _make_case(key, t, n, d, d_h, d_ff)
    exact_fn = lambda xb: jnp.dot(jax.nn.silu(jnp.dot(xb, wi)), wo)
    exact_fn_p = lambda ep, xb: jnp.dot(jax.nn.silu(jnp.dot(xb, ep[0])),
                                        ep[1])

    base_bound = 0.10
    bounds = default_tier_bounds(base_bound)      # tight / base / loose
    nt = len(bounds)
    # calibrate the margin scale to THIS router's logit spread so the
    # tier bias actually moves borderline rows (margins are traced — a
    # production server recalibrates without recompiling)
    scale = 1.5 * float(jnp.std(lg)) / float(np.log(2.0))
    margins = jnp.asarray(margins_from_bounds(bounds, base_bound,
                                              scale=scale), jnp.float32)
    # visited operating points: a tight rung where capacity BINDS (drops
    # visible in the per-tier columns), an ASYMMETRIC per-class rung (hot
    # class 1 gets the capacity the cold tail gives up), and the
    # full-capacity escape rung
    asym = tuple(round(f, 3) for f in np.linspace(0.5, 0.15, n))
    points = [OperatingPoint(0.5, 0.15),
              OperatingPoint(0.5, max(asym), invoke_fracs=asym),
              OperatingPoint(1.0, 1.0)]
    mixes = [(1, 1, 1), (3, 1, 1), (1, 1, 3)]    # balanced / tight- / loose-heavy

    mesh = jax.make_mesh((devices,), ("data",)) if devices > 1 else None
    tl = t // devices
    fns = {}                                  # (rung, backend) -> jitted fn

    def run_point(idx, tier, backend):
        pt = points[idx]
        ec = shard_capacity(tl, pt.exact_frac, slack=pt.shard_slack)
        ic = tuple(shard_capacity(tl, f, slack=pt.shard_slack)
                   for f in pt.class_fracs(n))
        interp = on_cpu and backend == "pallas"
        if (idx, backend) not in fns:
            if mesh is None:
                fns[(idx, backend)] = jax.jit(
                    lambda a, b, tr, tm, be=backend, ip=interp, e=ec, i=ic:
                    D.mcma_dispatch(a, b, exact_fn, w1, b1, w2, b2,
                                    exact_cap=e, invoke_cap=i, backend=be,
                                    block_t=block_t, interpret=ip,
                                    tier=tr, tier_margins=tm))
            else:
                fns[(idx, backend)] = jax.jit(
                    lambda a, b, tr, tm, be=backend, ip=interp, e=ec, i=ic:
                    D.mcma_dispatch_sharded(
                        mesh, a, b, exact_fn_p, (wi, wo), w1, b1, w2, b2,
                        exact_cap=e, invoke_cap=i, backend=be,
                        block_t=block_t, interpret=ip,
                        tier=tr, tier_margins=tm))
        return fns[(idx, backend)](x, lg, tier, margins)

    for mi, mix in enumerate(mixes):
        p = np.asarray(mix, float) / sum(mix)
        tier = jnp.asarray(
            np.random.default_rng(100 + mi).choice(nt, t, p=p), jnp.int32)
        for idx, pt in enumerate(points):
            yx, sx = run_point(idx, tier, "xla")
            yp, sp = run_point(idx, tier, "pallas")
            err = float(np.abs(np.asarray(yp) - np.asarray(yx)).max())
            assert err < 1e-4, \
                f"pallas-vs-xla divergence at point {pt} mix {mix}: {err}"
            tc = np.asarray(sx["tier_counts"], float)
            td = np.asarray(sx["tier_dispatched"], float)
            trows = tc.sum(-1)
            assert (trows > 0).all(), (mix, trows)
            served = td[:, 1:].sum(-1) / trows
            # the QoS contract this PR ships: a looser bound buys strictly
            # more SERVED invocation than a tighter one in the same batch
            assert served[-1] > served[0], \
                f"loose tier must out-invoke tight at point {pt} mix " \
                f"{mix}: served={served}"
            row = {
                "T": t, "n_approx": n, "d_model": d, "backend": "both",
                "block_t": block_t, "interpret": on_cpu,
                "devices": devices, "mode": "qos",
                "op_index": idx,
                "op_exact_frac": pt.exact_frac,
                "op_invoke_frac": pt.invoke_frac,
                "op_invoke_fracs": "/".join(str(f) for f in
                                            pt.class_fracs(n)),
                "tier_mix": ":".join(str(m) for m in mix),
                "invocation": round(float(sx["invocation"]), 4),
                "exact_frac": round(float(sx["exact_frac"]), 4),
                "dropped": int(sx["dropped"]),
                "max_abs_err_vs_xla": round(err, 7),
            }
            for k in range(nt):
                row[f"tier{k}_bound"] = bounds[k]
                row[f"tier{k}_margin"] = round(float(margins[k]), 3)
                row[f"tier{k}_rows"] = int(trows[k])
                row[f"tier{k}_served_inv"] = round(float(served[k]), 4)
                row[f"tier{k}_dropped"] = int(
                    (tc[k] - td[k]).sum())
            rows.append(row)
            print(f"qos x{devices} mix={row['tier_mix']:5s} point={idx} "
                  f"served_inv per tier="
                  f"{[round(float(s), 3) for s in served]}", flush=True)
    # margins and tier mixes are traced inputs: every mix above reused
    # ONE compiled program per (rung, backend)
    for f in fns.values():
        assert_zero_retrace(f, "a tier-mix change")


def _library_leg(rows, *, quick, devices=1):
    """Approximator-library residency microbench: a 16-member library
    with 4 resident slots serves a phase-shifting skewed demand mix.
    The residency-tuned arm (runtime/autotune.ResidencyController) and a
    static-first-n baseline run the SAME compiled program at the SAME
    capacities (same drop budget) — the tuned arm must serve strictly
    more approximator rows once demand shifts onto off-set classes.
    Pallas is gated against the XLA oracle at every visited residency
    set, and the whole trajectory must cost ZERO retraces (a swap is a
    new traced index vector)."""
    from repro.kernels import ops
    from repro.runtime.autotune import ResidencyController
    from repro.runtime.options import LibrarySpec
    from repro.sharding.rules import shard_capacity

    lib, n_res = 16, 4
    t = 256 if quick else 1024
    d, d_h, d_ff, block_t = (128, 32, 256, 64) if quick \
        else (256, 64, 1024, 128)
    on_cpu = jax.default_backend() != "tpu"
    key = jax.random.PRNGKey(29)
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (t, d), jnp.float32) * 0.5
    w1 = jax.random.normal(ks[1], (lib, d, d_h)) * 0.2
    b1 = jnp.zeros((lib, d_h))
    w2 = jax.random.normal(ks[2], (lib, d_h, d)) * 0.2
    b2 = jnp.zeros((lib, d))
    wi = jax.random.normal(ks[3], (d, d_ff)) * 0.1
    wo = jax.random.normal(ks[4], (d_ff, d)) * 0.1
    exact_fn = lambda xb: jnp.dot(jax.nn.silu(jnp.dot(xb, wi)), wo)
    exact_fn_p = lambda ep, xb: jnp.dot(jax.nn.silu(jnp.dot(xb, ep[0])),
                                        ep[1])
    W = ops.prepad_switched_weights(w1, b1, w2, b2)   # full library, once

    mesh = jax.make_mesh((devices,), ("data",)) if devices > 1 else None
    tl = t // devices
    ec = shard_capacity(tl, 0.5)
    ic = shard_capacity(tl, 0.3)                      # per resident slot

    fns = {}
    for backend in ("xla", "pallas"):
        interp = on_cpu and backend == "pallas"
        if mesh is None:
            fns[backend] = jax.jit(
                lambda xx, lg, rv, be=backend, ip=interp:
                D.mcma_dispatch(xx, lg, exact_fn, *W, exact_cap=ec,
                                invoke_cap=ic, backend=be, block_t=block_t,
                                interpret=ip, weights_prepadded=True,
                                residency=rv))
        else:
            fns[backend] = jax.jit(
                lambda xx, lg, rv, be=backend, ip=interp:
                D.mcma_dispatch_sharded(
                    mesh, xx, lg, exact_fn_p, (wi, wo), *W, exact_cap=ec,
                    invoke_cap=ic, backend=be, block_t=block_t,
                    interpret=ip, weights_prepadded=True, residency=rv))

    spec = LibrarySpec(library_size=lib, n_resident=n_res,
                       observe_window=2, cooldown=2, ema=0.5)
    ctrl = ResidencyController(spec)
    static = jnp.arange(n_res, dtype=jnp.int32)

    # three demand phases: hot class starts resident, then demand shifts
    # onto two off-set classes — the static arm folds the hot traffic
    # onto the exact path, the tuned arm swaps the hot weights in
    phases = [(1, 8), (10, 12), (14, 12)] if quick \
        else [(1, 10), (10, 20), (14, 20)]
    tuned_acc = np.zeros(2)                           # served approx, dropped
    static_acc = np.zeros(2)
    tick = 0
    tick_ms = []                                      # (post_swap, ms)
    for hot_cls, ticks in phases:
        for _ in range(ticks):
            lg = _skewed_logits(jax.random.fold_in(key, tick), t, lib,
                                hot_cls + 1, 0.6)
            resv = jnp.asarray(ctrl.residency, jnp.int32)
            swaps_before = len(ctrl.history)
            t0 = time.perf_counter()
            yx, sx = fns["xla"](x, lg, resv)
            jax.block_until_ready(yx)
            ms = (time.perf_counter() - t0) * 1e3
            yp, sp = fns["pallas"](x, lg, resv)
            err = float(np.abs(np.asarray(yp) - np.asarray(yx)).max())
            assert err < 1e-4, \
                f"pallas-vs-xla divergence at residency " \
                f"{ctrl.residency}: {err}"
            # static baseline: same program, the start residency (free —
            # residency is a traced input, no second compile)
            ss = sx if tuple(np.asarray(resv)) == tuple(range(n_res)) \
                else fns["xla"](x, lg, static)[1]
            tuned_acc += (float(np.asarray(sx["dispatched"])[1:].sum()),
                          float(sx["dropped"]))
            static_acc += (float(np.asarray(ss["dispatched"])[1:].sum()),
                           float(ss["dropped"]))
            ctrl.observe(jax.tree.map(np.asarray, sx))
            post_swap = len(ctrl.history) > swaps_before
            tick_ms.append((post_swap, ms))
            rows.append({
                "T": t, "n_approx": n_res, "d_model": d, "backend": "both",
                "block_t": block_t, "interpret": on_cpu,
                "devices": devices, "mode": "library",
                "tick": tick, "library_size": lib,
                "residency": "/".join(str(c) for c in
                                      np.asarray(resv).tolist()),
                "swap_count": len(ctrl.history),
                "ms_per_call": round(ms, 3),
                "invocation": round(float(sx["invocation"]), 4),
                "exact_frac": round(float(sx["exact_frac"]), 4),
                "dropped": int(sx["dropped"]),
                "served_invocation": round(
                    float(np.asarray(sx["dispatched"])[1:].sum())
                    / max(float(np.asarray(sx["class_counts"]).sum()), 1),
                    4),
                "off_set_exact_rows": int(sx["off_set_exact_rows"]),
                "static_served_invocation": round(
                    float(np.asarray(ss["dispatched"])[1:].sum())
                    / max(float(np.asarray(ss["class_counts"]).sum()), 1),
                    4),
                "max_abs_err_vs_xla": round(err, 7),
            })
            tick += 1

    # swap economics for the CSV/summary: a swap is a traced-index
    # update, so post-swap ticks must not pay a recompile
    steady = [m for p, m in tick_ms[1:] if not p]
    after = [tick_ms[i + 1][1] for i, (p, _) in enumerate(tick_ms[:-1])
             if p]
    swap_cost = (float(np.median(after)) - float(np.median(steady))) \
        if after and steady else 0.0
    swap_rate = len(ctrl.history) / tick
    rows.append({
        "T": t, "n_approx": n_res, "d_model": d, "backend": "both",
        "block_t": block_t, "interpret": on_cpu, "devices": devices,
        "mode": "library-summary", "library_size": lib, "tick": tick,
        "swap_count": len(ctrl.history),
        "swap_rate": round(swap_rate, 4),
        "swap_cost_ms": round(swap_cost, 3),
        "served_invocation": round(tuned_acc[0] / (tick * t), 4),
        "static_served_invocation": round(static_acc[0] / (tick * t), 4),
        "dropped": int(tuned_acc[1]),
        "residency": "/".join(str(c) for c in ctrl.residency),
    })
    print(f"library x{devices}: {len(ctrl.history)} swaps over {tick} "
          f"ticks (rate {swap_rate:.3f}, post-swap cost "
          f"{swap_cost:+.2f} ms), served approx rows tuned "
          f"{tuned_acc[0]:.0f} vs static {static_acc[0]:.0f} "
          f"(dropped {tuned_acc[1]:.0f} vs {static_acc[1]:.0f})",
          flush=True)
    # acceptance gates: the tuned arm must win strictly, the demand shift
    # must actually stress the static set, and NOTHING may have retraced
    assert len(ctrl.history) >= 2, \
        "demand phases failed to trigger residency swaps"
    assert tuned_acc[0] > static_acc[0], \
        "residency tuning must serve strictly more approximator rows " \
        "than the static resident set at the same capacities"
    for backend, f in fns.items():
        assert_zero_retrace(f, f"{backend}: a residency swap")


def _decode_tick_leg(rows, *, quick):
    """Full decode tick, route_scope=layer vs tick, oracle-gated."""
    import dataclasses
    from repro.configs.registry import get_config, smoke_config
    from repro.models import model as M
    from repro.runtime import steps as S

    on_cpu = jax.default_backend() != "tpu"
    n_layers, batch, iters = (4, 64, 3) if quick else (8, 128, 10)
    base = smoke_config(get_config("internlm2-1.8b"))
    base = dataclasses.replace(base, n_layers=n_layers)

    def cfg_with(scope, backend):
        return dataclasses.replace(base, approx=dataclasses.replace(
            base.approx, enable=True, backend=backend,
            interpret=on_cpu and backend == "pallas", block_t=32,
            route_scope=scope))

    params = M.init_model(jax.random.PRNGKey(0), cfg_with("layer", "xla"))
    cache = M.init_cache(base, batch, 64)
    toks = jnp.asarray(
        np.random.default_rng(0).integers(1, base.vocab, (batch, 1)),
        jnp.int32)
    mask = jnp.ones((batch,), bool)

    sorts = {}
    for scope in ("layer", "tick"):
        outs = {}
        for backend in ("xla", "pallas"):
            cfg = cfg_with(scope, backend)
            step = jax.jit(S.make_decode_step(cfg, with_stats=True))
            lg, _, m = step(params, cache, toks, mask)
            jax.block_until_ready(lg)                # compile off the clock
            t0 = time.perf_counter()
            for _ in range(iters):
                lg, _, m = step(params, cache, toks, mask)
            jax.block_until_ready(lg)
            ms = (time.perf_counter() - t0) / iters * 1e3
            outs[backend] = np.asarray(lg)
            jaxpr = jax.make_jaxpr(S.make_decode_step(cfg, with_stats=True))(
                params, cache, toks, mask).jaxpr
            n_sorts = count_dynamic_ops(jaxpr, {"sort"})
            n_scatter = count_dynamic_ops(
                jaxpr, {"scatter", "scatter-add"})
            sorts[(scope, backend)] = n_sorts
            rows.append({
                "T": batch, "n_approx": base.approx.n_approx,
                "d_model": base.d_model, "backend": backend,
                "block_t": 32, "interpret": on_cpu and backend == "pallas",
                "devices": 1, "mode": "decode-tick",
                "route_scope": scope, "layers": n_layers,
                "ms_per_tick": round(ms, 3),
                "sorts_per_tick": n_sorts,
                "scatters_per_tick": n_scatter,
                "invocation": round(float(m["invocation"]), 4),
                "exact_frac": round(float(m["exact_frac"]), 4),
            })
            print(f"decode-tick L={n_layers} B={batch} scope={scope:5s} "
                  f"{backend:6s} {ms:9.2f} ms/tick sorts={n_sorts} "
                  f"scatters={n_scatter}", flush=True)
        # oracle gate at this scope, same as the other legs
        err = float(np.abs(outs["pallas"] - outs["xla"]).max())
        for row in rows[-2:]:
            row["max_abs_err_vs_xla"] = round(err, 7) \
                if row["backend"] == "pallas" else 0.0
        assert err < 1e-4, f"decode-tick divergence at scope={scope}: {err}"
    # the leg's acceptance gate: one class-sort per tick, not one per
    # layer.  Only the Pallas executor sorts (the plan builds the sort
    # for it; the XLA oracle re-derives per-class slots from cls/rank and
    # honestly records 0 at both scopes — no dead argsorts in the CSV).
    assert sorts[("layer", "pallas")] == n_layers, sorts
    assert sorts[("tick", "pallas")] == 1, sorts
    assert sorts[("tick", "xla")] <= sorts[("layer", "xla")], sorts


def _backend_sweep_leg(rows, *, quick, iters, devices):
    """Fused vs unfused Pallas vs XLA at tick scope, op-count audited.

    One DispatchPlan per backend drives an L-layer ``lax.scan`` of
    ``execute_dispatch`` (distinct weights per layer; the approximators
    map d -> d so layer outputs chain through the next layer), timed as
    ms_per_tick.  Each backend's tick jaxpr is audited with
    ``repro.analysis.opcount.activation_moves`` — STANDALONE
    activation-sized (rank >= 2) gathers/scatters per layer, pallas
    kernel bodies excluded.  Gates per shape:

      * fused runs <= 1 standalone gather and <= 1 scatter per layer
        (the exact-path capacity buffers) and strictly fewer of both
        than unfused pallas (which pays the class-sort gather/scatter
        legs per layer);
      * fused is BITWISE equal to unfused pallas and < 1e-4 vs the XLA
        oracle — at BOTH visited operating points (uniform caps with a
        row mask + tiered margins, then asymmetric per-class caps);
      * moving to the second operating point retraces nothing (mask,
        tier, margins are traced plan inputs);
      * on CPU (interpret mode) fused must not be slower than unfused —
        the fused kernel does strictly less XLA-level work there, so a
        regression means the fusion itself broke.
    """
    on_cpu = jax.default_backend() != "tpu"
    layers = 4
    if quick:
        shapes = [(256, 2), (512, 4)]
        d, d_h, d_ff, block_t = 128, 32, 256, 64
    else:
        shapes = [(1024, 4), (2048, 8) if on_cpu else (4096, 8)]
        d, d_h, d_ff, block_t = 512, 64, 2048, 128
    iters = iters or (3 if quick else 10)
    margins = jnp.asarray([0.5, 0.0, -0.5], jnp.float32)

    for t, n in shapes:
        key = jax.random.PRNGKey(t * 131 + n)
        x, logits, (w1, b1, w2, b2), (wi, wo) = _make_case(
            key, t, n, d, d_h, d_ff)
        exact_fn = lambda xb: jnp.dot(jax.nn.silu(jnp.dot(xb, wi)), wo)
        # L distinct approximator banks: scaled copies keep layer outputs
        # bounded while making every layer a real weight switch
        stacked = jax.tree.map(
            lambda a: jnp.stack([a * (0.7 + 0.1 * i)
                                 for i in range(layers)]),
            (w1, b1, w2, b2))
        tier = jnp.arange(t, dtype=jnp.int32) % 3
        mask = jnp.arange(t) % 16 != 0
        # two operating points: uniform caps, then asymmetric per-class
        cap_points = [
            (max(t // 2, 1), max(int(t * 0.4), 1)),
            (max(t // 2, 1), tuple(max(t // (4 + 2 * c), block_t)
                                   for c in range(n))),
        ]
        per_tick, outs = {}, {be: [] for be in D.DISPATCH_BACKENDS}
        for backend in D.DISPATCH_BACKENDS:
            interp = on_cpu and backend in D.PALLAS_BACKENDS
            for pt_i, (ec, ic) in enumerate(cap_points):
                plan_fn = jax.jit(
                    lambda lg, tr, mg, mk, be=backend, e=ec, i=ic:
                    D.make_dispatch_plan(
                        lg, mk, exact_cap=e, invoke_cap=i, backend=be,
                        block_t=block_t, tier=tr, tier_margins=mg))

                def tick(plan, xx, ip=interp):
                    def layer(h, ws):
                        lw1, lb1, lw2, lb2 = ws
                        return D.execute_dispatch(
                            plan, h, exact_fn, lw1, lb1, lw2, lb2,
                            interpret=ip), None
                    return jax.lax.scan(layer, xx, stacked)[0]

                tick_fn = jax.jit(tick)
                plan = plan_fn(logits, tier, margins, mask)
                y = tick_fn(plan, x)
                jax.block_until_ready(y)         # compile off the clock
                t0 = time.perf_counter()
                for _ in range(iters):
                    y = tick_fn(plan, x)
                jax.block_until_ready(y)
                ms = (time.perf_counter() - t0) / iters * 1e3
                outs[backend].append(np.asarray(y))
                if pt_i == 0:
                    g, s = activation_moves(jax.make_jaxpr(tick)(plan, x))
                    assert g % layers == 0 and s % layers == 0, (g, s)
                    gl, sl = g // layers, s // layers
                    per_tick[backend] = (ms, gl, sl)
                    stats = D.plan_invoke_stats(plan)
                    rows.append({
                        "T": t, "n_approx": n, "d_model": d,
                        "backend": backend, "block_t": block_t,
                        "interpret": interp, "devices": 1,
                        "mode": "backend-sweep", "layers": layers,
                        "ms_per_tick": round(ms, 3),
                        "gathers_per_layer": gl,
                        "scatters_per_layer": sl,
                        "invocation": round(float(stats["invocation"]), 4),
                        "exact_frac": round(float(stats["exact_frac"]), 4),
                        "dropped": int(stats["dropped"]),
                    })
                    print(f"backend-sweep T={t:6d} n={n} L={layers} "
                          f"{backend:12s} {ms:9.2f} ms/tick "
                          f"gathers/layer={gl} scatters/layer={sl}",
                          flush=True)
                else:
                    # operating-point switch: new caps = new shapes = one
                    # fresh compile, but mask/tier/margins stay traced —
                    # replaying the FIRST point's fns must not retrace
                    p0 = plan_fn_prev(logits, tier, margins, ~mask)
                    jax.block_until_ready(tick_fn_prev(p0, x))
                    assert_zero_retrace(
                        plan_fn_prev, f"{backend}: a mask/tier change")
                    assert_zero_retrace(
                        tick_fn_prev, f"{backend}: a replanned tick")
                plan_fn_prev, tick_fn_prev = plan_fn, tick_fn

        # divergence gates at every visited operating point
        for pt_i in range(len(cap_points)):
            err_f = float(np.abs(outs["pallas_fused"][pt_i] -
                                 outs["xla"][pt_i]).max())
            assert err_f < 1e-4, \
                f"fused-vs-xla divergence at T={t} point {pt_i}: {err_f}"
            assert np.array_equal(outs["pallas_fused"][pt_i],
                                  outs["pallas"][pt_i]), \
                f"fused != unfused pallas bitwise at T={t} point {pt_i}"
        for r in rows:
            if r.get("mode") == "backend-sweep" and r["T"] == t \
                    and r["backend"] != "xla":
                r["max_abs_err_vs_xla"] = round(err_f, 7)

        # op-count gates: the fused kernel leaves at most the exact-path
        # capacity buffers (1 gather + 1 scatter) standalone per layer;
        # unfused pallas additionally pays the class-sort legs
        _, gf, sf = per_tick["pallas_fused"]
        _, gu, su = per_tick["pallas"]
        assert gf <= 1 and sf <= 1, \
            f"fused backend runs {gf} gathers/{sf} scatters per layer"
        assert gf < gu and sf < su, \
            f"fusion audit: fused ({gf},{sf}) vs unfused ({gu},{su})"
        if on_cpu:
            ms_f, ms_u = per_tick["pallas_fused"][0], per_tick["pallas"][0]
            assert ms_f <= ms_u * 1.10, \
                (f"fused slower than unfused in interpret mode at T={t}: "
                 f"{ms_f:.2f} vs {ms_u:.2f} ms/tick")
        for r in rows:
            if r.get("mode") == "backend-sweep" and r["T"] == t \
                    and r["backend"] == "pallas_fused":
                r["speedup_vs_unfused"] = round(
                    per_tick["pallas"][0] / per_tick["pallas_fused"][0], 3)

    if devices > 1:
        _backend_sweep_sharded(rows, quick=quick, iters=iters,
                               devices=devices)


def _backend_sweep_sharded(rows, *, quick, iters, devices):
    """One shape through ``mcma_dispatch_sharded`` per backend on an
    N-way mesh — the fused kernel inside shard_map, gated bitwise against
    unfused pallas and < 1e-4 against the XLA oracle."""
    on_cpu = jax.default_backend() != "tpu"
    t, n = (512, 4) if quick else (2048, 4)
    d, d_h, d_ff, block_t = (128, 32, 256, 64) if quick \
        else (512, 64, 2048, 128)
    assert t % devices == 0, (t, devices)
    tl = t // devices
    ec_l, ic_l = max(tl // 2, 1), max(int(tl * 0.4), 1)
    key = jax.random.PRNGKey(t * 131 + n + 1)
    x, logits, (w1, b1, w2, b2), (wi, wo) = _make_case(
        key, t, n, d, d_h, d_ff)
    exact_fn_p = lambda ep, xb: jnp.dot(jax.nn.silu(jnp.dot(xb, ep[0])),
                                        ep[1])
    mesh = jax.make_mesh((devices,), ("data",))
    outs = {}
    for backend in D.DISPATCH_BACKENDS:
        interp = on_cpu and backend in D.PALLAS_BACKENDS
        fn = jax.jit(lambda xx, lg, be=backend, ip=interp:
                     D.mcma_dispatch_sharded(
                         mesh, xx, lg, exact_fn_p, (wi, wo),
                         w1, b1, w2, b2, exact_cap=ec_l,
                         invoke_cap=ic_l, backend=be,
                         block_t=block_t, interpret=ip))
        ms, stats = _time(fn, x, logits, iters=iters)
        outs[backend] = np.asarray(fn(x, logits)[0])
        _record(rows, t=t, n=n, d=d, backend=backend, block_t=block_t,
                interpret=interp, ms=ms, stats=stats, devices=devices,
                mode="backend-sweep-sharded")
        print(f"  (sharded sweep x{devices})", flush=True)
    err = float(np.abs(outs["pallas_fused"] - outs["xla"]).max())
    assert err < 1e-4, f"sharded fused-vs-xla divergence: {err}"
    assert np.array_equal(outs["pallas_fused"], outs["pallas"]), \
        "sharded fused != unfused pallas bitwise"
    for r in rows[-2:]:
        r["max_abs_err_vs_xla"] = round(err, 7)


def main(quick: bool = False, iters: int | None = None, devices: int = 1,
         autotune: bool = False, decode_tick: bool = False,
         qos: bool = False, library: bool = False,
         backend_sweep: bool = False):
    os.makedirs(OUT, exist_ok=True)
    on_cpu = jax.default_backend() != "tpu"
    if devices > 1 and len(jax.devices()) < devices:
        raise SystemExit(
            f"--devices {devices} needs {devices} jax devices but only "
            f"{len(jax.devices())} exist; run via `python -m "
            f"benchmarks.bench_dispatch` (which forces virtual CPU devices) "
            f"or set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{devices}")
    if quick:
        shapes = [(256, 2), (512, 4)]
        d, d_h, d_ff, block_t = 128, 32, 256, 64
        iters = iters or 3
    else:
        shapes = [(1024, 2), (4096, 4), (4096, 8), (16384, 4)]
        d, d_h, d_ff, block_t = 512, 64, 2048, 256
        iters = iters or 10
        if on_cpu:  # interpreter-mode Pallas: keep CPU runs bounded
            shapes = [s for s in shapes if s[0] <= 4096]

    rows = []
    for t, n in shapes:
        key = jax.random.PRNGKey(t * 31 + n)
        x, logits, (w1, b1, w2, b2), (wi, wo) = _make_case(
            key, t, n, d, d_h, d_ff)
        exact_fn = lambda xb: jnp.dot(jax.nn.silu(jnp.dot(xb, wi)), wo)
        exact_cap, invoke_cap = max(t // 2, 1), max(int(t * 0.4), 1)
        outs = {}
        for backend in ("xla", "pallas"):
            interp = on_cpu and backend == "pallas"
            fn = jax.jit(lambda xx, lg, be=backend, ip=interp:
                         D.mcma_dispatch(
                             xx, lg, exact_fn, w1, b1, w2, b2,
                             exact_cap=exact_cap, invoke_cap=invoke_cap,
                             backend=be, block_t=block_t, interpret=ip))
            ms, stats = _time(fn, x, logits, iters=iters)
            y, _ = fn(x, logits)
            outs[backend] = np.asarray(y)
            _record(rows, t=t, n=n, d=d, backend=backend, block_t=block_t,
                    interpret=interp, ms=ms, stats=stats, devices=1,
                    mode="single")
        _check_oracle(rows, outs, t, n)

        if devices > 1:
            assert t % devices == 0, (t, devices)
            tl = t // devices
            ec_l, ic_l = max(tl // 2, 1), max(int(tl * 0.4), 1)
            mesh = jax.make_mesh((devices,), ("data",))
            exact_fn_p = lambda ep, xb: jnp.dot(
                jax.nn.silu(jnp.dot(xb, ep[0])), ep[1])
            outs_sh = {}
            for backend in ("xla", "pallas"):
                interp = on_cpu and backend == "pallas"
                # global sharded call: all shards dispatch concurrently,
                # invoke_stats psum-reduced to global totals
                fn = jax.jit(lambda xx, lg, be=backend, ip=interp:
                             D.mcma_dispatch_sharded(
                                 mesh, xx, lg, exact_fn_p, (wi, wo),
                                 w1, b1, w2, b2, exact_cap=ec_l,
                                 invoke_cap=ic_l, backend=be,
                                 block_t=block_t, interpret=ip))
                ms, stats = _time(fn, x, logits, iters=iters)
                y, _ = fn(x, logits)
                outs_sh[backend] = np.asarray(y)
                _record(rows, t=t, n=n, d=d, backend=backend,
                        block_t=block_t, interpret=interp, ms=ms,
                        stats=stats, devices=devices, mode="sharded")
            _check_oracle(rows, outs_sh, t, n)
            # shard-local baseline: one shard's rows on one device — the
            # per-shard cost the sharded mode amortizes across devices
            outs_loc = {}
            for backend in ("xla", "pallas"):
                interp = on_cpu and backend == "pallas"
                fn = jax.jit(lambda xx, lg, be=backend, ip=interp:
                             D.mcma_dispatch(
                                 xx, lg, exact_fn, w1, b1, w2, b2,
                                 exact_cap=ec_l, invoke_cap=ic_l,
                                 backend=be, block_t=block_t, interpret=ip))
                ms, stats = _time(fn, x[:tl], logits[:tl], iters=iters)
                outs_loc[backend] = np.asarray(fn(x[:tl], logits[:tl])[0])
                _record(rows, t=tl, n=n, d=d, backend=backend,
                        block_t=block_t, interpret=interp, ms=ms,
                        stats=stats, devices=1, mode="shard-local")
            _check_oracle(rows, outs_loc, tl, n)

    if autotune:
        _autotune_leg(rows, quick=quick, devices=devices)
    if qos:
        _qos_leg(rows, quick=quick, devices=devices)
    if library:
        _library_leg(rows, quick=quick, devices=devices)
    if decode_tick:
        _decode_tick_leg(rows, quick=quick)
    if backend_sweep:
        _backend_sweep_leg(rows, quick=quick, iters=iters, devices=devices)

    # column union across modes (the autotune rows add trajectory columns)
    fields = list(rows[0].keys())
    for r in rows:
        fields += [k for k in r if k not in fields]
    with open(os.path.join(OUT, "dispatch.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=fields, restval="")
        w.writeheader()
        w.writerows(rows)
    print(f"wrote {os.path.join(OUT, 'dispatch.csv')} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--devices", type=int, default=1,
                    help="shard the dispatch over an N-way data mesh "
                         "(forces N virtual CPU devices when run as main)")
    ap.add_argument("--autotune", action="store_true",
                    help="add the capacity-autotuning trajectory leg "
                         "(controller over a skewed phase-shifting mix; "
                         "pallas-vs-xla gated at every operating point)")
    ap.add_argument("--decode-tick", action="store_true",
                    help="add the tick-level dispatch-planning leg: a full "
                         "L-layer decode tick at route_scope=layer vs tick "
                         "(per-tick wall + dynamic sort/scatter op counts; "
                         "asserts 1 class-sort per tick under tick scope "
                         "and pallas==xla at both scopes)")
    ap.add_argument("--library", action="store_true",
                    help="add the approximator-library residency leg: a "
                         "16-member library with 4 resident slots over a "
                         "phase-shifting skewed mix; the controller-tuned "
                         "residency must serve strictly more approximator "
                         "rows than the static first-4 set at the same "
                         "capacities, pallas==xla at every visited "
                         "residency set, zero retraces across swaps")
    ap.add_argument("--qos", action="store_true",
                    help="add the per-request QoS tier-mix sweep: mixed "
                         "error-bound batches at several operating points "
                         "(incl. an asymmetric per-class-capacity rung), "
                         "pallas-vs-xla gated per mix; asserts loose-bound "
                         "rows serve strictly more invocation than "
                         "tight-bound rows at every visited point")
    ap.add_argument("--backend-sweep", action="store_true",
                    help="add the fused-kernel sweep: fused vs unfused "
                         "pallas vs xla over an L-layer tick per shape "
                         "(ms_per_tick + standalone activation gather/"
                         "scatter counts per layer from a jaxpr audit); "
                         "asserts the fused backend runs <=1 of each per "
                         "layer, matches unfused pallas BITWISE and the "
                         "xla oracle <1e-4 at every visited operating "
                         "point, and is no slower than unfused in "
                         "interpret mode (with --devices N also through "
                         "the sharded engine)")
    args = ap.parse_args()
    if args.devices > 1 and "host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        # must land before jax initializes its backend (first device use)
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.devices}").strip()
    main(quick=args.quick, iters=args.iters, devices=args.devices,
         autotune=args.autotune, decode_tick=args.decode_tick,
         qos=args.qos, library=args.library,
         backend_sweep=args.backend_sweep)
