"""Fig. 7(c): invocation vs error bound on Black-Scholes.

The paper's claim: as the bound tightens, MCMA's invocation drops the
LEAST — multiple approximators keep salvaging data that a single
approximator abandons.  Writes benchmarks/out/errorbound.csv.
"""
from __future__ import annotations

import csv
import dataclasses
import os

import jax

from repro.apps import APPS, make_dataset
from repro.core import train_iterative, train_mcma, train_one_pass

OUT = os.path.join(os.path.dirname(__file__), "out")
BOUNDS = (0.025, 0.05, 0.075, 0.10, 0.15)


def main(n_train=8_000, n_test=3_000, epochs=1500, seed=0, bounds=BOUNDS):
    os.makedirs(OUT, exist_ok=True)
    app0 = APPS["blackscholes"]
    key = jax.random.PRNGKey(seed)
    xtr, ytr, xte, yte = make_dataset(app0, key, n_train, n_test)
    rows = []
    for bound in bounds:
        app = dataclasses.replace(app0, error_bound=bound)
        ks = jax.random.split(jax.random.fold_in(key, int(bound * 1e4)), 4)
        res = {
            "one-pass": train_one_pass(app, ks[0], xtr, ytr,
                                       epochs=epochs).evaluate(xte, yte),
            "iterative": train_iterative(app, ks[1], xtr, ytr,
                                         epochs=epochs).evaluate(xte, yte),
            "mcma-complementary": train_mcma(
                app, ks[2], xtr, ytr, scheme="complementary",
                epochs=epochs).evaluate(xte, yte),
            "mcma-competitive": train_mcma(
                app, ks[3], xtr, ytr, scheme="competitive",
                epochs=epochs).evaluate(xte, yte),
        }
        for method, met in res.items():
            rows.append({"bound": bound, "method": method,
                         "invocation": round(met.invocation, 4),
                         "err_over_bound": round(met.err_norm, 4)})
            print(f"bound={bound:.3f} {method:18s} inv={met.invocation:.3f}",
                  flush=True)
    with open(os.path.join(OUT, "errorbound.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    main()
