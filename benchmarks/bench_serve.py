"""Serving-scheduler bench: replay Poisson / bursty arrival streams
through ``runtime/server.DecodeServer`` and report p50/p99 time-to-first-
token and tokens/sec at several offered loads, chunked prefill vs the
token-by-token reference.

Arrivals are scheduled in TICK time (a request arrives "at tick T"), so
the replay — and every TTFT-in-ticks number — is fully deterministic and
the chunked/token-by-token comparison runs the exact same request stream.
Wall-clock TTFT and tokens/sec are reported next to the tick numbers; on
CPU the Pallas dispatch runs in interpreter mode, so wall columns measure
scheduling+plumbing, not kernel speed (rerun on TPU for real numbers).

Four servers replay each (process, load) cell:
  * ``token``  — prefill_chunk=0, FIFO admission: the pre-chunking
    reference path (one prompt token per decode tick);
  * ``chunk``  — chunked prefill + cost-model admission: the scheduler
    this bench exists to measure;
  * ``paged``  — the chunk scheduler on the paged KV cache
    (kv_page_size=16, full pool): same tokens, strictly fewer resident
    KV bytes on this mixed-length stream (max_len=160 overshoots the
    typical request by design — the dense layout pays worst case per
    slot, the paged one pays its page high-water mark);
  * ``chunk-xla`` (one cell only) — same scheduler on the XLA oracle
    dispatch backend, gating the Pallas engine at the SERVER level.

Gates (the bench fails loudly, it does not just report):
  * greedy decode tokens per request are IDENTICAL between token and
    chunk modes on every cell (the servers run at a no-clip operating
    point — capacity contention is batch-mix-dependent by design, so the
    bit-exactness contract holds when prefill-phase capacity never
    binds; docs/serving.md spells this out);
  * chunked prefill beats token-by-token mean TTFT (in ticks) on the
    long prompts (>= 64 tokens) of every cell;
  * the paged cell's greedy tokens are IDENTICAL to the dense chunk
    cell's, its ``kv_bytes_resident`` is STRICTLY below dense, and its
    pages all return to the pool at drain;
  * pallas == xla greedy tokens on the gated cell.

    PYTHONPATH=src python -m benchmarks.bench_serve --quick
    PYTHONPATH=src python -m benchmarks.bench_serve --quick --devices 8

``--devices N`` replays on an N-way data mesh (virtual CPU devices when
run as __main__): params/cache sharded by the declarative rules, both
steps traced under serve_mesh_context, invoke_stats psum-reduced.

Writes benchmarks/out/serve.csv next to dispatch.csv.
"""
from __future__ import annotations

import argparse
import csv
import dataclasses
import os
import time

import numpy as np

OUT = os.path.join(os.path.dirname(__file__), "out")

LONG_PROMPT = 64          # the TTFT-gate threshold from the PR criteria


@dataclasses.dataclass
class _Arrival:
    rid: int
    tick: int
    prompt: np.ndarray
    max_new: int
    tier: int | None


def gen_stream(process: str, load: float, n_reqs: int, vocab: int,
               *, n_tiers: int = 0, seed: int = 0) -> list[_Arrival]:
    """Deterministic tick-time arrival stream.  ``load`` = offered
    requests per tick.  "poisson": exponential inter-arrivals; "bursty":
    the same mean load concentrated in bursts of 4 back-to-back arrivals.
    Prompt lengths mix short (8-24) and long (64-96) so the TTFT gate
    always has both populations."""
    rng = np.random.default_rng(seed + int(load * 1000))
    out, t = [], 0
    for i in range(n_reqs):
        if process == "poisson":
            t += max(1, int(round(rng.exponential(1.0 / load))))
        elif process == "bursty":
            t += 0 if i % 4 else max(1, int(round(4.0 / load)))
        else:
            raise ValueError(f"unknown arrival process: {process!r}")
        n = int(rng.integers(64, 97)) if rng.random() < 0.4 \
            else int(rng.integers(8, 25))
        out.append(_Arrival(
            rid=i, tick=t,
            prompt=rng.integers(1, vocab, n).astype(np.int32),
            max_new=int(rng.integers(4, 9)),
            tier=int(rng.integers(0, n_tiers)) if n_tiers else None))
    return out


def replay(server, stream: list[_Arrival], *, max_ticks: int = 20_000):
    """Drive the server against the stream: submit each arrival when the
    tick counter reaches it, fast-forwarding the counter across idle gaps
    (an idle server burns no compute, but queue age still accrues in
    ticks).  Returns (requests, drain_stats)."""
    from repro.runtime.server import Request
    reqs, i = [], 0
    t0 = time.time()
    while i < len(stream) or server.queue \
            or any(s is not None for s in server.slots):
        while i < len(stream) and stream[i].tick <= server.ticks:
            a = stream[i]
            r = Request(rid=a.rid, prompt=a.prompt.copy(), max_new=a.max_new,
                        tier=a.tier)
            server.submit(r)
            reqs.append(r)
            i += 1
        if not server.tick():
            if i < len(stream):
                server.ticks = stream[i].tick     # idle fast-forward
            else:
                break
        if server.ticks >= max_ticks:
            break
    stats = server.run_until_drained(max_ticks=max_ticks)
    stats["replay_wall_s"] = time.time() - t0
    return reqs, stats


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, float), q)) if len(xs) else 0.0


def summarize(reqs, stats) -> dict:
    done = [r for r in reqs if r.done and not r.aborted]
    ttft_t = [r.first_token_tick - r.arrival_tick for r in done]
    ttft_w = [r.first_token_s - r.arrival_s for r in done]
    long_t = [r.first_token_tick - r.arrival_tick for r in done
              if len(r.prompt) >= LONG_PROMPT]
    toks = sum(len(r.out) for r in done)
    wall = max(stats["replay_wall_s"], 1e-9)
    return {
        "completed": len(done),
        "aborted": sum(r.aborted for r in reqs),
        "ticks": stats["ticks"],
        "prefill_ticks": stats.get("prefill_ticks", 0),
        "ttft_p50_ticks": _pct(ttft_t, 50),
        "ttft_p99_ticks": _pct(ttft_t, 99),
        "ttft_p50_s": round(_pct(ttft_w, 50), 4),
        "ttft_p99_s": round(_pct(ttft_w, 99), 4),
        "ttft_long_mean_ticks": round(float(np.mean(long_t)), 2)
        if long_t else 0.0,
        "long_prompts": len(long_t),
        "tokens": toks,
        "tokens_per_s": round(toks / wall, 2),
        "wall_s": round(wall, 3),
        "invocation_rate": round(stats.get("invocation_rate", 0.0), 4),
        "served_invocation_rate":
            round(stats.get("served_invocation_rate", 0.0), 4),
        "undrained_queued": stats["undrained_queued"],
        "undrained_inflight": stats["undrained_inflight"],
        # serving-memory columns: dense cells report their (constant)
        # worst-case resident KV bytes, paged cells the page high-water
        # mark's worth plus the pool-utilisation ledger
        "kv_bytes_resident": stats.get("kv_bytes_resident", 0),
        "page_util": round(stats.get("page_util", 0.0), 4),
        "peak_pages": stats.get("page_hwm", 0),
    }


def _tokens_by_rid(reqs) -> dict:
    return {r.rid: tuple(r.out) for r in reqs}


def main(quick: bool = False, devices: int = 1, chunk: int = 16,
         n_reqs: int | None = None, base_options=None):
    import jax
    from repro.configs.registry import get_config, smoke_config
    from repro.models import model as M
    from repro.runtime.options import ServeOptions
    from repro.runtime.server import DecodeServer

    os.makedirs(OUT, exist_ok=True)
    if devices > 1 and len(jax.devices()) < devices:
        raise SystemExit(
            f"--devices {devices} needs {devices} jax devices but only "
            f"{len(jax.devices())} exist; run via `python -m "
            f"benchmarks.bench_serve` (which forces virtual CPU devices) "
            f"or set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{devices}")
    mesh = None
    batch, max_len = 4, 160
    if devices > 1:
        from repro.launch.mesh import make_host_mesh
        # data axis bounded by the slot table (batch % data must hold);
        # spare devices go to the model axis
        data = min(batch, devices)
        mesh = make_host_mesh(data=data, model=devices // data)

    cfg = smoke_config(get_config("internlm2-1.8b"))
    # full-capacity operating point: the bit-exactness gate's contract —
    # prefill-phase capacity clipping is batch-mix-dependent (it was
    # pre-chunking too: a prompt token's tickmates set its contention),
    # so the equality gates run where capacity never binds
    cfg = dataclasses.replace(cfg, approx=dataclasses.replace(
        cfg.approx, enable=True, exact_frac=1.0, invoke_frac=1.0,
        route_scope="tick"))
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    n_reqs = n_reqs or (10 if quick else 24)
    loads = [0.05, 0.25] if quick else [0.05, 0.15, 0.4]
    processes = ["poisson", "bursty"]
    tiers = (0.05, 0.10, 0.20)

    # the bench's fixed cell geometry overrides whatever the shared CLI
    # surface supplied; per-mode scheduling knobs land per server below
    base = base_options or ServeOptions()

    def server(mode: str, backend: str | None = None):
        return DecodeServer(cfg, params, options=dataclasses.replace(
            base, batch=batch, max_len=max_len,
            use_mcma_dispatch=True, mesh=mesh, qos_tiers=tiers,
            route_scope="tick", backend=backend,
            prefill_chunk=0 if mode == "token" else chunk,
            admission="fifo" if mode == "token" else "cost",
            kv_page_size=16 if mode == "paged" else 0))

    rows, gated = [], False
    for process in processes:
        for load in loads:
            stream = gen_stream(process, load, n_reqs, cfg.vocab,
                                n_tiers=len(tiers))
            cell = {}
            for mode in ("token", "chunk", "paged"):
                reqs, stats = replay(server(mode), stream)
                s = summarize(reqs, stats)
                cell[mode] = (reqs, s)
                rows.append(dict(
                    process=process, load=load, mode=mode, devices=devices,
                    prefill_chunk=0 if mode == "token" else chunk,
                    n_reqs=n_reqs, **s))
                print(f"{process:8s} load={load:5.2f} {mode:9s} "
                      f"ticks={s['ticks']:5d} ttft p50/p99="
                      f"{s['ttft_p50_ticks']:.0f}/{s['ttft_p99_ticks']:.0f} "
                      f"tok/s={s['tokens_per_s']:8.1f} "
                      f"inv={s['invocation_rate']:.3f} "
                      f"kvB={s['kv_bytes_resident']}", flush=True)
            # gate 1: identical greedy tokens per request, all modes
            tt, tc, tp = (_tokens_by_rid(cell[m][0])
                          for m in ("token", "chunk", "paged"))
            assert tt == tc, \
                f"chunked tokens diverge from token-by-token at " \
                f"{process}/load={load}: " \
                f"{ {k: (tt[k], tc[k]) for k in tt if tt[k] != tc[k]} }"
            # gate 1b: the paged cache is invisible to the sampled tokens
            assert tp == tc, \
                f"paged tokens diverge from dense at " \
                f"{process}/load={load}: " \
                f"{ {k: (tc[k], tp[k]) for k in tc if tc[k] != tp[k]} }"
            # gate 1c: paged must pay strictly fewer resident KV bytes
            # than dense on this mixed-length stream, and drain clean
            kb_d = cell["chunk"][1]["kv_bytes_resident"]
            kb_p = cell["paged"][1]["kv_bytes_resident"]
            assert 0 < kb_p < kb_d, \
                f"paged KV bytes must undercut dense at " \
                f"{process}/load={load}: paged {kb_p} vs dense {kb_d}"
            # gate 2: chunked prefill wins TTFT on long prompts
            lt = cell["token"][1]["ttft_long_mean_ticks"]
            lc = cell["chunk"][1]["ttft_long_mean_ticks"]
            assert cell["token"][1]["long_prompts"] > 0, \
                "stream has no long prompts — the TTFT gate is vacuous"
            assert lc < lt, \
                f"chunked prefill must beat token-by-token TTFT on " \
                f">= {LONG_PROMPT}-token prompts at {process}/load={load}: " \
                f"chunk {lc} vs token {lt} ticks"
            # gate 3 (one cell): the Pallas dispatch vs the XLA oracle,
            # server-level — identical greedy tokens on the same stream
            if not gated:
                reqs_x, stats_x = replay(server("chunk", backend="xla"),
                                         stream)
                sx = summarize(reqs_x, stats_x)
                rows.append(dict(process=process, load=load,
                                 mode="chunk-xla", devices=devices,
                                 prefill_chunk=chunk, n_reqs=n_reqs, **sx))
                tx = _tokens_by_rid(reqs_x)
                assert tx == tc, \
                    "pallas-vs-xla greedy token divergence at the server " \
                    f"level: { {k: (tc[k], tx[k]) for k in tc if tc[k] != tx[k]} }"
                gated = True
                print(f"{process:8s} load={load:5.2f} chunk-xla oracle gate "
                      f"passed ({sx['tokens']} tokens)", flush=True)

    path = os.path.join(OUT, "serve.csv")
    fields = list(rows[0].keys())
    for r in rows:
        fields += [k for k in r if k not in fields]
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=fields, restval="")
        w.writeheader()
        w.writerows(rows)
    print(f"wrote {path} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    from repro.runtime.cli import add_serve_options

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--devices", type=int, default=1,
                    help="replay on an N-way data mesh (forces N virtual "
                         "CPU devices when run as main)")
    ap.add_argument("--chunk", type=int, default=16,
                    help="prefill chunk size S for the chunked servers")
    ap.add_argument("--n-reqs", type=int, default=None)
    # the shared serving surface (runtime/cli.py): the bench pins its own
    # cell geometry (batch/max_len/chunking per mode) but any OTHER knob
    # registered there reaches the replayed servers via base_options
    add_serve_options(ap)
    args = ap.parse_args()
    if args.devices > 1 and "host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        # must land before jax initializes its backend (first device use)
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.devices}").strip()
    from repro.runtime.options import ServeOptions
    main(quick=args.quick, devices=args.devices, chunk=args.chunk,
         n_reqs=args.n_reqs, base_options=ServeOptions.from_args(args))
