"""Fig. 10/11: data-distribution statistics for Bessel under MCMA.

Fig. 10: each approximator's territory (dispatch share, per-territory mean
error) — shows the specialization the paper plots in 2D.
Fig. 11: confusion quadrants (AC / AnC / nAC / nAnC) for one-pass,
iterative, MCMA — MCMA must raise true-positive AC and crush the false
negatives (abandoned-but-safe data).
Writes benchmarks/out/distribution.csv.
"""
from __future__ import annotations

import csv
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import APPS, make_dataset
from repro.core import quality, train_iterative, train_mcma, train_one_pass

OUT = os.path.join(os.path.dirname(__file__), "out")


def main(n_train=8_000, n_test=3_000, epochs=1500, seed=0):
    os.makedirs(OUT, exist_ok=True)
    app = APPS["bessel"]
    key = jax.random.PRNGKey(seed)
    xtr, ytr, xte, yte = make_dataset(app, key, n_train, n_test)
    ks = jax.random.split(key, 3)
    rows = []

    # ---- Fig. 11 quadrants for the three methods ---------------------------
    methods = {
        "one-pass": train_one_pass(app, ks[0], xtr, ytr, epochs=epochs),
        "iterative": train_iterative(app, ks[1], xtr, ytr, epochs=epochs),
        "mcma-competitive": train_mcma(app, ks[2], xtr, ytr,
                                       scheme="competitive", epochs=epochs),
    }
    for name, model in methods.items():
        met = model.evaluate(xte, yte)
        nanc = 1.0 - met.true_invocation - met.false_neg - met.false_pos
        rows.append({"table": "fig11", "method": name, "approx": "",
                     "AC": round(met.true_invocation, 4),
                     "AnC": round(met.false_neg, 4),
                     "nAC": round(met.false_pos, 4),
                     "nAnC": round(nanc, 4),
                     "recall": round(met.recall, 4),
                     "share": "", "territory_err": ""})
        print(f"fig11 {name:18s} AC={met.true_invocation:.3f} "
              f"AnC={met.false_neg:.3f} nAC={met.false_pos:.3f}", flush=True)

    # ---- Fig. 10: per-approximator territories under MCMA ------------------
    mcma = methods["mcma-competitive"]
    cls = np.asarray(mcma.classify(xte))
    errs = np.asarray(mcma.approximator_errors(xte, yte))
    for i in range(mcma.n_approx):
        sel = cls == i
        share = float(sel.mean())
        terr = float(errs[i][sel].mean()) if sel.any() else float("nan")
        rows.append({"table": "fig10", "method": "mcma-competitive",
                     "approx": f"A{i + 1}", "AC": "", "AnC": "", "nAC": "",
                     "nAnC": "", "recall": "",
                     "share": round(share, 4),
                     "territory_err": round(terr, 5)})
        print(f"fig10 A{i+1}: share={share:.3f} territory_err={terr:.4f}",
              flush=True)

    with open(os.path.join(OUT, "distribution.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    main()
